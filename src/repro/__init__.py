"""repro: production-scale JAX/Pallas reproduction of REGTOP-k
(Novel Gradient Sparsification Algorithm via Bayesian Inference)."""
import functools as _functools

import jax as _jax

# jax < 0.5 exposes shard_map only under jax.experimental (with the
# replication check spelled check_rep rather than check_vma); the
# codebase targets the stable jax.shard_map spelling.
if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    @_functools.wraps(_shard_map)
    def _compat_shard_map(f, *args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, *args, **kwargs)

    _jax.shard_map = _compat_shard_map

# jax < 0.5 has no jax.lax.axis_size; psum(1, axis) is the classic
# spelling (constant-folded by XLA inside shard_map).
if not hasattr(_jax.lax, "axis_size"):
    def _axis_size(axis_name):
        return _jax.lax.psum(1, axis_name)

    _jax.lax.axis_size = _axis_size
