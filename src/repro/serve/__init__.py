from repro.serve.step import (
    build_decode_step, build_prefill, decode_cache_specs,
    delta_applier_from_snapshot, serve_parallel,
)
from repro.serve.delta import (
    DeltaApplier, DeltaPayload, DeltaPublisher, DeltaVersionError,
    FaultyChannel, MemoryChannel, SpoolChannel,
)
