from repro.serve.step import (
    build_decode_step, build_prefill, decode_cache_specs, serve_parallel,
)
