"""Versioned sparse delta broadcast: learning-while-serving (DESIGN.md §2.10).

The trainer publishes its post-step parameter movement as an exactly-k
sparse payload; serving replicas apply it as an O(k) scatter into live
params BETWEEN decode steps. The channel between them is lossy **by
contract**, not by hope — every robustness property is explicit:

- **Versioning.** Each payload carries a monotonic ``param_version``.
  A replica only ever applies version ``v+1`` on top of ``v``: stale
  arrivals are dropped (counted), a gap flips the replica into
  ``needs_resync`` and it REFUSES to advance until a full snapshot
  (``checkpoint/io.py``) at a newer version arrives.
- **Scatter-SET wire semantics.** ``values[i]`` is the absolute new
  parameter value at flat index ``indices[i]`` (TreeFlattener order),
  not an additive diff — applying a delta is idempotent, and publisher
  and replica run the SAME ``scatter_set_tree`` on the same payload, so
  a replica at accepted version v is bit-identical to the publisher's
  params-at-v in every leaf dtype.
- **Publisher-side error feedback.** The publisher mirrors what the
  replicas hold (``published``) and each step ships the top-k of
  ``|true - published|``; whatever did not fit stays visible in the
  next step's residual (the EF property that makes sparsification — and
  therefore a missed delta — a bounded, self-correcting error; see
  PAPERS.md on top-k sparsification).
- **Corruption + health guards.** Payloads carry a cheap position-
  weighted checksum over the bit patterns; checksum-failing or
  non-finite payloads are dropped for the step with ``dropped_corrupt``
  / ``dropped_nonfinite`` counters (the serve-side mirror of PR 6's
  aggregation guard; :func:`payload_health` is the traced-safe form a
  distributed replica psums).
- **In-flight consistency.** Applies are functional (never donated):
  a decode stream pins ``(params, version)`` from :meth:`DeltaApplier.
  acquire` and keeps computing against those immutable buffers while
  the live tree advances — free double-buffering, paid for with one
  O(params) copy per apply instead of an in-place update.

Transports: :class:`MemoryChannel` (in-process, thread-safe),
:class:`SpoolChannel` (atomic one-file-per-payload spool directory for
cross-process trainer → replica wiring), and :class:`FaultyChannel`
(wraps either side with the seeded ``core.faults`` channel schedules:
``loss`` / ``corrupt`` / ``reorder`` / ``stall``).

The contract the tests pin: under ANY injected fault trace, a replica
either holds version v with params bit-equal to the publisher's
params-at-v, or is mid-resync and refuses to advance.
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import tempfile
import threading
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bigvec
from repro.core.faults import (ChannelFaultSchedule, channel_corrupts,
                               channel_delay, channel_drops, channel_stalled)
from repro.core.flatten import TreeFlattener

# Wire header: version u32 + count u32 + j u64 + checksum u32, padded.
DELTA_HEADER_BYTES = 24


class DeltaVersionError(RuntimeError):
    """A delta's version violates the staleness contract (out of order,
    gapped, or at/below a restored checkpoint's version floor)."""


# ---------------------------------------------------------------------------
# Checksum + payload
# ---------------------------------------------------------------------------

def _u32(x):
    if isinstance(x, int):
        x = x & 0xFFFFFFFF
    return jnp.asarray(x).astype(jnp.uint32)


def payload_checksum(values, indices, version, count, j):
    """Position-weighted uint32 checksum over the payload bit patterns.

    Traced-safe (pure jnp, wraps mod 2^32), so the publisher stamps and
    the replica verifies with the SAME function — any single bit flip in
    values, indices, or the header fields changes the sum, and the
    position weights catch swapped entries. This is a transport
    integrity check, not a cryptographic MAC.
    """
    vb = jax.lax.bitcast_convert_type(
        jnp.asarray(values, jnp.float32), jnp.uint32)
    ib = jnp.asarray(indices, jnp.int32).astype(jnp.uint32)
    pos = jnp.arange(vb.shape[0], dtype=jnp.uint32)
    h = jnp.sum(vb * (pos * jnp.uint32(2654435761) + jnp.uint32(1)),
                dtype=jnp.uint32)
    h = h + jnp.sum(ib * (pos * jnp.uint32(40503) + jnp.uint32(2654435769)),
                    dtype=jnp.uint32)
    return (h + _u32(version) * jnp.uint32(97)
            + _u32(count) * jnp.uint32(89)
            + _u32(j) * jnp.uint32(83))


def payload_health(values, indices, checksum, version, count, j):
    """Traced-safe inbound guard: ``(ok, corrupt, nonfinite)`` bools.

    The shard_map'd form of :meth:`DeltaPayload.verify` — a distributed
    replica evaluates it per rank and psums the negations into the
    ``dropped_corrupt`` / ``dropped_nonfinite`` health counters (the
    serve-side mirror of the §2.7 aggregation guard).
    """
    values = jnp.asarray(values, jnp.float32)
    indices = jnp.asarray(indices, jnp.int32)
    finite = jnp.all(jnp.isfinite(values))
    pos = jnp.arange(indices.shape[0], dtype=jnp.int32)
    live = pos < jnp.asarray(count, jnp.int32)
    in_range = jnp.all(~live | ((indices >= 0) & (indices < j)))
    want = payload_checksum(values, indices, version, count, j)
    corrupt = (want != _u32(checksum)) | ~in_range
    return ~corrupt & finite, corrupt, ~finite


@dataclasses.dataclass(frozen=True)
class DeltaPayload:
    """One wire unit: ``count`` live (value, index) pairs at ``version``.

    ``values`` are fp32 ABSOLUTE new parameter values (scatter-SET),
    ``indices`` int32 positions in the TreeFlattener flat order over a
    ``j``-element model. ``checksum`` is stamped by the publisher and
    verified on intake.
    """
    version: int
    values: np.ndarray     # (k,) float32
    indices: np.ndarray    # (k,) int32
    count: int
    j: int
    checksum: int

    @classmethod
    def stamp(cls, version, values, indices, count, j) -> "DeltaPayload":
        values = np.asarray(values, np.float32)
        indices = np.asarray(indices, np.int32)
        csum = int(payload_checksum(values, indices, version, count, j))
        return cls(int(version), values, indices, int(count), int(j), csum)

    def verify(self) -> str:
        """'ok' | 'corrupt' | 'nonfinite' — intake guard verdict.

        Checksum/shape/index-range failures are 'corrupt' (the transport
        mangled it); a checksum-VALID payload carrying non-finite values
        is 'nonfinite' (the publisher shipped poison). Both are dropped,
        on distinct counters, and never reach live params.
        """
        v = np.asarray(self.values)
        i = np.asarray(self.indices)
        if v.ndim != 1 or v.shape != i.shape:
            return "corrupt"
        want = int(payload_checksum(v, i, self.version, self.count, self.j))
        if want != (self.checksum & 0xFFFFFFFF):
            return "corrupt"
        live = i[:min(max(self.count, 0), i.shape[0])]
        if live.size and (live.min() < 0 or live.max() >= self.j):
            return "corrupt"
        if not np.all(np.isfinite(v)):
            return "nonfinite"
        return "ok"

    def wire_bytes(self) -> int:
        return delta_wire_bytes(int(self.values.shape[0]))

    def to_dict(self) -> dict:
        return {"version": np.int64(self.version),
                "values": np.asarray(self.values, np.float32),
                "indices": np.asarray(self.indices, np.int32),
                "count": np.int64(self.count), "j": np.int64(self.j),
                "checksum": np.uint32(self.checksum)}

    @classmethod
    def from_dict(cls, d) -> "DeltaPayload":
        return cls(int(d["version"]), np.asarray(d["values"], np.float32),
                   np.asarray(d["indices"], np.int32), int(d["count"]),
                   int(d["j"]), int(d["checksum"]))


# ---------------------------------------------------------------------------
# The shared O(k) scatter — publisher mirror and replica apply run THIS
# ---------------------------------------------------------------------------

def scatter_set_tree(flattener: TreeFlattener, tree, values, indices,
                     count=None):
    """Scatter-SET ``values`` at flat ``indices`` into ``tree``'s leaves.

    O(k) per leaf: each leaf claims the live pairs inside its
    [offset, offset+size) slice via the §2.7 sentinel trick (dead slots
    point one past the leaf, ``mode="drop"``). Values cast to the leaf
    dtype AT THE LEAF, so publisher mirror and replica converge to
    bit-identical trees in any dtype. Functional (never donates): old
    trees stay valid for pinned in-flight readers.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    values = jnp.asarray(values, jnp.float32)
    indices = jnp.asarray(indices, jnp.int32)
    pos = jnp.arange(indices.shape[0], dtype=jnp.int32)
    live_all = (jnp.ones(indices.shape, bool) if count is None
                else pos < jnp.asarray(count, jnp.int32))
    out = []
    for leaf, off, size in zip(leaves, flattener.offsets, flattener.sizes):
        live = live_all & (indices >= off) & (indices < off + size)
        lidx = jnp.where(live, indices - off, size)
        flat = bigvec.scatter_set(leaf.reshape(-1), lidx,
                                  values.astype(leaf.dtype), mode="drop")
        out.append(flat.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(flattener.treedef, out)


# ---------------------------------------------------------------------------
# Publisher (trainer side)
# ---------------------------------------------------------------------------

class DeltaPublisher:
    """Stamps the trainer's post-step movement into versioned payloads.

    Keeps ``published`` — a mirror of what a fully-caught-up replica
    holds (version 0 mirror = the params handed to the constructor; ship
    that base to replicas as a snapshot). Each :meth:`publish` selects
    the top-k of ``|flatten(params) - flatten(published)|``, ships the
    ABSOLUTE new values there, and folds them into the mirror — residual
    movement stays in the next step's diff (publisher-side error
    feedback), so a coordinate the budget skipped is never lost, only
    late.
    """

    def __init__(self, params, k: int, *, record_history: bool = False):
        self.flattener = TreeFlattener(params)
        self.j = int(self.flattener.total)
        self.k = int(max(1, min(int(k), self.j)))
        self.version = 0
        # deep copy: the caller's buffers may be donated to its next
        # step (launch/train jits with donate_argnums); the mirror must
        # own its storage
        self.published = jax.tree_util.tree_map(
            lambda l: jnp.asarray(l).copy(), params)
        self.record_history = bool(record_history)
        self._history = {0: self._host_copy()} if record_history else {}
        flat = self.flattener

        def _step(params, published):
            true = flat.flatten(params)
            diff = jnp.abs(true - flat.flatten(published))
            _, idx = jax.lax.top_k(diff, self.k)
            idx = jnp.sort(idx).astype(jnp.int32)
            vals = bigvec.gather(true, idx).astype(jnp.float32)
            return scatter_set_tree(flat, published, vals, idx), vals, idx

        self._step = jax.jit(_step)

    def _host_copy(self):
        return jax.tree_util.tree_map(
            lambda l: np.array(l, copy=True), self.published)

    def publish(self, params) -> DeltaPayload:
        """One post-step publish: returns the stamped payload for
        version ``self.version + 1`` and advances the mirror."""
        self.published, vals, idx = self._step(params, self.published)
        self.version += 1
        if self.record_history:
            self._history[self.version] = self._host_copy()
        return DeltaPayload.stamp(self.version, np.asarray(vals),
                                  np.asarray(idx), self.k, self.j)

    def params_at(self, version: int):
        """The published mirror as of ``version`` — the oracle side of
        the §2.10 invariant (requires ``record_history=True``)."""
        if not self.record_history:
            raise ValueError("DeltaPublisher(record_history=True) required")
        return self._history[int(version)]

    def write_snapshot(self, snap_dir: str) -> str:
        """Full-params resync snapshot at the current version, via the
        checkpoint path (version-stamped manifest)."""
        return write_snapshot(snap_dir, self.published, self.version)


# ---------------------------------------------------------------------------
# Resync snapshots (checkpoint/io.py reuse)
# ---------------------------------------------------------------------------

def write_snapshot(snap_dir: str, params, version: int) -> str:
    """Save ``params`` as a resync snapshot: a params-only checkpoint at
    step == ``version`` with ``param_version`` stamped in the manifest."""
    from repro.checkpoint.io import save_checkpoint
    return save_checkpoint(snap_dir, int(version), params, {}, {},
                           param_version=int(version))


def read_snapshot(snap_dir: str, params_template, step: Optional[int] = None):
    """Load a resync snapshot -> ``(params, param_version)``. ``step``
    defaults to the latest snapshot in the directory."""
    from repro.checkpoint.io import (latest_step, read_manifest,
                                     restore_checkpoint)
    if step is None:
        step = latest_step(snap_dir)
        if step is None:
            raise FileNotFoundError(f"no snapshot in {snap_dir!r}")
    params, _, _ = restore_checkpoint(snap_dir, step, params_template, {}, {})
    manifest = read_manifest(snap_dir, step)
    version = manifest.get("param_version")
    return params, int(step if version is None else version)


# ---------------------------------------------------------------------------
# Applier (replica side)
# ---------------------------------------------------------------------------

class DeltaApplier:
    """Applies versioned deltas into live serving params between decode
    steps, under the §2.10 staleness contract.

    Two intake surfaces:

    - :meth:`offer` — channel-tolerant. Corrupt / non-finite / stale
      payloads are dropped ON COUNTERS; a version gap flips
      ``needs_resync`` and every later offer is refused
      (``resync_pending``) until :meth:`resync_from` restores a newer
      full snapshot. Nothing raises: a hostile channel cannot crash the
      replica, and nothing unhealthy ever reaches live params.
    - :meth:`apply` — strict. Raises :class:`DeltaVersionError` on ANY
      contract violation, including versions at or below the restored
      checkpoint floor (a delta predating the checkpoint you restored is
      a programming error, not channel weather — hard error, never a
      silent skip).

    Applies are functional: :meth:`acquire` pins ``(params, version)``
    for an in-flight decode stream, which keeps reading those immutable
    buffers bit-unchanged while later deltas move the live tree.
    """

    COUNTERS = ("received", "applied", "dropped_corrupt",
                "dropped_nonfinite", "dropped_stale", "gaps_detected",
                "resyncs")

    def __init__(self, params, *, version: int = 0,
                 version_floor: Optional[int] = None):
        self.flattener = TreeFlattener(params)
        self.j = int(self.flattener.total)
        self.params = params
        self.version = int(version)
        self.floor = int(version if version_floor is None else version_floor)
        self.needs_resync = False
        self.counters = {c: 0 for c in self.COUNTERS}
        flat = self.flattener
        shardings = [getattr(l, "sharding", None)
                     for l in jax.tree_util.tree_leaves(params)]
        out_shardings = None
        if shardings and all(s is not None for s in shardings):
            out_shardings = jax.tree_util.tree_unflatten(
                flat.treedef, shardings)

        def _apply(tree, values, indices, count):
            return scatter_set_tree(flat, tree, values, indices, count)

        self._apply = (jax.jit(_apply, out_shardings=out_shardings,
                               static_argnums=(3,))
                       if out_shardings is not None
                       else jax.jit(_apply, static_argnums=(3,)))

    # -- intake ------------------------------------------------------------

    def offer(self, payload: DeltaPayload) -> str:
        """Channel-tolerant intake; returns the verdict:
        'applied' | 'corrupt' | 'nonfinite' | 'stale' | 'gap' |
        'resync_pending'."""
        self.counters["received"] += 1
        verdict = payload.verify()
        if verdict == "corrupt" or (verdict == "ok"
                                    and payload.j != self.j):
            self.counters["dropped_corrupt"] += 1
            return "corrupt"
        if verdict == "nonfinite":
            self.counters["dropped_nonfinite"] += 1
            return "nonfinite"
        if self.needs_resync:
            return "resync_pending"
        if payload.version <= self.version:
            self.counters["dropped_stale"] += 1
            return "stale"
        if payload.version != self.version + 1:
            self.counters["gaps_detected"] += 1
            self.needs_resync = True
            return "gap"
        self._apply_verified(payload)
        return "applied"

    def apply(self, payload: DeltaPayload) -> None:
        """Strict intake: raises on any contract violation."""
        self.counters["received"] += 1
        verdict = payload.verify()
        if verdict != "ok" or payload.j != self.j:
            raise DeltaVersionError(
                f"refusing {verdict} delta v{payload.version} "
                f"(j={payload.j}, want {self.j})")
        if self.needs_resync:
            raise DeltaVersionError(
                f"mid-resync at v{self.version}: refusing to advance")
        if payload.version <= self.floor:
            raise DeltaVersionError(
                f"delta v{payload.version} is at/below the restored "
                f"checkpoint floor v{self.floor} — it predates the "
                "restored state and must never be applied")
        if payload.version != self.version + 1:
            raise DeltaVersionError(
                f"delta v{payload.version} on top of v{self.version}: "
                "versions must be contiguous")
        self._apply_verified(payload)

    def _apply_verified(self, payload: DeltaPayload) -> None:
        self.params = self._apply(self.params,
                                  np.asarray(payload.values, np.float32),
                                  np.asarray(payload.indices, np.int32),
                                  int(payload.count))
        self.version = payload.version
        self.counters["applied"] += 1

    # -- pinning + resync ---------------------------------------------------

    def acquire(self):
        """Pin ``(params, version)`` for a decode stream: JAX arrays are
        immutable and applies never donate, so the pinned tree stays
        bit-identical for the stream's whole life — double-buffering for
        the price of the functional update's copy."""
        return self.params, self.version

    def can_resync(self, snap_dir: str) -> bool:
        """Is a snapshot strictly NEWER than the held version available?
        (Resyncing backwards is forbidden; equal-version snapshots
        cannot fill the missed gap either.)"""
        from repro.checkpoint.io import latest_step
        step = latest_step(snap_dir)
        return step is not None and step > self.version

    def resync_from(self, snap_dir: str, step: Optional[int] = None) -> int:
        """Restore the full snapshot (latest by default), raise the
        version floor to it, and re-arm intake. Raises
        :class:`DeltaVersionError` if the snapshot would move the
        replica backwards."""
        params, version = read_snapshot(snap_dir, self.params, step)
        if version < self.version:
            raise DeltaVersionError(
                f"snapshot v{version} is older than held v{self.version}: "
                "resync must never move a replica backwards")
        self.params = self._reshard(params)
        self.version = version
        self.floor = version
        self.needs_resync = False
        self.counters["resyncs"] += 1
        return version

    def _reshard(self, params):
        old = self.params
        return jax.tree_util.tree_map(
            lambda o, n: (jax.device_put(jnp.asarray(n, o.dtype), o.sharding)
                          if hasattr(o, "sharding")
                          else jnp.asarray(n, o.dtype)),
            old, params)

    def metrics(self) -> dict:
        """Serve-metrics view: version + health counters (the
        single-process reading of the psum'd guard)."""
        return {"param_version": self.version,
                "needs_resync": self.needs_resync, **self.counters}


def drain(channel, applier: DeltaApplier) -> list:
    """Offer every payload the channel has ready; returns the verdicts."""
    return [applier.offer(p) for p in channel.recv()]


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------

class MemoryChannel:
    """In-process FIFO (thread-safe: the examples' trainer thread feeds
    a replica applying between decode steps)."""

    def __init__(self):
        self._q = deque()

    def send(self, payload: DeltaPayload) -> None:
        self._q.append(payload)

    def recv(self) -> list:
        out = []
        while True:
            try:
                out.append(self._q.popleft())
            except IndexError:
                return out


class SpoolChannel:
    """One-file-per-payload spool directory: the cross-process transport
    behind ``launch/train.py --publish-deltas`` / ``launch/serve.py
    --apply-deltas``.

    Files are named by a monotonic SEND sequence number (then version),
    written atomically (tmpfile + rename), so the receiver observes the
    channel's delivery order even when a fault wrapper reordered
    versions. Sender and receiver are independent instances; the
    receiver remembers the last sequence consumed.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        seqs = [self._parse(f)[0] for f in os.listdir(root)
                if f.startswith("delta_") and f.endswith(".npz")]
        self._seq = max(seqs) + 1 if seqs else 0
        self._read_seq = -1

    @staticmethod
    def _parse(fname: str):
        stem = fname[:-len(".npz")].split("_")
        return int(stem[1]), int(stem[2])

    def send(self, payload: DeltaPayload) -> None:
        with self._lock:
            seq = self._seq
            self._seq += 1
        fname = f"delta_{seq:08d}_{payload.version:08d}.npz"
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload.to_dict())
        os.replace(tmp, os.path.join(self.root, fname))

    def recv(self) -> list:
        ready = sorted(
            (self._parse(f), f) for f in os.listdir(self.root)
            if f.startswith("delta_") and f.endswith(".npz")
            and self._parse(f)[0] > self._read_seq)
        out = []
        for (seq, _), fname in ready:
            with np.load(os.path.join(self.root, fname)) as d:
                out.append(DeltaPayload.from_dict(d))
            self._read_seq = seq
        return out


class FaultyChannel:
    """Injects a seeded ``core.faults`` channel schedule around any
    transport — wrap the SEND side (in-process) or the RECV side (spool
    receiver); the per-version decisions are deterministic either way.

    ``loss`` drops the payload outright; ``corrupt`` flips a value bit
    AFTER the checksum was stamped (the applier's guard detects it, so
    it degenerates to a counted loss); ``reorder`` delays each version
    by a seeded amount and releases by (due, version); ``stall`` buffers
    the whole window and flushes it IN ORDER afterwards — a paused link,
    which the replica absorbs by applying the backlog, no resync.
    Call :meth:`flush` when the stream ends to release anything held.
    """

    def __init__(self, inner, sched: Optional[ChannelFaultSchedule]):
        self.inner = inner
        self.sched = sched
        self._pending = []      # reorder: heap of (due, version, payload)
        self._stalled = []      # stall: arrival-order buffer
        self._send_mode = False
        self.counters = {"sent": 0, "dropped": 0, "corrupted": 0,
                         "delayed": 0, "stalled": 0}

    def _process(self, payload: DeltaPayload) -> list:
        sched, v = self.sched, payload.version
        if sched is None:
            return [payload]
        if sched.kind == "loss":
            if bool(channel_drops(sched, v)):
                self.counters["dropped"] += 1
                return []
            return [payload]
        if sched.kind == "corrupt":
            if bool(channel_corrupts(sched, v)):
                self.counters["corrupted"] += 1
                return [_flip_bit(payload)]
            return [payload]
        if sched.kind == "stall":
            out = []
            if not bool(channel_stalled(sched, v)):
                out, self._stalled = self._stalled, []
                out.append(payload)
                return out
            self._stalled.append(payload)
            self.counters["stalled"] += 1
            return []
        # reorder
        delay = int(channel_delay(sched, v))
        if delay:
            self.counters["delayed"] += 1
        heapq.heappush(self._pending, (v + delay, v, payload))
        out = []
        while self._pending and self._pending[0][0] <= v:
            out.append(heapq.heappop(self._pending)[2])
        return out

    def send(self, payload: DeltaPayload) -> None:
        self._send_mode = True
        for p in self._process(payload):
            self.counters["sent"] += 1
            self.inner.send(p)

    def recv(self) -> list:
        inbound = self.inner.recv()
        if self._send_mode:
            # faults were already injected on the send path; applying
            # them again on receive would double-corrupt (an even number
            # of identical bit flips cancels) and double-count
            return inbound
        out = []
        for p in inbound:
            out.extend(self._process(p))
        return out

    def flush(self) -> list:
        """Release everything still held (end of stream). In send mode
        the releases are forwarded to the inner transport; they are also
        returned either way."""
        out, self._stalled = self._stalled, []
        while self._pending:
            out.append(heapq.heappop(self._pending)[2])
        if self._send_mode:
            for p in out:
                self.counters["sent"] += 1
                self.inner.send(p)
        return out


def _flip_bit(payload: DeltaPayload) -> DeltaPayload:
    """In-flight single-bit corruption — checksum left stale, so the
    intake guard must catch it."""
    vals = np.array(payload.values, np.float32, copy=True)
    bits = vals.view(np.uint32)
    bits[bits.size // 2] ^= np.uint32(1 << 20)
    return dataclasses.replace(payload, values=vals)


# ---------------------------------------------------------------------------
# Analytic costs (roofline/analysis.py + dryrun records consume these)
# ---------------------------------------------------------------------------

def delta_wire_bytes(k: int, value_bytes: int = 4, index_bytes: int = 4)\
        -> int:
    """Wire size of one delta: k (value, index) pairs + header."""
    return int(k) * (value_bytes + index_bytes) + DELTA_HEADER_BYTES


def resync_bytes(j: int, value_bytes: int = 4) -> int:
    """Wire size of one full-snapshot resync: the whole flat model."""
    return int(j) * value_bytes + DELTA_HEADER_BYTES


def resync_equiv_deltas(j: int, k: int, value_bytes: int = 4,
                        index_bytes: int = 4) -> float:
    """How many deltas one resync costs — the staleness-vs-bandwidth
    breakeven: a channel losing more than ~1/this fraction of versions
    spends its savings on snapshots."""
    return resync_bytes(j, value_bytes) / max(
        1, delta_wire_bytes(k, value_bytes, index_bytes))
