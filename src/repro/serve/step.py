"""Distributed serving steps (prefill + batched decode) under shard_map.

Sharding policy (DESIGN.md §2.1):

- prefill: batch over data axes, TP over model. The decode shapes have
  batch >= DP so the cache batch dim shards over data.
- decode with batch >= DP (decode_32k): cache (B/DP, S, kv_l, hd) local per
  rank; attention local.
- decode with batch < DP (long_500k, batch=1): KV cache SEQ dim shards over
  the data axes (context-parallel decode) with flash LSE-merge psums;
  SSM/conv states are replicated over data (O(1) size).

The decode step processes ONE token per sequence against the cache — this is
what the decode_32k / long_500k dry-run shapes lower.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models import decode_step as model_decode
from repro.models import init_decode_cache, prefill as model_prefill
from repro.models.parallel import Parallel
from repro.models.specs import param_specs
from repro.train.step import resolve_model_cfg


def serve_parallel(mesh, run: RunConfig, *, decode: bool) -> Parallel:
    axes = mesh.axis_names
    tp = mesh.shape["model"]
    dpaxes = tuple(a for a in axes if a != "model")
    dp = 1
    for a in dpaxes:
        dp *= mesh.shape[a]
    batch = run.shape.global_batch
    cache_seq_axis = None
    if decode and batch < dp:
        cache_seq_axis = dpaxes if len(dpaxes) > 1 else dpaxes[0]
    return Parallel(model_axis="model" if tp > 1 else None, data_axes=dpaxes,
                    tp=tp, seq_parallel=False, cache_seq_axis=cache_seq_axis)


def _dp(mesh):
    n = 1
    for a in mesh.axis_names:
        if a != "model":
            n *= mesh.shape[a]
    return n


def decode_cache_specs(run: RunConfig, mesh, pal: Parallel):
    """(abstract cache, PartitionSpec tree, local batch, local cache seq)."""
    cfg = resolve_model_cfg(run)
    dp = _dp(mesh)
    b = run.shape.global_batch
    seq = run.shape.seq_len
    if cfg.attn_kind == "sliding":
        seq = min(seq, cfg.window)
    dpaxes = pal.data_axes
    if pal.cache_seq_axis is not None:
        b_local, seq_local = b, seq // dp
        batch_spec, seq_spec = None, dpaxes
    else:
        b_local, seq_local = b // dp, seq
        batch_spec, seq_spec = dpaxes, None

    cache = jax.eval_shape(partial(
        init_decode_cache, cfg, pal, b_local, seq_local,
        jnp.dtype(cfg.dtype),
        1500 if cfg.is_encoder_decoder else 0))

    def spec_for(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        stacked = "blocks" in keys or "cross" in keys
        if name == "pos":
            return P()
        # attention KV caches have a seq dim at index 1 (after batch)
        if name in ("k", "v", "ckv", "krope"):
            head_sharded = (name in ("k", "v") and "cross" not in keys
                            and cfg.attn_kind != "mla" and pal.tp_on)
            dims = ([batch_spec, seq_spec]
                    + [None] * (leaf.ndim - 2 - (1 if stacked else 0)))
            if head_sharded:
                nd = leaf.ndim - (1 if stacked else 0)
                dims[-2 if nd >= 4 else -1] = "model"
            if "cross" in keys:
                # cross K/V: (nsb, B, S_enc, kv, hd), seq NOT ctx-sharded
                dims = ([batch_spec, None]
                        + [None] * (leaf.ndim - 2 - (1 if stacked else 0)))
                if cfg.attn_kind != "mla" and pal.tp_on:
                    dims[-2] = "model"
            return P(*([None] if stacked else []), *dims)
        # SSM states: batch leading; replicated over data if ctx-parallel
        dims = [batch_spec if pal.cache_seq_axis is None else None]
        dims += [None] * (leaf.ndim - 1 - (1 if stacked else 0))
        # channel-sharded dims over model
        if pal.tp_on and name in ("conv", "h", "c", "n"):
            ch_ax = {"conv": -1, "h": -2, "c": -2, "n": -1}[name]
            if name == "c":
                ch_ax = -2
            dims[ch_ax] = "model"
        return P(*([None] if stacked else []), *dims)

    specs = jax.tree_util.tree_map_with_path(spec_for, cache)
    return cache, specs, b_local, seq_local


def build_decode_step(run: RunConfig, mesh, pal: Parallel):
    """Returns (decode_fn(params, cache, token) -> (logits, cache), specs)."""
    cfg = resolve_model_cfg(run)
    tmpl = jax.eval_shape(
        partial(__import__("repro.models", fromlist=["m"]).init_params, cfg, pal),
        jax.random.PRNGKey(0))
    pspecs = param_specs(tmpl) if pal.tp_on else jax.tree_util.tree_map(
        lambda _: P(), tmpl)
    cache_abs, cspecs, b_local, seq_local = decode_cache_specs(run, mesh, pal)
    dpaxes = pal.data_axes
    tok_spec = P(dpaxes, None) if pal.cache_seq_axis is None else P(None, None)
    logit_spec = P(dpaxes, None) if pal.cache_seq_axis is None else P(None, None)

    def fn(params, cache, token):
        logits, cache = model_decode(params, cache, token, cfg, pal)
        return logits, cache

    wrapped = jax.shard_map(fn, mesh=mesh,
                            in_specs=(pspecs, cspecs, tok_spec),
                            out_specs=(logit_spec, cspecs), check_vma=False)
    return wrapped, (pspecs, cspecs, tok_spec)


def delta_applier_from_snapshot(run: RunConfig, mesh, pal: Parallel,
                                snap_dir: str):
    """Replica-side entry to the delta broadcast (DESIGN.md §2.10):
    restore the trainer's latest full snapshot as the serving params,
    sharded per the decode step's param specs, and return
    ``(DeltaApplier, params)`` positioned at the snapshot's
    ``param_version``. The applier's floor starts there, so deltas at or
    below the snapshot version can never apply."""
    from jax.sharding import NamedSharding
    from repro.serve.delta import DeltaApplier, read_snapshot
    from repro.train.step import abstract_params
    tmpl = abstract_params(run, pal)
    pspecs = param_specs(tmpl) if pal.tp_on else jax.tree_util.tree_map(
        lambda _: P(), tmpl)
    params_np, version = read_snapshot(snap_dir, tmpl)
    params = jax.tree_util.tree_map(
        lambda n, t, s: jax.device_put(jnp.asarray(n, t.dtype),
                                       NamedSharding(mesh, s)),
        params_np, tmpl, pspecs)
    applier = DeltaApplier(params, version=version)
    return applier, params


def build_prefill(run: RunConfig, mesh, pal: Parallel):
    cfg = resolve_model_cfg(run)
    tmpl = jax.eval_shape(
        partial(__import__("repro.models", fromlist=["m"]).init_params, cfg, pal),
        jax.random.PRNGKey(0))
    pspecs = param_specs(tmpl) if pal.tp_on else jax.tree_util.tree_map(
        lambda _: P(), tmpl)
    dpaxes = pal.data_axes
    cache_abs, cspecs, b_local, seq_local = decode_cache_specs(
        run, mesh, dataclasses.replace(pal, cache_seq_axis=None))

    def fn(params, batch):
        logits, cache = model_prefill(params, batch, cfg, pal,
                                      max_seq=run.shape.seq_len)
        return logits, cache

    batch_specs = {"tokens": P(dpaxes, None)}
    if cfg.frontend == "vision_stub":
        batch_specs["patches"] = P(dpaxes, None, None)
    elif cfg.frontend == "audio_stub":
        batch_specs["frames"] = P(dpaxes, None, None)
    wrapped = jax.shard_map(fn, mesh=mesh, in_specs=(pspecs, batch_specs),
                            out_specs=(P(dpaxes, None), cspecs),
                            check_vma=False)
    return wrapped, (pspecs, batch_specs)
