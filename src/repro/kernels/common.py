"""Shared kernel-dispatch helpers."""
from __future__ import annotations

import jax


def auto_interpret() -> bool:
    """Pallas interpret-mode auto-selection: native on TPU, interpreted
    elsewhere. The single source of truth for backend detection across
    the kernel packages."""
    return jax.default_backend() != "tpu"
