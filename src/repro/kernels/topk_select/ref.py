"""Pure-jnp oracle for the histogram threshold top-k kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.topk_select.kernel import BINS


def histogram_ref(x: jnp.ndarray, amax: jnp.ndarray, bins: int = BINS):
    """Identical semantics to kernel.histogram_pallas: linear histogram of
    |x|/amax into `bins` bins (clipped)."""
    amax = jnp.maximum(amax, 1e-30)
    scaled = jnp.abs(x.astype(jnp.float32)) / amax
    bidx = jnp.clip((scaled * bins).astype(jnp.int32), 0, bins - 1)
    return jnp.zeros((bins,), jnp.int32).at[bidx].add(1)


def threshold_from_hist(hist: jnp.ndarray, amax: jnp.ndarray, k: int,
                        dtype=jnp.float32):
    """Smallest bin boundary tau with count(|x| >= tau) >= k."""
    from repro.core.select import hist_tail_bin
    bins = hist.shape[0]
    b = hist_tail_bin(hist, k)
    return jnp.where(b >= 0, b.astype(jnp.float32) / bins * amax, 0.0).astype(dtype)


def topk_mask_ref(x: jnp.ndarray, k: int, bins: int = BINS):
    amax = jnp.max(jnp.abs(x))
    hist = histogram_ref(x, amax, bins)
    tau = threshold_from_hist(hist, amax, k)
    return (jnp.abs(x) >= tau).astype(x.dtype)
