"""Pallas TPU kernel: magnitude histogram for threshold top-k selection.

TPU adaptation of radix-select (DESIGN.md §2.2): one O(J) VMEM-tiled pass
builds a BINS-bin histogram of |x| / amax; the k-th magnitude threshold is
the smallest bin boundary whose tail count >= k. The TPU grid is sequential,
so the kernel accumulates into the same output block across grid steps
(out index_map -> (0, 0)).

Block layout: x reshaped to (J/BLOCK, BLOCK) rows, BLOCK = 8 * 128 * 4
(fp32 VMEM tile-aligned); per grid step the kernel bins one row with an
in-register bincount (scatter-add into the accumulated histogram block)
under interpret mode, keeping the O(BLOCK x BINS) one-hot compare-and-sum
only for native-TPU lowering until the bincount is TPU-validated
(ROADMAP open item). The kernels/compress two-sweep pipeline subsumes
the separate amax pass via bit-pattern binning for the full compression
step; this kernel remains the standalone linear-histogram selector used
by core.select's "histogram_kernel" method.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BINS = 2048
BLOCK = 8 * 128 * 4   # 4096 elements per grid step


def _hist_kernel(amax_ref, x_ref, hist_ref, *, bins: int,
                 use_bincount: bool):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    amax = amax_ref[0, 0]
    x = x_ref[...]                                   # (1, BLOCK)
    scaled = jnp.abs(x.astype(jnp.float32)) / amax
    bidx = jnp.clip((scaled * bins).astype(jnp.int32), 0, bins - 1)  # (1, B)
    if use_bincount:
        # in-register bincount (replaces the O(BLOCK x BINS) one-hot
        # compare); dynamic scatter-add — validated under interpret only
        hist_ref[...] += jnp.zeros((1, bins), jnp.int32).at[
            0, bidx[0]].add(1)
    else:
        # native-TPU lowering keeps the one-hot compare-and-sum until the
        # bincount is TPU-validated (ROADMAP open item)
        onehot = (bidx.reshape(-1, 1) ==
                  jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1))
        hist_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0,
                                 keepdims=True)


def histogram_pallas(x: jnp.ndarray, amax: jnp.ndarray, bins: int = BINS,
                     interpret=None) -> jnp.ndarray:
    """x: (J,) with J % BLOCK == 0 (caller pads). Returns (bins,) int32.

    interpret=None auto-selects from the JAX backend (native on TPU,
    interpreted elsewhere)."""
    if interpret is None:
        from repro.kernels.common import auto_interpret
        interpret = auto_interpret()
    j = x.shape[0]
    assert j % BLOCK == 0, j
    rows = j // BLOCK
    xr = x.reshape(rows, BLOCK)
    amax2 = jnp.maximum(amax, 1e-30).reshape(1, 1).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, bins=bins, use_bincount=interpret),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),          # amax (SMEM-ish)
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),      # x row
        ],
        out_specs=pl.BlockSpec((1, bins), lambda i: (0, 0)),  # accumulate
        out_shape=jax.ShapeDtypeStruct((1, bins), jnp.int32),
        interpret=interpret,
    )(amax2, xr)
    return out[0]
