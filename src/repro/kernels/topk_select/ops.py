"""Jit-friendly wrapper for the histogram threshold-select kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import auto_interpret as _interpret
from repro.kernels.topk_select.kernel import BINS, BLOCK, histogram_pallas
from repro.kernels.topk_select.ref import threshold_from_hist


def histogram_threshold_op(x: jnp.ndarray, k: int, bins: int = BINS):
    """k-th |x| magnitude via the Pallas histogram. x: (J,) any float."""
    j = x.shape[0]
    j_pad = -(-j // BLOCK) * BLOCK
    xp = jnp.pad(x.astype(jnp.float32), (0, j_pad - j))
    amax = jnp.max(jnp.abs(xp))
    hist = histogram_pallas(xp, amax, bins, interpret=_interpret())
    # padding contributes j_pad - j zeros to bin 0; harmless for the tail
    # count unless k reaches into bin 0 — correct by subtracting them.
    hist = hist.at[0].add(-(j_pad - j))
    return threshold_from_hist(hist, amax, k, x.dtype)


def topk_mask_op(x: jnp.ndarray, k: int, bins: int = BINS):
    tau = histogram_threshold_op(x, k, bins)
    return (jnp.abs(x) >= tau).astype(x.dtype)
