"""Jit-friendly entry points for the two-sweep fused compression pipeline.

``fused_compress_arrays`` runs the whole compression step for one worker:

    sweep 1:  a, score           (dense inputs read exactly once)
    sweep 2:  candidate slots    (per-row/per-block top candidates)
    O(cand):  exact-k trim, REGTOP-k posterior corrections, exactness
              checks, fixed-k (values, indices), optional dense ghat,
              and the O(k) scatter-zero that writes the next step's
              err state in place (DESIGN.md §2.2)

The step is **two O(J) traversals end to end** on the sparse-comm path:
the only J-sized state is ``err_prev`` (= a^{t-1} * (1 - s^{t-1}),
maintained by zeroing the k selected slots of ``a`` after the trim), so
no dense mask is ever written and sweep 1 reads exactly one state
vector. Dense masks, when a caller needs one, are reconstructed from
the packed indices (``core.sparsify.dense_mask``, O(k)).

With ``num_buckets > 1`` (DESIGN.md §2.4) the flat gradient is
partitioned into contiguous buckets (core.flatten.bucket_bounds); both
sweeps run per bucket and the per-bucket bit-pattern histograms are
merged (O(num_buckets x BINS)) into ONE global threshold, so the union
of per-bucket candidate selections still covers the exact global top-k.
The O(cand) trim stays global — selected support and packed order are
bit-identical to the flat (num_buckets=1) path. NB: because the trim
(and its lax.cond fallback) joins all buckets, the packed pairs exist
only after every bucket's sweeps finish; the overlap the bucketing buys
is on the COMMUNICATION side (core.aggregate chunks the packed pairs so
gather b+1 runs concurrently with scatter-add b), not compression
hidden behind collectives.

The execution strategy is auto-selected from the JAX backend (the
"interpret or not" decision the old kernels hardcoded): native Pallas
kernels on TPU, fusion-friendly XLA lowering elsewhere, and
``pallas_interpret`` for validating the kernel bodies in tests.

Exactness: the compacted candidate set provably covers the true top-k
unless the per-row/per-block witnesses say otherwise (or a boundary tie
is ambiguous under REGTOP-k support corrections); those rare cases take
a ``lax.cond`` fallback to a full ``lax.top_k`` with identical
semantics. Fast path and fallback both reproduce the reference
selector's tie-break support exactly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.flatten import bucket_bounds
from repro.core.numerics import safe_denom
from repro.kernels.common import auto_interpret
from repro.kernels.compress import kernel as pk
from repro.kernels.compress import xla as px
from repro.kernels.compress.dispatch import hist_capacity


def default_strategy() -> str:
    return "xla" if auto_interpret() else "pallas"


def sweep_plan(pipeline: str, comm_mode: str = "sparse") -> dict:
    """Analytic O(J) HBM-traversal plan per compress step (DESIGN.md §2.2).

    A "pass" is a full J-sized streaming read or write. O(k) scatters and
    gathers (mask/ghat/packing fix-ups) are not passes. Bucketing does
    not change the plan: num_buckets partial sweeps of J/num_buckets
    elements are one J-equivalent traversal (the audit weights them
    fractionally, DESIGN.md §2.3).
    """
    if pipeline == "reference":
        # score chain reads (g, err, a_prev, g_agg_prev, s_prev) + writes
        # (a, score) + step-0 where pass + two full |score| sorts + mask
        # scatter + ghat/err pass: ~8 traversals, 2 O(J log k) sorts.
        return {"o_j_passes": 8, "full_sorts": 2}
    # fused: sweep 1 (one elementwise stream) + sweep 2 (candidate
    # compaction). State updates (err scatter-zero, mom masking, packed
    # pairs, mask reconstruction) are all O(k) — no third traversal.
    passes = 2 if comm_mode == "sparse" else 3   # +1: dense ghat write
    return {"o_j_passes": passes, "full_sorts": 0}


def _posterior_keys(a_sel, a_prev_sel, g_prev_sel, step, *,
                    omega, mu, support_valid=None):
    """|score| of the support entries (Algorithm 1 line 5, O(k)).

    ``a_sel`` is the error-compensated gradient AT the support indices.
    The production call site gathers it from the dense ``a`` buffer
    BEFORE the trim's lax.cond (a pre-cond read keeps the final err
    scatter-zero in-place); the fallback branch recomputes it from the
    function parameters (``_gather_inputs``). ``support_valid`` masks
    inert pad slots of the histogram selector's fixed-capacity support
    state (slots >= nsel_prev point at index 0 and must not contribute
    a corrected key)."""
    safe = safe_denom(omega * a_sel)
    delta_sel = (g_prev_sel - omega * a_prev_sel) / safe
    skey = jnp.abs(a_sel * jnp.tanh(jnp.abs(1.0 + delta_sel) / mu))
    skey = jnp.where(step == 0, -jnp.inf, skey)
    if support_valid is not None:
        skey = jnp.where(support_valid, skey, -jnp.inf)
    return skey


def _sweep1_xla(kind, g, err_prev, c, *, momentum, mom):
    err = err_prev.astype(jnp.float32)               # ONE state read
    g = g.astype(jnp.float32)
    mom_out = mom
    if kind == "dgc":
        mom_out = momentum * mom.astype(jnp.float32) + g
        a = err + mom_out
    else:
        a = err + g
    return a, a * c, mom_out


def _candidates_pallas(kind, g, err_prev, c, step, *, k: int,
                       regtopk: bool, momentum: float, mom, interpret: bool,
                       bounds):
    """Per-bucket Pallas sweeps + histogram-merge global threshold.

    Sweep 1 runs once per bucket and emits that bucket's 2048-bin
    bit-pattern histogram; the merged histogram picks a single global
    tau (count(|score| >= tau) >= k + margin over the WHOLE vector, so
    per-bucket >=tau compaction unions to a global-top-k cover). Sweep 2
    then compacts each bucket independently against that shared tau.
    """
    j = g.shape[0]
    dgc = kind == "dgc"
    a_parts, score_parts, mom_parts, hists = [], [], [], []
    for off, size in bounds:
        j_pad = -(-size // pk.BLOCK) * pk.BLOCK
        pad = lambda x: jnp.pad(
            x[off:off + size].astype(jnp.float32), (0, j_pad - size))
        a_p, score_p, mom_p, _amax, hist = pk.sweep1_pallas(
            pad(g), pad(err_prev), c,
            mode=("dgc" if dgc else "plain"), momentum=momentum,
            mom=None if mom is None else pad(mom), interpret=interpret)
        # padding contributed (j_pad - size) zero keys to bin 0
        hists.append(hist.at[0].add(-(j_pad - size)))
        a_parts.append(a_p[:size])
        score_parts.append(score_p)
        if dgc:
            mom_parts.append(mom_p[:size])
    # margin k: REGTOP-k support corrections may drop <=k entries below
    # tau without breaking top-k coverage of the candidates
    target = k + jnp.where(jnp.logical_and(regtopk, step > 0), k, 0)
    tau = pk.threshold_from_bucket_hists(hists, target)
    # per-block slot capacity from the GLOBAL selection density (a bucket
    # block's expected candidate share does not depend on the bucketing)
    maxpb = int(min(pk.BLOCK, max(32, -(-8 * k * pk.BLOCK // j))))
    ck_parts, ci_parts, oks = [], [], []
    for (off, size), score_p in zip(bounds, score_parts):
        _mask_t, ck, ci, cnts = pk.sweep2_pallas(
            score_p, tau, maxpb=maxpb, interpret=interpret, want_mask=False)
        # bucket-local padding slots must not alias the next bucket's
        # index range: kill them BEFORE the global-offset shift
        ck = jnp.where(ci < size, ck, -jnp.inf)
        ci_parts.append(ci + jnp.uint32(off))
        ck_parts.append(ck)
        oks.append(jnp.max(cnts) <= maxpb)
    producer_ok = oks[0]
    for ok_b in oks[1:]:
        producer_ok = jnp.logical_and(producer_ok, ok_b)
    a = a_parts[0] if len(bounds) == 1 else jnp.concatenate(a_parts)
    mom_out = None
    if dgc:
        mom_out = (mom_parts[0] if len(bounds) == 1
                   else jnp.concatenate(mom_parts))
    cand_k = ck_parts[0] if len(bounds) == 1 else jnp.concatenate(ck_parts)
    cand_i = ci_parts[0] if len(bounds) == 1 else jnp.concatenate(ci_parts)
    return a, mom_out, cand_k, cand_i, producer_ok


def _candidates_xla(kind, g, err_prev, c, *, k: int, momentum: float,
                    mom, bounds):
    """Per-bucket XLA candidate compaction.

    Sweep 1 is one fused elementwise pass over the whole vector (XLA
    fuses across bucket slices anyway); sweep 2's per-row top-W
    compaction runs per bucket so each bucket's candidate chain is
    independent. Returns per-bucket (full_cover, row_min) witnesses —
    the exactness check needs the global tau_k, known only after the
    trim. Candidate order stays global-index-ascending across buckets,
    preserving the flat path's tie-break semantics bit-for-bit.
    """
    j = g.shape[0]
    a, score, mom_out = _sweep1_xla(kind, g, err_prev, c,
                                    momentum=momentum, mom=mom)
    if kind != "dgc":
        mom_out = None
    keys = jnp.abs(score)
    ck_parts, ci_parts, witnesses = [], [], []
    for off, size in bounds:
        kb = px.pad_keys(keys[off:off + size])
        # density over the GLOBAL j: a bucket's rows are provisioned
        # exactly like the flat path's (witness + fallback cover
        # concentration), so bucketing adds no candidate-slot cost
        cv, ci, row_min, full_cover = px.candidates_xla(
            kb, k, density_len=(j if len(bounds) > 1 else 0))
        ck_parts.append(cv)
        ci_parts.append(ci + jnp.uint32(off))
        witnesses.append((full_cover, row_min))
    cand_k = ck_parts[0] if len(bounds) == 1 else jnp.concatenate(ck_parts)
    cand_i = ci_parts[0] if len(bounds) == 1 else jnp.concatenate(ci_parts)
    return a, mom_out, cand_k, cand_i, witnesses


def _fused_randk(g, err_prev, *, k: int, key, want_ghat: bool,
                 ef_dtype) -> dict:
    """Fused RANDOM-k: selection is score-free, so the whole step is ONE
    elementwise sweep (the err_prev + g stream) plus O(k) random gathers
    and the O(k) scatter-zero state write — no sweep 2, no histogram, no
    trim. The elementwise form is optimal on every backend (XLA fuses
    it; a Pallas grid would add nothing), so all strategies share it.
    Index stream is identical to the reference randk's (both call
    select.randk_indices on the same key)."""
    from repro.core import bigvec
    from repro.core.select import randk_indices
    assert key is not None, "randk needs a PRNG key"
    j = g.shape[0]
    a, _, _ = _sweep1_xla("randk", g, err_prev, jnp.float32(1.0),
                          momentum=0.0, mom=None)
    idx = randk_indices(key, j, k)
    # gather before the scatter-zero: a's buffer is read-complete when
    # the O(k) state write runs, so it updates in place
    values = bigvec.gather(a, idx)
    err = bigvec.scatter_set(a.astype(jnp.dtype(ef_dtype)), idx, 0.0)
    ghat = None
    if want_ghat:
        ghat = bigvec.scatter_set(jnp.zeros((j,), jnp.float32), idx, values)
    return {"err": err, "values": values, "indices": idx,
            "ghat": ghat, "mom": None, "count": jnp.asarray(k, jnp.int32),
            "tau": None}


def fused_compress_arrays(kind: str, g, err_prev, step, *, k: int,
                          omega=1.0, mu: float = 0.1, Q: float = 0.0,
                          momentum: float = 0.9, mom=None,
                          idx_prev=None, a_prev_sel=None, g_prev_sel=None,
                          nsel_prev=None, want_ghat: bool = True,
                          strategy: Optional[str] = None,
                          num_buckets: int = 1, selector: str = "exact",
                          ef_dtype="float32", key=None) -> dict:
    """One fused compression step. kind in {"topk", "dgc", "regtopk",
    "randk", "thresholdk"} (thresholdk shares the plain-score path with
    topk; randk needs ``key`` and ignores ``selector``).

    Inputs: g (J,) raw gradient; err_prev (J,) the ONE J-sized state
    vector — the previous step's error feedback a^{t-1} * (1 - s^{t-1})
    (fp32 or bf16 per ``ef_dtype``; sweep math is always fp32
    in-register); step () int32. REGTOP-k additionally takes the O(k)
    posterior (idx_prev uint32, a_prev_sel, g_prev_sel; with
    selector="histogram" these are hist_capacity-sized and ``nsel_prev``
    marks how many leading slots are live) — the posterior's idx_prev
    doubles as the support set, so no dense mask exists anywhere in the
    state. DGC takes the momentum buffer ``mom``. ``num_buckets``
    partitions the sweeps into contiguous buckets (DESIGN.md §2.4);
    selection semantics are bucketing-invariant.

    Returns {"err", "values", "indices", "count", "tau", "ghat" (None
    unless want_ghat), "mom" (dgc only: the selection-masked momentum)}.
    ``err`` is the NEXT step's state — ``a`` with the selected slots
    zeroed by an O(k) scatter (bit-identical to the reference's
    a - mask*a), stored in ``ef_dtype``.

    - selector="exact": values/indices are the fixed-k packed pairs
      ordered by |score| descending; selected support is bit-identical
      to the reference exact selector's (and to the flat num_buckets=1
      path) for every num_buckets. count == k, tau is None.
    - selector="histogram": threshold selection at tau =
      key_bin_edge(k-th |score|) — the sweep-1 bit-pattern histogram
      threshold (DESIGN.md §2.5). values/indices are fixed
      hist_capacity(k, j)-sized; ``count`` in [k, capacity] entries are
      live, the tail is inert (value 0.0 at index 0). ``tau`` is the
      realized threshold.
    """
    from repro.core import bigvec
    strategy = strategy or default_strategy()
    j = g.shape[0]
    k = int(min(k, j))
    if kind == "randk":
        return _fused_randk(g, err_prev, k=k, key=key,
                            want_ghat=want_ghat, ef_dtype=ef_dtype)
    hist = selector == "histogram"
    # static packed capacity; also the candidate-provisioning budget —
    # for exact selection kcap == k and everything below degenerates to
    # the original exact-k trim
    kcap = hist_capacity(k, j) if hist else k
    bounds = bucket_bounds(j, num_buckets)
    regtopk = kind == "regtopk"
    if regtopk:
        c = jnp.where(step == 0, jnp.float32(1.0),
                      jnp.tanh(jnp.abs(1.0 + jnp.float32(Q)) / mu))
    else:
        c = jnp.float32(1.0)

    if strategy in ("pallas", "pallas_interpret"):
        interpret = strategy == "pallas_interpret" or auto_interpret()
        a, mom_out, cand_k, cand_i, producer_ok = _candidates_pallas(
            kind, g, err_prev, c, step, k=kcap, regtopk=regtopk,
            momentum=momentum, mom=mom, interpret=interpret, bounds=bounds)
        witnesses = None
    else:
        a, mom_out, cand_k, cand_i, witnesses = _candidates_xla(
            kind, g, err_prev, c, k=kcap, momentum=momentum, mom=mom,
            bounds=bounds)
        producer_ok = None                   # needs tau; checked below

    # --- O(candidates) fixed-capacity trim ------------------------------
    def _gather_inputs(idx):
        """a[idx] recomputed from the step's INPUT arrays (bitwise
        identical: per-element adds commute with the gather). Used only
        inside the lax.cond fallback branch, whose operands are already
        the function parameters — gathering from the dense ``a`` there
        would extend a's liveness past the cond and force the err
        scatter-zero to copy the whole buffer."""
        gi = bigvec.gather(g, idx).astype(jnp.float32)
        ei = bigvec.gather(err_prev, idx).astype(jnp.float32)
        if kind == "dgc":
            return ei + (momentum * bigvec.gather(mom, idx).astype(
                jnp.float32) + gi)
        return ei + gi

    support_valid = None
    if regtopk:
        if nsel_prev is not None:
            support_valid = (jnp.arange(idx_prev.shape[0], dtype=jnp.int32)
                             < nsel_prev)
        skey = _posterior_keys(bigvec.gather(a, idx_prev), a_prev_sel,
                               g_prev_sel, step, omega=omega, mu=mu,
                               support_valid=support_valid)
        # candidates that are support members carry an uncorrected key:
        # disable them (the corrected copy is appended below). With no
        # dense mask in the state, membership is resolved against the
        # O(k) posterior support itself — sort + searchsorted in
        # candidate space, O((k + cand) log k), no O(J) array touched.
        if support_valid is not None:
            # inert pad slots alias index 0: exclude them via the
            # out-of-range sentinel before the sort (bigvec.live_idx)
            idx_live = bigvec.live_idx(idx_prev, support_valid, j)
        else:
            idx_live = idx_prev.astype(jnp.uint32)
        idx_sorted = jnp.sort(idx_live)
        pos = jnp.minimum(jnp.searchsorted(idx_sorted, cand_i),
                          idx_sorted.shape[0] - 1)
        hit = (idx_sorted[pos] == cand_i) & (step > 0)
        cand_k = jnp.where(hit, -jnp.inf, cand_k)
        allk = jnp.concatenate([cand_k, skey])
        alli = jnp.concatenate([cand_i, idx_prev.astype(jnp.uint32)])
    else:
        allk, alli = cand_k, cand_i

    tv, tsel = jax.lax.top_k(allk, kcap)
    idx_fast = alli[tsel]
    # signed a-values of every trim entry, gathered from the dense ``a``
    # BEFORE the cond: every read of a's buffer stays ahead of the final
    # err scatter-zero, which can then update it in place (a post-cond
    # gather would extend a's liveness and cost a defensive O(J) copy).
    # Clamp: Pallas INVALID_IDX slots carry -inf keys and are never
    # selected on the fast path.
    allv = bigvec.gather(a, jnp.minimum(alli, jnp.uint32(j - 1)))
    val_fast = allv[tsel]
    kth = tv[k - 1]
    valid = kth > -jnp.inf
    # histogram tau: bit-pattern bin lower edge of the k-th key. The
    # sweep-2 compaction threshold (merged-histogram tau at target
    # kcap + margin) is <= this edge, so the candidates cover every
    # entry >= tau (kernel.key_bin_edge docstring).
    tau = pk.key_bin_edge(kth) if hist else kth
    if producer_ok is None:                  # xla strategy witness
        # a bucket can hide a missed entry only if one of its rows
        # saturated its W candidate slots at or above the selection
        # threshold (the global tau)
        producer_ok = valid
        for full_cover, row_min in witnesses:
            ok_b = full_cover | (jnp.max(row_min) < tau)
            producer_ok = jnp.logical_and(producer_ok, ok_b)
    ok = producer_ok & valid
    if regtopk and not hist:
        # Boundary ties among compacted candidates resolve exactly like the
        # reference (candidate position order == global index order). The
        # one exception: a tie involving a corrected SUPPORT key (appended
        # last, out of index order) with more ties than slots — fallback.
        # (Histogram selection has no exact-parity contract: every tie at
        # tau is either wholly selected or cut at the fixed capacity.)
        n_gt = jnp.sum((allk > kth).astype(jnp.int32))
        n_eq = jnp.sum((allk == kth).astype(jnp.int32))
        support_tie = jnp.any(skey == kth)
        ok = ok & ((n_eq == (k - n_gt)) | ~support_tie)

    def _fallback_keys():
        # adversarial-input escape hatch: recompute (a, keys) from the
        # *function parameters* rather than capturing the intermediate
        # `a` — XLA CPU copies non-parameter conditional operands, which
        # would tax the fast path with an O(J) copy
        a2, score2, _ = _sweep1_xla(kind, g, err_prev, c,
                                    momentum=momentum, mom=mom)
        keys_d = jnp.abs(score2)
        if regtopk:
            base = bigvec.gather(keys_d, idx_prev)
            live = step > 0
            if support_valid is not None:
                live = live & support_valid
                # inert pad slots alias index 0: sentinel + drop
                # (bigvec.live_idx docstring)
                idx_w = bigvec.live_idx(idx_prev, support_valid, j)
            else:
                idx_w = idx_prev
            fix = jnp.where(live, skey, base)
            keys_d = bigvec.scatter_set(keys_d, idx_w, fix, mode="drop")
        return keys_d

    if hist:
        def _fast(_):
            return idx_fast, val_fast, tv >= tau, tau

        def _fallback(_):
            keys_d = _fallback_keys()
            from repro.core import select
            idx_d = select.topk_indices(keys_d, kcap)
            tvd = bigvec.gather(keys_d, idx_d)
            tau_d = pk.key_bin_edge(tvd[k - 1])
            return idx_d, _gather_inputs(idx_d), tvd >= tau_d, tau_d

        idx_k, vraw, valid_sel, tau = jax.lax.cond(ok, _fast, _fallback,
                                                   operand=None)
        values = jnp.where(valid_sel, vraw, 0.0)
        idx_k = jnp.where(valid_sel, idx_k, 0).astype(jnp.uint32)
        count = jnp.sum(valid_sel.astype(jnp.int32))
        # inert pad slots must never zero a live entry's error feedback:
        # sentinel + drop for the O(k) state scatters (bigvec.live_idx)
        idx_w = bigvec.live_idx(idx_k, valid_sel, j)
        ghat = None
        if want_ghat:
            # scatter-ADD: a pad's (0, 0.0) never clobbers index 0
            ghat = bigvec.scatter_add(jnp.zeros((j,), jnp.float32),
                                      idx_k, values)
    else:
        def _fast(_):
            return idx_fast, val_fast

        def _fallback(_):
            from repro.core import select
            idx_d = select.topk_indices(_fallback_keys(), k)
            return idx_d, _gather_inputs(idx_d)

        idx_k, values = jax.lax.cond(ok, _fast, _fallback, operand=None)
        count = jnp.asarray(k, jnp.int32)
        tau = None
        idx_w = idx_k                        # exact: all k slots live
        ghat = None
        if want_ghat:
            ghat = bigvec.scatter_set(jnp.zeros((j,), jnp.float32),
                                      idx_k, values)
    # --- O(k) state writes ---------------------------------------------
    # err^{t+1} = a * (1 - s): zero the selected slots of a in place —
    # the ONLY J-sized state, written by an O(k) scatter (the third
    # O(J) traversal of the old (a_prev, s_prev) layout is gone). The
    # ef_dtype cast happens BEFORE the scatter so bf16 state fuses into
    # the sweep-1 stream instead of adding a post-scatter convert pass.
    dt = jnp.dtype(ef_dtype)
    err = bigvec.scatter_set(a.astype(dt), idx_w, 0.0, mode="drop")
    if kind == "dgc":
        # momentum masking mom * (1 - s), same O(k) scatter-zero
        mom_out = bigvec.scatter_set(mom_out.astype(dt), idx_w, 0.0,
                                     mode="drop")
    return {"err": err, "values": values,
            "indices": idx_k.astype(jnp.uint32), "ghat": ghat,
            "mom": mom_out, "count": count, "tau": tau}
