"""Jit-friendly entry points for the two-sweep fused compression pipeline.

``fused_compress_arrays`` runs the whole compression step for one worker:

    sweep 1:  a, score           (dense inputs read exactly once)
    sweep 2:  candidate slots    (per-row/per-block top candidates)
    O(cand):  exact-k trim, REGTOP-k posterior corrections, exactness
              checks, fixed-k (values, indices), optional dense ghat,
              and the O(k) scatter-zero that writes the next step's
              err state in place (DESIGN.md §2.2)

The step is **two O(J) traversals end to end** on the sparse-comm path:
the only J-sized state is ``err_prev`` (= a^{t-1} * (1 - s^{t-1}),
maintained by zeroing the k selected slots of ``a`` after the trim), so
no dense mask is ever written and sweep 1 reads exactly one state
vector. Dense masks, when a caller needs one, are reconstructed from
the packed indices (``core.sparsify.dense_mask``, O(k)).

With ``num_buckets > 1`` (DESIGN.md §2.4) the flat gradient is
partitioned into contiguous buckets (core.flatten.bucket_bounds); both
sweeps run per bucket and the per-bucket bit-pattern histograms are
merged (O(num_buckets x BINS)) into ONE global threshold, so the union
of per-bucket candidate selections still covers the exact global top-k.
The O(cand) trim stays global — selected support and packed order are
bit-identical to the flat (num_buckets=1) path. NB: because the trim
(and its lax.cond fallback) joins all buckets, the packed pairs exist
only after every bucket's sweeps finish; the overlap the bucketing buys
is on the COMMUNICATION side (core.aggregate chunks the packed pairs so
gather b+1 runs concurrently with scatter-add b), not compression
hidden behind collectives.

With ``allocation != "global"`` (DESIGN.md §2.6) the sweeps run per
SEGMENT (the allocation partition — layer-aligned when the caller
passes TreeFlattener bounds) instead of per bucket, each segment gets
its own threshold/provisioning sized for its cap, and the global trim
becomes per-segment trims + one O(sum(caps)) pack; sum(k_l) == k keeps
the packed output exactly k pairs. Bucketing continues to govern only
the comm-side chunking of those pairs (core.aggregate).

With ``g_segments``/``stream_bounds`` (backward-overlapped streaming,
DESIGN.md §2.8) the gradient arrives as per-segment arrays instead of
one flat vector, and the sweeps partition by the stream bounds: each
segment's sweep-1 (EF fold, score, histogram/statistics) depends only
on its own segment, so XLA schedules it as soon as the backward pass
emits that segment's leaves; the trim/pack is the only cross-segment
join. The same partition-invariance that makes bucketing bit-identical
makes streaming bit-identical — and S partial sweeps of J/S elements
still audit as the same 2 traversals (the streaming reorders WHEN
sweeps run, not how many).

The execution strategy is auto-selected from the JAX backend (the
"interpret or not" decision the old kernels hardcoded): native Pallas
kernels on TPU, fusion-friendly XLA lowering elsewhere, and
``pallas_interpret`` for validating the kernel bodies in tests.

Exactness: the compacted candidate set provably covers the true top-k
unless the per-row/per-block witnesses say otherwise (or a boundary tie
is ambiguous under REGTOP-k support corrections); those rare cases take
a ``lax.cond`` fallback to a full ``lax.top_k`` with identical
semantics. Fast path and fallback both reproduce the reference
selector's tie-break support exactly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.flatten import bucket_bounds
from repro.core.numerics import safe_denom
from repro.kernels.common import auto_interpret
from repro.kernels.compress import kernel as pk
from repro.kernels.compress import xla as px
from repro.kernels.compress.dispatch import hist_capacity


def default_strategy() -> str:
    return "xla" if auto_interpret() else "pallas"


def sweep_plan(pipeline: str, comm_mode: str = "sparse") -> dict:
    """Analytic O(J) HBM-traversal plan per compress step (DESIGN.md §2.2).

    A "pass" is a full J-sized streaming read or write. O(k) scatters and
    gathers (mask/ghat/packing fix-ups) are not passes. Bucketing does
    not change the plan: num_buckets partial sweeps of J/num_buckets
    elements are one J-equivalent traversal (the audit weights them
    fractionally, DESIGN.md §2.3). Density allocation doesn't either:
    per-segment partial sweeps weight the same way, and the allocated
    trim/pack/statistics are all O(sum(caps)) ~ O(k)
    (tests/test_allocate.py::TestAllocatedSweepCount).
    """
    if pipeline == "reference":
        # score chain reads (g, err, a_prev, g_agg_prev, s_prev) + writes
        # (a, score) + step-0 where pass + two full |score| sorts + mask
        # scatter + ghat/err pass: ~8 traversals, 2 O(J log k) sorts.
        return {"o_j_passes": 8, "full_sorts": 2}
    # fused: sweep 1 (one elementwise stream) + sweep 2 (candidate
    # compaction). State updates (err scatter-zero, mom masking, packed
    # pairs, mask reconstruction) are all O(k) — no third traversal.
    passes = 2 if comm_mode == "sparse" else 3   # +1: dense ghat write
    return {"o_j_passes": passes, "full_sorts": 0}


def _posterior_keys(a_sel, a_prev_sel, g_prev_sel, step, *,
                    omega, mu, support_valid=None):
    """|score| of the support entries (Algorithm 1 line 5, O(k)).

    ``a_sel`` is the error-compensated gradient AT the support indices.
    The production call site gathers it from the dense ``a`` buffer
    BEFORE the trim's lax.cond (a pre-cond read keeps the final err
    scatter-zero in-place); the fallback branch recomputes it from the
    function parameters (``_gather_inputs``). ``support_valid`` masks
    inert pad slots of the histogram selector's fixed-capacity support
    state (slots >= nsel_prev point at index 0 and must not contribute
    a corrected key)."""
    safe = safe_denom(omega * a_sel)
    delta_sel = (g_prev_sel - omega * a_prev_sel) / safe
    skey = jnp.abs(a_sel * jnp.tanh(jnp.abs(1.0 + delta_sel) / mu))
    skey = jnp.where(step == 0, -jnp.inf, skey)
    if support_valid is not None:
        skey = jnp.where(support_valid, skey, -jnp.inf)
    return skey


def _scalar_select(pred, x, y):
    """``where(pred, x, y)`` for a SCALAR predicate, emitted as a plain
    ``select_n`` over an explicit broadcast. ``jnp.where`` traces as a
    nested pjit call, and the traversal audit (audit.py) breaks fusion
    groups at call boundaries — a where on a J-sized array would bill a
    spurious traversal + escape write. lax primitives stay inline and
    fuse into the surrounding elementwise group."""
    x = jnp.asarray(x)
    y = jnp.asarray(y, x.dtype)
    if y.shape != x.shape:
        y = jax.lax.broadcast_in_dim(y, x.shape, ())
    p = jax.lax.broadcast_in_dim(
        jnp.asarray(pred, jnp.bool_).reshape(()), x.shape, ())
    return jax.lax.select(p, x, y)


def _decayed_err(err_prev, pf, err_decay):
    """``where(p, err, err_decay * err)`` — the EF-decay half of
    ``masked_inputs``, factored out so the streaming path (DESIGN.md
    §2.8, no flat ``g`` to mask) applies the bitwise-identical select
    to the flat state while masking ``g`` per segment."""
    return _scalar_select(
        pf, err_prev,
        (jnp.float32(err_decay) * err_prev.astype(jnp.float32)
         ).astype(err_prev.dtype))


def masked_inputs(g, err_prev, participate, err_decay):
    """Effective sweep-1 inputs under elastic participation (DESIGN.md
    §2.7): ``g_eff = where(p, g, 0)`` and ``err_eff = where(p, err,
    err_decay * err)``. With these as the step's inputs, a sitting-out
    worker's accumulator is ``a = err_decay * err`` — which the skipped
    (sentinel-routed) err scatter-zero then stores verbatim as the next
    err_prev, implementing the EF decay WITHOUT a third traversal: the
    wheres are elementwise with a scalar predicate, so they fuse into
    sweep 1's existing read group, and for a participating worker
    (p=True) both selects pass the original arrays through bitwise.
    The decay multiply is fp32 in-register (bf16 EF state rounds once,
    like every other sweep write). Shared verbatim by the fused pipeline
    and the reference oracle so their post-step states stay
    bit-comparable. Returns (g_eff, err_eff, p_bool)."""
    pf = jnp.asarray(participate, jnp.bool_)
    g_eff = _scalar_select(pf, g, jnp.zeros_like(g))
    return g_eff, _decayed_err(err_prev, pf, err_decay), pf


def _sweep1_xla(kind, g, err_prev, c, *, momentum, mom, gate=None):
    err = err_prev.astype(jnp.float32)               # ONE state read
    g = g.astype(jnp.float32)
    mom_out = mom
    if kind == "dgc":
        mom_out = momentum * mom.astype(jnp.float32) + g
        # elastic gate (DESIGN.md §2.7): a sitting-out worker must keep
        # a = err_eff (so err decays in place) while mom_out still
        # advances to momentum * mom (its g contribution is already
        # masked to zero) — input masking alone cannot remove the
        # momentum term from ``a``, hence the scalar select (fuses into
        # the same elementwise group; gate=True is a bitwise pass-through)
        am = mom_out if gate is None else _scalar_select(gate, mom_out, 0.0)
        a = err + am
    else:
        a = err + g
    return a, a * c, mom_out


def _sweep1_slice(kind, g_s, err_s, c, *, momentum, mom_s,
                  interpret, gate=None):
    """One padded-slice sweep-1 launch over PRE-SLICED inputs, shared by
    the bucketed global path, the allocated per-segment path, and the
    streaming path (whose ``g_s`` arrives as a standalone segment array
    rather than a view of a flat vector — slicing happens at the call
    site so both forms share this launch verbatim). Returns
    (a (size,), score_padded, mom (size,)|None, hist) with the bin-0
    padding contribution already corrected out of the histogram.
    ``gate`` is the elastic participation scalar for mode="dgc"
    (kernel-side a = err + gate * mom select; None for the ungated
    kernel)."""
    dgc = kind == "dgc"
    size = g_s.shape[0]
    j_pad = -(-size // pk.BLOCK) * pk.BLOCK
    pad = lambda x: jnp.pad(x.astype(jnp.float32), (0, j_pad - size))
    a_p, score_p, mom_p, _amax, hist = pk.sweep1_pallas(
        pad(g_s), pad(err_s), c,
        mode=("dgc" if dgc else "plain"), momentum=momentum,
        mom=None if mom_s is None else pad(mom_s),
        gate=gate if dgc else None, interpret=interpret)
    # padding contributed (j_pad - size) zero keys to bin 0
    return (a_p[:size], score_p, mom_p[:size] if dgc else None,
            hist.at[0].add(-(j_pad - size)))


def _sweep2_slice(score_p, tau, off, size, maxpb: int, interpret):
    """One slice sweep-2 compaction (shared like _sweep1_slice): kills
    slice-local padding slots BEFORE the global-offset shift (they must
    not alias the next slice's index range) and reports ok iff no block
    overflowed its maxpb candidate slots. Returns (cand_keys,
    cand_idx_global, ok)."""
    _mask_t, ck, ci, cnts = pk.sweep2_pallas(
        score_p, tau, maxpb=maxpb, interpret=interpret, want_mask=False)
    ck = jnp.where(ci < size, ck, -jnp.inf)
    return ck, ci + jnp.uint32(off), jnp.max(cnts) <= maxpb


def _candidates_pallas(kind, g, err_prev, c, step, *, k: int,
                       regtopk: bool, momentum: float, mom, interpret: bool,
                       bounds, gate=None, g_segments=None):
    """Per-bucket Pallas sweeps + histogram-merge global threshold.

    Sweep 1 runs once per bucket and emits that bucket's 2048-bin
    bit-pattern histogram; the merged histogram picks a single global
    tau (count(|score| >= tau) >= k + margin over the WHOLE vector, so
    per-bucket >=tau compaction unions to a global-top-k cover). Sweep 2
    then compacts each bucket independently against that shared tau.

    ``g_segments`` (streaming, DESIGN.md §2.8): per-``bounds`` gradient
    segments in place of the flat ``g`` — each slot's sweep-1 then
    depends only on its own segment array (the backward pass can still
    be producing the others), and the histogram merge is the first
    cross-segment join. Selection is partition-invariant, so the output
    is bit-identical either way.
    """
    j = err_prev.shape[0]
    dgc = kind == "dgc"
    a_parts, score_parts, mom_parts, hists = [], [], [], []
    for pos, (off, size) in enumerate(bounds):
        g_s = (g_segments[pos] if g_segments is not None
               else g[off:off + size])
        a_p, score_p, mom_p, hist = _sweep1_slice(
            kind, g_s, err_prev[off:off + size], c, momentum=momentum,
            mom_s=None if mom is None else mom[off:off + size],
            interpret=interpret, gate=gate)
        hists.append(hist)
        a_parts.append(a_p)
        score_parts.append(score_p)
        if dgc:
            mom_parts.append(mom_p)
    # margin k: REGTOP-k support corrections may drop <=k entries below
    # tau without breaking top-k coverage of the candidates
    target = k + jnp.where(jnp.logical_and(regtopk, step > 0), k, 0)
    tau = pk.threshold_from_bucket_hists(hists, target)
    # per-block slot capacity from the GLOBAL selection density (a bucket
    # block's expected candidate share does not depend on the bucketing)
    maxpb = int(min(pk.BLOCK, max(32, -(-8 * k * pk.BLOCK // j))))
    ck_parts, ci_parts, oks = [], [], []
    for (off, size), score_p in zip(bounds, score_parts):
        ck, ci, ok_b = _sweep2_slice(score_p, tau, off, size, maxpb,
                                     interpret)
        ci_parts.append(ci)
        ck_parts.append(ck)
        oks.append(ok_b)
    producer_ok = oks[0]
    for ok_b in oks[1:]:
        producer_ok = jnp.logical_and(producer_ok, ok_b)
    a = a_parts[0] if len(bounds) == 1 else jnp.concatenate(a_parts)
    mom_out = None
    if dgc:
        mom_out = (mom_parts[0] if len(bounds) == 1
                   else jnp.concatenate(mom_parts))
    cand_k = ck_parts[0] if len(bounds) == 1 else jnp.concatenate(ck_parts)
    cand_i = ci_parts[0] if len(bounds) == 1 else jnp.concatenate(ci_parts)
    return a, mom_out, cand_k, cand_i, producer_ok


def _candidates_xla(kind, g, err_prev, c, *, k: int, momentum: float,
                    mom, bounds, gate=None, g_segments=None):
    """Per-bucket XLA candidate compaction.

    Sweep 1 is one fused elementwise pass over the whole vector (XLA
    fuses across bucket slices anyway); sweep 2's per-row top-W
    compaction runs per bucket so each bucket's candidate chain is
    independent. Returns per-bucket (full_cover, row_min) witnesses —
    the exactness check needs the global tau_k, known only after the
    trim. Candidate order stays global-index-ascending across buckets,
    preserving the flat path's tie-break semantics bit-for-bit.

    ``g_segments`` (streaming, DESIGN.md §2.8): sweep 1 runs per
    segment over the standalone segment arrays instead, so the WHOLE
    per-segment chain (sweep-1 + compaction — no shared threshold on
    this strategy) depends only on that segment's gradient; the first
    cross-segment join is the trim. Elementwise math commutes with the
    partition, so ``a`` (concatenated) and every candidate key are
    bitwise identical to the flat pass.
    """
    j = err_prev.shape[0]
    if g_segments is None:
        a, score, mom_out = _sweep1_xla(kind, g, err_prev, c,
                                        momentum=momentum, mom=mom,
                                        gate=gate)
        keys = jnp.abs(score)
        key_parts = [keys[off:off + size] for off, size in bounds]
    else:
        a_parts, key_parts, mom_parts = [], [], []
        for pos, (off, size) in enumerate(bounds):
            a_p, score_p, mom_p = _sweep1_xla(
                kind, g_segments[pos], err_prev[off:off + size], c,
                momentum=momentum,
                mom=None if mom is None else mom[off:off + size],
                gate=gate)
            a_parts.append(a_p)
            key_parts.append(jnp.abs(score_p))
            mom_parts.append(mom_p)
        a = a_parts[0] if len(bounds) == 1 else jnp.concatenate(a_parts)
        mom_out = None
        if kind == "dgc":
            mom_out = (mom_parts[0] if len(bounds) == 1
                       else jnp.concatenate(mom_parts))
    if kind != "dgc":
        mom_out = None
    ck_parts, ci_parts, witnesses = [], [], []
    for (off, size), key_s in zip(bounds, key_parts):
        kb = px.pad_keys(key_s)
        # density over the GLOBAL j: a bucket's rows are provisioned
        # exactly like the flat path's (witness + fallback cover
        # concentration), so bucketing adds no candidate-slot cost
        cv, ci, row_min, full_cover = px.candidates_xla(
            kb, k, density_len=(j if len(bounds) > 1 else 0))
        ck_parts.append(cv)
        ci_parts.append(ci + jnp.uint32(off))
        witnesses.append((full_cover, row_min))
    cand_k = ck_parts[0] if len(bounds) == 1 else jnp.concatenate(ck_parts)
    cand_i = ci_parts[0] if len(bounds) == 1 else jnp.concatenate(ci_parts)
    return a, mom_out, cand_k, cand_i, witnesses


def _fused_randk(g, err_prev, *, k: int, key, want_ghat: bool,
                 ef_dtype, allocation: str = "global",
                 seg_bounds=None, pf=None, g_segments=None,
                 stream_bounds=None) -> dict:
    """Fused RANDOM-k: selection is score-free, so the whole step is ONE
    elementwise sweep (the err_prev + g stream) plus O(k) random gathers
    and the O(k) scatter-zero state write — no sweep 2, no histogram, no
    trim. The elementwise form is optimal on every backend (XLA fuses
    it; a Pallas grid would add nothing), so all strategies share it.
    Index stream is identical to the reference randk's (both call
    select.randk_indices — or, for allocation != "global", the shared
    per-segment sampler allocate.randk_allocated_indices — on the same
    key). Allocated randk draws a uniform k_l-subset per segment with
    the PROPORTIONAL counts (score-free selection has no statistic for
    "adaptive" to adapt to; the degrade is documented, DESIGN.md §2.6)."""
    from repro.core import bigvec
    from repro.core.select import randk_indices
    assert key is not None, "randk needs a PRNG key"
    j = err_prev.shape[0]
    if g_segments is not None:
        # streaming: the one elementwise sweep runs per segment (err + g
        # commutes with the partition bitwise); index sampling is
        # selection-score-free, so nothing else changes
        a_parts = [
            _sweep1_xla("randk", g_segments[pos],
                        err_prev[off:off + size], jnp.float32(1.0),
                        momentum=0.0, mom=None)[0]
            for pos, (off, size) in enumerate(stream_bounds)]
        a = (a_parts[0] if len(a_parts) == 1
             else jnp.concatenate(a_parts))
    else:
        a, _, _ = _sweep1_xla("randk", g, err_prev, jnp.float32(1.0),
                              momentum=0.0, mom=None)
    if allocation != "global":
        from repro.core import allocate
        bounds = seg_bounds or allocate.segment_bounds(
            j, allocate.DEFAULT_SEGMENTS)
        counts = allocate.proportional_counts(k, [sz for _, sz in bounds])
        idx = allocate.randk_allocated_indices(key, bounds, counts)
    else:
        idx = randk_indices(key, j, k)
    # gather before the scatter-zero: a's buffer is read-complete when
    # the O(k) state write runs, so it updates in place
    values = bigvec.gather(a, idx)
    count = jnp.asarray(k, jnp.int32)
    if pf is None:
        err = bigvec.scatter_set(a.astype(jnp.dtype(ef_dtype)), idx, 0.0)
    else:
        # elastic: a sitting-out worker keeps err = a (= decayed err —
        # inputs are pre-masked) and ships an inert payload
        err = bigvec.scatter_set(a.astype(jnp.dtype(ef_dtype)),
                                 bigvec.live_idx(idx, pf, j), 0.0,
                                 mode="drop")
        values = jnp.where(pf, values, 0.0)
        idx = jnp.where(pf, idx, jnp.zeros_like(idx))
        count = jnp.where(pf, count, 0)
    ghat = None
    if want_ghat:
        ghat = bigvec.scatter_set(jnp.zeros((j,), jnp.float32), idx, values)
    return {"err": err, "values": values, "indices": idx,
            "ghat": ghat, "mom": None, "count": count,
            "tau": None}


def fused_sketch_encode(g, err_prev, *, rows: int, width: int,
                        strategy: Optional[str] = None,
                        participate=None, err_decay: float = 1.0) -> dict:
    """Sweep 1 with the CountSketch ENCODE folded in (DESIGN.md §2.9).

    The sketch-coordinated path (kind="sketchtopk") has no per-worker
    selection — the shared mask is decoded from the all-reduced sketch
    at the aggregate level — so its per-worker compress unit is exactly
    this: accumulate a = err_prev + g and encode it into a (rows, width)
    CountSketch, bit-identical to core.sketch.encode. Returns
    {"a": (J,) fp32, "sketch": (rows, width) fp32}.

    Budget (audit.py absolutes, pinned in tests/test_sketch.py):

    - strategy="pallas": ONE combined kernel emits a and the sketch in a
      single pass — 1.0 traversal, 1.0 J-sized write.
    - strategy="xla": the elementwise a-stream (XLA-fused) plus a
      dedicated encode kernel reading a once — 2.0 traversals, 1.0
      J-sized write. The kernel route is load-bearing: an XLA
      ``.at[h].add`` encode bills one extra traversal PER ROW (rows
      scatter barriers), and the legacy vmap encode materializes
      (rows, J) hash/sign intermediates; both blow the 2.0 budget.

    The (rows, width) sketch output is below the audit's sizable floor
    at every bench shape (width ~ 4k << J/16), so the encode adds no
    write units. ``participate`` applies the standard elastic input
    masking (masked_inputs): a sitting-out worker encodes its decayed
    error feedback — the aggregate zeroes its sketch before the
    all-reduce, this just keeps the EF stream bit-comparable.
    """
    from repro.core import sketch as core_sketch
    strategy = strategy or default_strategy()
    if participate is not None:
        g, err_prev, _pf = masked_inputs(g, err_prev, participate,
                                         err_decay)
    mults = tuple(int(x) for x in core_sketch._MULTS[:rows])
    adds = tuple(int(x) for x in core_sketch._ADDS[:rows])
    if strategy in ("pallas", "pallas_interpret"):
        a, sk = pk.sweep1_sketch_pallas(
            g, err_prev, rows=rows, width=width, mults=mults, adds=adds,
            interpret=strategy != "pallas")
    elif strategy == "xla":
        a = err_prev.astype(jnp.float32) + g.astype(jnp.float32)
        sk = pk.sketch_encode_pallas(a, rows=rows, width=width,
                                     mults=mults, adds=adds,
                                     interpret=True)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return {"a": a, "sketch": sk}


def _seg_candidates_pallas(kind, g, err_prev, c, step, *, provs, k: int,
                           regtopk: bool, momentum: float, mom,
                           interpret: bool, bounds, gate=None,
                           g_segments=None):
    """Per-SEGMENT Pallas sweeps for allocation != "global" (DESIGN.md
    §2.6): unlike the bucketed global path (one merged-histogram tau),
    each segment's sweep-1 histogram picks its OWN threshold at target
    provs[l] (the segment's provisioning budget — its static count for
    proportional, its cap for adaptive — plus the REGTOP-k
    support-correction margin), so the segment's candidates cover its
    own top-provs[l] regardless of other segments' magnitudes — the
    coverage the per-segment trim needs. Candidate parts stay SEPARATE
    (the trim is per segment). Returns (a, mom_out, ck_parts, ci_parts,
    ok_parts)."""
    dgc = kind == "dgc"
    a_parts, mom_parts = [], []
    ck_parts, ci_parts, ok_parts = [], [], []
    for pos, (off, size) in enumerate(bounds):
        g_s = (g_segments[pos] if g_segments is not None
               else g[off:off + size])
        a_p, score_p, mom_p, hist = _sweep1_slice(
            kind, g_s, err_prev[off:off + size], c, momentum=momentum,
            mom_s=None if mom is None else mom[off:off + size],
            interpret=interpret, gate=gate)
        # support corrections may drop <= min(k, size) in-segment entries
        # below tau without breaking coverage of the segment's top-prov
        target = provs[pos] + jnp.where(
            jnp.logical_and(regtopk, step > 0), int(min(k, size)), 0)
        tau = pk.threshold_from_hist(hist, target)
        maxpb = int(min(pk.BLOCK,
                        max(32, -(-8 * provs[pos] * pk.BLOCK // size))))
        ck, ci, ok_b = _sweep2_slice(score_p, tau, off, size, maxpb,
                                     interpret)
        ck_parts.append(ck)
        ci_parts.append(ci)
        ok_parts.append(ok_b)
        a_parts.append(a_p)
        if dgc:
            mom_parts.append(mom_p)
    a = a_parts[0] if len(bounds) == 1 else jnp.concatenate(a_parts)
    mom_out = None
    if dgc:
        mom_out = (mom_parts[0] if len(bounds) == 1
                   else jnp.concatenate(mom_parts))
    return a, mom_out, ck_parts, ci_parts, ok_parts


def _seg_candidates_xla(kind, g, err_prev, c, *, provs, slack, momentum,
                        mom, bounds, gate=None, g_segments=None):
    """Per-SEGMENT XLA candidate compaction for allocation != "global":
    sweep 1 stays one fused elementwise pass; each segment's per-row
    top-W compaction is provisioned for ITS budget (provs[l] over the
    segment length — per-segment density, not global): the static
    counts for proportional (the realized selection, same 4x slack as
    the global path), the cap for adaptive (an adaptive segment may
    hold up to cap_l of the budget however the other segments score —
    at reduced slack, since the cap already embeds the clip headroom).
    Candidate parts stay separate; per-segment (full_cover, row_min)
    witnesses are checked against the segment's OWN realized threshold
    in the trim."""
    if g_segments is None:
        a, score, mom_out = _sweep1_xla(kind, g, err_prev, c,
                                        momentum=momentum, mom=mom,
                                        gate=gate)
        keys = jnp.abs(score)
        key_parts = [keys[off:off + size] for off, size in bounds]
    else:
        # streaming: sweep 1 per segment (bitwise — elementwise math
        # commutes with the partition); the candidate chain below is
        # already per segment, so each segment's whole compression chain
        # depends only on its own gradient array
        a_parts, key_parts, mom_parts = [], [], []
        for pos, (off, size) in enumerate(bounds):
            a_p, score_p, mom_p = _sweep1_xla(
                kind, g_segments[pos], err_prev[off:off + size], c,
                momentum=momentum,
                mom=None if mom is None else mom[off:off + size],
                gate=gate)
            a_parts.append(a_p)
            key_parts.append(jnp.abs(score_p))
            mom_parts.append(mom_p)
        a = a_parts[0] if len(bounds) == 1 else jnp.concatenate(a_parts)
        mom_out = (None if kind != "dgc" else
                   (mom_parts[0] if len(bounds) == 1
                    else jnp.concatenate(mom_parts)))
    if kind != "dgc":
        mom_out = None
    ck_parts, ci_parts, wit_parts = [], [], []
    for pos, (off, size) in enumerate(bounds):
        kb = px.pad_keys(key_parts[pos])
        cv, ci, row_min, full_cover = px.candidates_xla(kb, provs[pos],
                                                        slack=slack)
        ck_parts.append(cv)
        ci_parts.append(ci + jnp.uint32(off))
        wit_parts.append((full_cover, row_min))
    return a, mom_out, ck_parts, ci_parts, wit_parts


def _fused_allocated(kind, g, err_prev, step, *, k: int, omega, mu, Q,
                     momentum, mom, idx_prev, a_prev_sel, g_prev_sel,
                     want_ghat: bool, strategy: str, allocation: str,
                     seg_bounds, ef_dtype, gate=None, pf=None,
                     g_segments=None) -> dict:
    """Fused compress step with per-segment budget allocation
    (allocation in {"proportional", "adaptive"}, DESIGN.md §2.6).

    Same two-sweep structure and O(k) state tail as the global exact
    path; what changes is the trim: the global O(cand) exact-k trim is
    replaced by PER-SEGMENT trims (top-cap_l candidates ranked, leading
    k_l live) plus one O(sum(caps)) pack that keeps the output at
    exactly k (values, indices) pairs — sum(k_l) == k, so the packed
    wire format (and sparse-comm bytes) is unchanged. Adaptive k_l
    comes from per-segment top-mass statistics of the CORRECTED ranked
    candidate pool (support corrections applied first, so the sums
    equal allocate.dense_segment_moments bitwise when the covers hold;
    O(segments * cap log cap), no extra O(J) traversal — audit-gated at
    2.0 sweeps). Exactness witnesses are per segment (coverage vs the
    segment's own realized threshold — and, for adaptive, vs the ranked
    top-cap the statistics were summed over — REGTOP-k boundary-tie
    ambiguity, candidate-capacity overflow); any failure takes the
    lax.cond fallback to dense per-segment selection with identical
    semantics, INCLUDING densely recomputed adaptive counts — the
    fallback branch IS the reference pipeline's allocated selector
    (allocate.reference_allocated_select), which is what
    tests/test_allocate.py::TestAllocatedParity (incl. the regtopk
    stress seeds) pins."""
    from repro.core import allocate, bigvec
    j = err_prev.shape[0]
    bounds = seg_bounds or allocate.segment_bounds(
        j, allocate.DEFAULT_SEGMENTS)
    if g_segments is not None:
        # streaming requires the stream partition == the allocation
        # partition (sparsify routes both off the same resolved bounds)
        assert len(g_segments) == len(bounds), (len(g_segments),
                                                len(bounds))
    sizes = [sz for _, sz in bounds]
    caps = allocate.segment_caps(k, sizes)
    # candidate provisioning per segment: proportional realizes its
    # STATIC counts, so provision exactly those at the global path's 4x
    # row slack; adaptive may tilt any segment up to its cap, so
    # provision the cap — at 2x slack, since the cap already embeds the
    # ADAPTIVE_CLIP**2 headroom over the typically-realized count (the
    # row_min witness + fallback still guard adversarial concentration)
    if allocation == "proportional":
        counts_static = allocate.proportional_counts(k, sizes)
        provs = [max(1, ci) for ci in counts_static]
        trim_caps = provs
        slack = 4.0
    else:
        counts_static = None
        provs = caps
        trim_caps = caps
        slack = 2.0
    regtopk = kind == "regtopk"
    if regtopk:
        c = jnp.where(step == 0, jnp.float32(1.0),
                      jnp.tanh(jnp.abs(1.0 + jnp.float32(Q)) / mu))
    else:
        c = jnp.float32(1.0)

    if strategy in ("pallas", "pallas_interpret"):
        interpret = strategy == "pallas_interpret" or auto_interpret()
        a, mom_out, ck_parts, ci_parts, ok_parts = _seg_candidates_pallas(
            kind, g, err_prev, c, step, provs=provs, k=k, regtopk=regtopk,
            momentum=momentum, mom=mom, interpret=interpret, bounds=bounds,
            gate=gate, g_segments=g_segments)
        wit_parts = None
        ok = ok_parts[0]
        for ok_b in ok_parts[1:]:
            ok = jnp.logical_and(ok, ok_b)
    else:
        a, mom_out, ck_parts, ci_parts, wit_parts = _seg_candidates_xla(
            kind, g, err_prev, c, provs=provs, slack=slack,
            momentum=momentum, mom=mom, bounds=bounds, gate=gate,
            g_segments=g_segments)
        ok = jnp.asarray(True)

    # REGTOP-k support corrections, candidate space, routed per segment:
    # disable support members' uncorrected candidate keys everywhere;
    # append every support entry to ITS segment with the corrected key
    # (masked -inf elsewhere). Done BEFORE the adaptive statistics —
    # they must see the CORRECTED pool, exactly like the dense oracle
    # (allocate.dense_segment_moments over the corrected score).
    skey = None
    if regtopk:
        skey = _posterior_keys(bigvec.gather(a, idx_prev), a_prev_sel,
                               g_prev_sel, step, omega=omega, mu=mu)
        idx_sorted = jnp.sort(idx_prev.astype(jnp.uint32))
        for pos in range(len(bounds)):
            ci_l = ci_parts[pos]
            p = jnp.minimum(jnp.searchsorted(idx_sorted, ci_l),
                            idx_sorted.shape[0] - 1)
            hit = (idx_sorted[p] == ci_l) & (step > 0)
            ck_parts[pos] = jnp.where(hit, -jnp.inf, ck_parts[pos])

    # phase A, per segment: corrected candidate pool, rank the
    # top-trim_cap_l (counts-independent), gather the signed a-values
    # BEFORE the cond (in-place err scatter), and — for adaptive — the
    # top-cap mass moments from the RANKED CORRECTED keys, which equal
    # allocate.dense_segment_moments bitwise whenever the cover holds
    # (same sorted values, same summation order)
    seg_trims, ms = [], []
    for pos, ((off, size), cap) in enumerate(zip(bounds, trim_caps)):
        allk, alli = ck_parts[pos], ci_parts[pos]
        if regtopk:
            in_seg = ((idx_prev >= jnp.uint32(off))
                      & (idx_prev < jnp.uint32(off + size)))
            allk = jnp.concatenate([allk,
                                    jnp.where(in_seg, skey, -jnp.inf)])
            alli = jnp.concatenate([alli, idx_prev.astype(jnp.uint32)])
        eff = max(1, int(min(cap, allk.shape[0])))
        tv, tsel = jax.lax.top_k(allk, eff)
        allv = bigvec.gather(a, jnp.minimum(alli, jnp.uint32(j - 1)))
        seg_trims.append((allk, tv, alli[tsel], allv[tsel], eff))
        if allocation == "adaptive":
            ms.append(jnp.sum(jnp.where(tv > -jnp.inf, tv * tv, 0.0)))
            if eff < cap:
                # ranked pool shorter than the statistic's window: the
                # top-cap mass cannot be complete — route to fallback
                ok = ok & jnp.asarray(False)
    if allocation == "adaptive":
        counts = allocate.adaptive_counts(k, sizes, jnp.stack(ms),
                                          caps=caps)
    else:
        counts = jnp.asarray(counts_static, jnp.int32)

    # phase B, per segment: leading counts[l] of the ranking are live;
    # witnesses guard the selection cover AND (adaptive) the statistic's
    # top-cap cover, so a truncated cover can never silently shift k_l
    pk_parts, pi_parts, pv_parts = [], [], []
    for pos, (allk, tv, isel, vsel, eff) in enumerate(seg_trims):
        kl = counts[pos]
        has = kl > 0
        live = jnp.arange(eff, dtype=jnp.int32) < kl
        kth = tv[jnp.clip(kl - 1, 0, eff - 1)]
        ok = ok & jnp.where(has, kth > -jnp.inf, True) & (kl <= eff)
        if wit_parts is not None:
            full_cover, row_min = wit_parts[pos]
            tau_l = jnp.where(has, kth, jnp.inf)
            if allocation == "adaptive":
                # stricter: no row may hide an entry that belongs in the
                # ranked top-eff the moments were summed over
                tau_l = jnp.minimum(tau_l, tv[eff - 1])
            ok = ok & (full_cover | (jnp.max(row_min) < tau_l))
        if regtopk:
            # boundary tie involving a corrected support key (appended
            # out of index order): same ambiguity rule as the global
            # exact trim, per segment
            n_gt = jnp.sum((allk > kth).astype(jnp.int32))
            n_eq = jnp.sum((allk == kth).astype(jnp.int32))
            support_tie = jnp.any(allk[-idx_prev.shape[0]:] == kth)
            ok = ok & jnp.where(has, (n_eq == (kl - n_gt)) | ~support_tie,
                                True)
        pk_parts.append(jnp.where(live, tv, -jnp.inf))
        pi_parts.append(isel)
        pv_parts.append(vsel)
    # pack: one O(sum(caps)) top-k over the live-masked union -> exactly
    # the sum(k_l) == k live entries, ordered by key desc (ties resolve
    # segment-major then index asc — allocated_select_dense's order)
    packk = jnp.concatenate(pk_parts)
    packi = jnp.concatenate(pi_parts)
    packv = jnp.concatenate(pv_parts)
    _tvg, sel = jax.lax.top_k(packk, k)
    idx_fast = packi[sel]
    val_fast = packv[sel]

    def _flat_g():
        # fallback-only: materialize the flat (effective) gradient — on
        # the streaming path it exists only as segment arrays, and the
        # concat must happen INSIDE the cond branch so the fast path
        # never pays it (cond audits as the min over branches)
        return g if g_segments is None else jnp.concatenate(g_segments)

    def _gather_inputs(idx):
        # fallback-only: recompute a[idx] from the function parameters
        # (bitwise identical; keeps `a` read-complete before the cond)
        gi = bigvec.gather(_flat_g(), idx).astype(jnp.float32)
        ei = bigvec.gather(err_prev, idx).astype(jnp.float32)
        if kind == "dgc":
            mi = momentum * bigvec.gather(mom, idx).astype(jnp.float32) + gi
            return ei + (mi if gate is None else jnp.where(gate, mi, 0.0))
        return ei + gi

    def _fast(_):
        return idx_fast, val_fast

    def _fallback(_):
        a2, score2, _ = _sweep1_xla(kind, _flat_g(), err_prev, c,
                                    momentum=momentum, mom=mom, gate=gate)
        keys_d = jnp.abs(score2)
        if regtopk:
            base = bigvec.gather(keys_d, idx_prev)
            fix = jnp.where(step > 0, skey, base)
            keys_d = bigvec.scatter_set(keys_d, idx_prev, fix, mode="drop")
        if allocation == "adaptive":
            # dense statistics, not the (witness-failed) candidate ones:
            # this branch IS the reference allocated selector, so fused
            # output equals the reference pipeline's even when covers
            # fail (tests/test_allocate.py::TestAllocatedParity stress)
            counts_d = allocate.adaptive_counts(
                k, sizes,
                allocate.dense_segment_moments(keys_d, bounds, caps),
                caps=caps)
        else:
            counts_d = counts
        idx_d, _kv = allocate.allocated_select_dense(keys_d, bounds, caps,
                                                     counts_d, k)
        return idx_d, _gather_inputs(idx_d)

    idx_k, values = jax.lax.cond(ok, _fast, _fallback, operand=None)
    # O(k) state tail, identical to the global exact path; under elastic
    # participation a sitting-out worker skips the scatter-zero (sentinel
    # + drop) so err/mom keep their decayed values, and the packed
    # payload is masked inert
    count = jnp.asarray(k, jnp.int32)
    idx_w = idx_k
    if pf is not None:
        idx_w = bigvec.live_idx(idx_k, pf, j)
        values = jnp.where(pf, values, 0.0)
        idx_k = jnp.where(pf, idx_k, jnp.zeros_like(idx_k))
        count = jnp.where(pf, count, 0)
    dt = jnp.dtype(ef_dtype)
    err = bigvec.scatter_set(a.astype(dt), idx_w, 0.0, mode="drop")
    if kind == "dgc":
        mom_out = bigvec.scatter_set(mom_out.astype(dt), idx_w, 0.0,
                                     mode="drop")
    ghat = None
    if want_ghat:
        ghat = bigvec.scatter_set(jnp.zeros((j,), jnp.float32),
                                  idx_k, values)
    return {"err": err, "values": values,
            "indices": idx_k.astype(jnp.uint32), "ghat": ghat,
            "mom": mom_out, "count": count,
            "tau": None}


def fused_compress_arrays(kind: str, g, err_prev, step, *, k: int,
                          omega=1.0, mu: float = 0.1, Q: float = 0.0,
                          momentum: float = 0.9, mom=None,
                          idx_prev=None, a_prev_sel=None, g_prev_sel=None,
                          nsel_prev=None, want_ghat: bool = True,
                          strategy: Optional[str] = None,
                          num_buckets: int = 1, selector: str = "exact",
                          ef_dtype="float32", key=None,
                          allocation: str = "global",
                          seg_bounds=None, participate=None,
                          err_decay: float = 1.0, g_segments=None,
                          stream_bounds=None) -> dict:
    """One fused compression step. kind in {"topk", "dgc", "regtopk",
    "randk", "thresholdk"} (thresholdk shares the plain-score path with
    topk; randk needs ``key`` and ignores ``selector``).

    Inputs: g (J,) raw gradient; err_prev (J,) the ONE J-sized state
    vector — the previous step's error feedback a^{t-1} * (1 - s^{t-1})
    (fp32 or bf16 per ``ef_dtype``; sweep math is always fp32
    in-register); step () int32. REGTOP-k additionally takes the O(k)
    posterior (idx_prev uint32, a_prev_sel, g_prev_sel; with
    selector="histogram" these are hist_capacity-sized and ``nsel_prev``
    marks how many leading slots are live) — the posterior's idx_prev
    doubles as the support set, so no dense mask exists anywhere in the
    state. DGC takes the momentum buffer ``mom``. ``num_buckets``
    partitions the sweeps into contiguous buckets (DESIGN.md §2.4);
    selection semantics are bucketing-invariant.

    Returns {"err", "values", "indices", "count", "tau", "ghat" (None
    unless want_ghat), "mom" (dgc only: the selection-masked momentum)}.
    ``err`` is the NEXT step's state — ``a`` with the selected slots
    zeroed by an O(k) scatter (bit-identical to the reference's
    a - mask*a), stored in ``ef_dtype``.

    - selector="exact": values/indices are the fixed-k packed pairs
      ordered by |score| descending; selected support is bit-identical
      to the reference exact selector's (and to the flat num_buckets=1
      path) for every num_buckets. count == k, tau is None.
    - selector="histogram": threshold selection at tau =
      key_bin_edge(k-th |score|) — the sweep-1 bit-pattern histogram
      threshold (DESIGN.md §2.5). values/indices are fixed
      hist_capacity(k, j)-sized; ``count`` in [k, capacity] entries are
      live, the tail is inert (value 0.0 at index 0). ``tau`` is the
      realized threshold.
    - allocation in {"proportional", "adaptive"} (DESIGN.md §2.6,
      exact selector only — allocate.check_allocation): the budget
      splits sum(k_l) == k over ``seg_bounds`` (static [(offset, size),
      ...]; near-equal DEFAULT_SEGMENTS cut when None) and the global
      trim becomes per-segment trims + one O(sum(caps)) pack — output
      shapes, the O(k) state tail, and the wire format are unchanged
      (still exactly k pairs).
    - participate (DESIGN.md §2.7): optional traced () bool — this
      worker's elastic participation bit. None (the default) is
      literally today's code path. With a mask, sweep 1 reads the
      masked effective inputs (g_eff = where(p, g, 0), err_eff =
      where(p, err, err_decay * err) — the wheres fuse, no extra
      traversal), a sitting-out worker's O(k) state scatters are
      sentinel-skipped (so err' = err_decay * err in place; DGC's
      mom' = momentum * mom via the kernel gate), and its packed
      payload comes back inert (values 0.0, indices 0, count 0).
      p=True is a bitwise pass-through of the unmasked path.
    - g_segments + stream_bounds (DESIGN.md §2.8): the gradient arrives
      as per-segment arrays (``g`` must be None) partitioned by the
      static ``stream_bounds`` [(offset, size), ...] — the streaming
      form the backward-overlapped train step feeds. Sweeps partition by
      stream_bounds instead of bucket_bounds, so each segment's sweep-1
      (+ EF fold + allocation statistics) depends only on its own
      segment array and can run while later segments are still being
      produced; the trim/pack is the only cross-segment join. Selection
      is partition-invariant (the bucketed-path theorem), so values/
      indices/err are BIT-identical to the flat call, and S partial
      sweeps of J/S elements still audit as 2 traversals. With
      allocation != "global", stream_bounds must equal the resolved
      ``seg_bounds``.
    """
    from repro.core import bigvec
    strategy = strategy or default_strategy()
    streaming = g_segments is not None
    if streaming:
        assert g is None, "streaming: pass g_segments, not a flat g"
        assert stream_bounds is not None and \
            len(stream_bounds) == len(g_segments)
        j = err_prev.shape[0]
    else:
        j = g.shape[0]
    k = int(min(k, j))
    # raw FUNCTION PARAMETERS, kept for the trim's lax.cond fallback:
    # the cond must consume these (not the produced masked arrays) or the
    # audit bills the masked intermediates as escaped cond-operand writes
    g_raw, err_raw = g, err_prev
    segs_raw = g_segments
    pf = gate = None
    if participate is not None:
        if streaming:
            # per-segment masking: a scalar-predicate select commutes
            # with the partition, so this matches masked_inputs bitwise
            pf = jnp.asarray(participate, jnp.bool_)
            g_segments = [_scalar_select(pf, gs, jnp.zeros_like(gs))
                          for gs in g_segments]
            err_prev = _decayed_err(err_prev, pf, err_decay)
        else:
            g, err_prev, pf = masked_inputs(g, err_prev, participate,
                                            err_decay)
        gate = pf                      # dgc: a = err_eff + where(p, mom, 0)
    if kind == "randk":
        return _fused_randk(g, err_prev, k=k, key=key,
                            want_ghat=want_ghat, ef_dtype=ef_dtype,
                            allocation=allocation, seg_bounds=seg_bounds,
                            pf=pf, g_segments=g_segments,
                            stream_bounds=stream_bounds)
    if allocation != "global":
        # exact-count selection only (check_allocation gates upstream)
        assert selector == "exact", (allocation, selector)
        return _fused_allocated(
            kind, g, err_prev, step, k=k, omega=omega, mu=mu, Q=Q,
            momentum=momentum, mom=mom, idx_prev=idx_prev,
            a_prev_sel=a_prev_sel, g_prev_sel=g_prev_sel,
            want_ghat=want_ghat, strategy=strategy, allocation=allocation,
            seg_bounds=seg_bounds, ef_dtype=ef_dtype, gate=gate, pf=pf,
            g_segments=g_segments)
    hist = selector == "histogram"
    # static packed capacity; also the candidate-provisioning budget —
    # for exact selection kcap == k and everything below degenerates to
    # the original exact-k trim
    kcap = hist_capacity(k, j) if hist else k
    # streaming partitions the sweeps by the stream segments; selection
    # is partition-invariant, and num_buckets keeps governing only the
    # comm-side chunking of the packed pairs (core.aggregate)
    bounds = stream_bounds if streaming else bucket_bounds(j, num_buckets)
    regtopk = kind == "regtopk"
    if regtopk:
        c = jnp.where(step == 0, jnp.float32(1.0),
                      jnp.tanh(jnp.abs(1.0 + jnp.float32(Q)) / mu))
    else:
        c = jnp.float32(1.0)

    if strategy in ("pallas", "pallas_interpret"):
        interpret = strategy == "pallas_interpret" or auto_interpret()
        a, mom_out, cand_k, cand_i, producer_ok = _candidates_pallas(
            kind, g, err_prev, c, step, k=kcap, regtopk=regtopk,
            momentum=momentum, mom=mom, interpret=interpret, bounds=bounds,
            gate=gate, g_segments=g_segments)
        witnesses = None
    else:
        a, mom_out, cand_k, cand_i, witnesses = _candidates_xla(
            kind, g, err_prev, c, k=kcap, momentum=momentum, mom=mom,
            bounds=bounds, gate=gate, g_segments=g_segments)
        producer_ok = None                   # needs tau; checked below

    # --- O(candidates) fixed-capacity trim ------------------------------
    def _raw_flat_g():
        # fallback-only: the RAW flat gradient — on the streaming path it
        # exists only as segment params, and the concat runs INSIDE the
        # cond branch so the fast path never pays it (min over branches)
        return g_raw if segs_raw is None else jnp.concatenate(segs_raw)

    def _gather_inputs(idx):
        """a[idx] recomputed from the step's INPUT arrays (bitwise
        identical: per-element adds commute with the gather). Used only
        inside the lax.cond fallback branch, whose operands are already
        the function parameters — gathering from the dense ``a`` there
        would extend a's liveness past the cond and force the err
        scatter-zero to copy the whole buffer. Elastic masking is
        re-applied to the gathered O(k) values (a scalar-predicate
        select commutes with the gather, so this matches
        ``masked_inputs`` bitwise without touching the masked J-sized
        intermediates)."""
        gi = bigvec.gather(_raw_flat_g(), idx).astype(jnp.float32)
        ei = bigvec.gather(err_raw, idx).astype(jnp.float32)
        if pf is not None:
            gi = _scalar_select(pf, gi, 0.0)
            ei = _scalar_select(
                pf, ei,
                (jnp.float32(err_decay) * ei).astype(err_raw.dtype)
                .astype(jnp.float32))
        if kind == "dgc":
            mi = momentum * bigvec.gather(mom, idx).astype(jnp.float32) + gi
            return ei + (mi if gate is None else
                         _scalar_select(gate, mi, 0.0))
        return ei + gi

    support_valid = None
    if regtopk:
        if nsel_prev is not None:
            support_valid = (jnp.arange(idx_prev.shape[0], dtype=jnp.int32)
                             < nsel_prev)
        skey = _posterior_keys(bigvec.gather(a, idx_prev), a_prev_sel,
                               g_prev_sel, step, omega=omega, mu=mu,
                               support_valid=support_valid)
        # candidates that are support members carry an uncorrected key:
        # disable them (the corrected copy is appended below). With no
        # dense mask in the state, membership is resolved against the
        # O(k) posterior support itself — sort + searchsorted in
        # candidate space, O((k + cand) log k), no O(J) array touched.
        if support_valid is not None:
            # inert pad slots alias index 0: exclude them via the
            # out-of-range sentinel before the sort (bigvec.live_idx)
            idx_live = bigvec.live_idx(idx_prev, support_valid, j)
        else:
            idx_live = idx_prev.astype(jnp.uint32)
        idx_sorted = jnp.sort(idx_live)
        pos = jnp.minimum(jnp.searchsorted(idx_sorted, cand_i),
                          idx_sorted.shape[0] - 1)
        hit = (idx_sorted[pos] == cand_i) & (step > 0)
        cand_k = jnp.where(hit, -jnp.inf, cand_k)
        allk = jnp.concatenate([cand_k, skey])
        alli = jnp.concatenate([cand_i, idx_prev.astype(jnp.uint32)])
    else:
        allk, alli = cand_k, cand_i

    tv, tsel = jax.lax.top_k(allk, kcap)
    idx_fast = alli[tsel]
    # signed a-values of every trim entry, gathered from the dense ``a``
    # BEFORE the cond: every read of a's buffer stays ahead of the final
    # err scatter-zero, which can then update it in place (a post-cond
    # gather would extend a's liveness and cost a defensive O(J) copy).
    # Clamp: Pallas INVALID_IDX slots carry -inf keys and are never
    # selected on the fast path.
    allv = bigvec.gather(a, jnp.minimum(alli, jnp.uint32(j - 1)))
    val_fast = allv[tsel]
    kth = tv[k - 1]
    valid = kth > -jnp.inf
    # histogram tau: bit-pattern bin lower edge of the k-th key. The
    # sweep-2 compaction threshold (merged-histogram tau at target
    # kcap + margin) is <= this edge, so the candidates cover every
    # entry >= tau (kernel.key_bin_edge docstring).
    tau = pk.key_bin_edge(kth) if hist else kth
    if producer_ok is None:                  # xla strategy witness
        # a bucket can hide a missed entry only if one of its rows
        # saturated its W candidate slots at or above the selection
        # threshold (the global tau)
        producer_ok = valid
        for full_cover, row_min in witnesses:
            ok_b = full_cover | (jnp.max(row_min) < tau)
            producer_ok = jnp.logical_and(producer_ok, ok_b)
    ok = producer_ok & valid
    if regtopk and not hist:
        # Boundary ties among compacted candidates resolve exactly like the
        # reference (candidate position order == global index order). The
        # one exception: a tie involving a corrected SUPPORT key (appended
        # last, out of index order) with more ties than slots — fallback.
        # (Histogram selection has no exact-parity contract: every tie at
        # tau is either wholly selected or cut at the fixed capacity.)
        n_gt = jnp.sum((allk > kth).astype(jnp.int32))
        n_eq = jnp.sum((allk == kth).astype(jnp.int32))
        support_tie = jnp.any(skey == kth)
        ok = ok & ((n_eq == (k - n_gt)) | ~support_tie)

    def _fallback_keys():
        # adversarial-input escape hatch: recompute (a, keys) from the
        # *function parameters* rather than capturing the intermediate
        # `a` — XLA CPU copies non-parameter conditional operands, which
        # would tax the fast path with an O(J) copy. The elastic masking
        # is likewise re-derived INSIDE the branch from the raw params
        # (the masked J-sized arrays must not become cond operands).
        gg, ee = _raw_flat_g(), err_raw
        if pf is not None:
            gg, ee, _ = masked_inputs(gg, err_raw, pf, err_decay)
        a2, score2, _ = _sweep1_xla(kind, gg, ee, c,
                                    momentum=momentum, mom=mom, gate=gate)
        keys_d = jnp.abs(score2)
        if regtopk:
            base = bigvec.gather(keys_d, idx_prev)
            live = step > 0
            if support_valid is not None:
                live = live & support_valid
                # inert pad slots alias index 0: sentinel + drop
                # (bigvec.live_idx docstring)
                idx_w = bigvec.live_idx(idx_prev, support_valid, j)
            else:
                idx_w = idx_prev
            fix = jnp.where(live, skey, base)
            keys_d = bigvec.scatter_set(keys_d, idx_w, fix, mode="drop")
        return keys_d

    if hist:
        def _fast(_):
            return idx_fast, val_fast, tv >= tau, tau

        def _fallback(_):
            keys_d = _fallback_keys()
            from repro.core import select
            idx_d = select.topk_indices(keys_d, kcap)
            tvd = bigvec.gather(keys_d, idx_d)
            tau_d = pk.key_bin_edge(tvd[k - 1])
            return idx_d, _gather_inputs(idx_d), tvd >= tau_d, tau_d

        idx_k, vraw, valid_sel, tau = jax.lax.cond(ok, _fast, _fallback,
                                                   operand=None)
        if pf is not None:
            # elastic: a sitting-out worker's payload is wholly inert —
            # masking valid_sel itself routes the state scatters to the
            # sentinel (err keeps its decayed value) AND zeroes
            # values/indices/count through the pad-slot handling below
            valid_sel = valid_sel & pf
        values = jnp.where(valid_sel, vraw, 0.0)
        idx_k = jnp.where(valid_sel, idx_k, 0).astype(jnp.uint32)
        count = jnp.sum(valid_sel.astype(jnp.int32))
        # inert pad slots must never zero a live entry's error feedback:
        # sentinel + drop for the O(k) state scatters (bigvec.live_idx)
        idx_w = bigvec.live_idx(idx_k, valid_sel, j)
        ghat = None
        if want_ghat:
            # scatter-ADD: a pad's (0, 0.0) never clobbers index 0
            ghat = bigvec.scatter_add(jnp.zeros((j,), jnp.float32),
                                      idx_k, values)
    else:
        def _fast(_):
            return idx_fast, val_fast

        def _fallback(_):
            from repro.core import select
            idx_d = select.topk_indices(_fallback_keys(), k)
            return idx_d, _gather_inputs(idx_d)

        idx_k, values = jax.lax.cond(ok, _fast, _fallback, operand=None)
        count = jnp.asarray(k, jnp.int32)
        tau = None
        idx_w = idx_k                        # exact: all k slots live
        if pf is not None:
            # elastic: sentinel-skip the state scatters and mask the
            # packed payload inert for a sitting-out worker
            idx_w = bigvec.live_idx(idx_k, pf, j)
            values = jnp.where(pf, values, 0.0)
            idx_k = jnp.where(pf, idx_k, jnp.zeros_like(idx_k))
            count = jnp.where(pf, count, 0)
        ghat = None
        if want_ghat:
            ghat = bigvec.scatter_set(jnp.zeros((j,), jnp.float32),
                                      idx_k, values)
    # --- O(k) state writes ---------------------------------------------
    # err^{t+1} = a * (1 - s): zero the selected slots of a in place —
    # the ONLY J-sized state, written by an O(k) scatter (the third
    # O(J) traversal of the old (a_prev, s_prev) layout is gone). The
    # ef_dtype cast happens BEFORE the scatter so bf16 state fuses into
    # the sweep-1 stream instead of adding a post-scatter convert pass.
    dt = jnp.dtype(ef_dtype)
    err = bigvec.scatter_set(a.astype(dt), idx_w, 0.0, mode="drop")
    if kind == "dgc":
        # momentum masking mom * (1 - s), same O(k) scatter-zero
        mom_out = bigvec.scatter_set(mom_out.astype(dt), idx_w, 0.0,
                                     mode="drop")
    return {"err": err, "values": values,
            "indices": idx_k.astype(jnp.uint32), "ghat": ghat,
            "mom": mom_out, "count": count, "tau": tau}
