"""XLA execution strategy for the two-sweep fused compression pipeline.

On CPU/GPU the Pallas grid (interpret mode) costs far more than the
memory traffic it saves, so the same two-sweep contract is lowered to
fusion-friendly XLA ops instead:

- Sweep 1 is the elementwise (a, score) computation — XLA fuses it into
  one loop over the dense inputs (and into the sweep-2 operand read).
- Sweep 2 is a batched per-row ``lax.top_k``: each CHUNK-sized row emits
  its top-W |score| candidates, the row analogue of the Pallas kernel's
  per-block threshold slots. W is sized ~4x the expected per-row top-k
  share, so the candidate set provably covers the true top-k unless a
  row's W-th candidate reaches the global threshold (the ``ok`` flag the
  caller checks before trusting the compaction).

Cost: O(J log W) compute in one O(J) read — no full-array O(J log k)
sort, and no second sort for packing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 8192


def row_shape(j_pad: int, k: int) -> tuple:
    """(rows, chunk, W) for the candidate sweep over a padded length."""
    chunk = min(CHUNK, j_pad)
    rows = j_pad // chunk
    if rows <= 1:
        # single row: take k (+ slack so the overflow check can pass)
        w = min(chunk, k + 8)
    else:
        mean = k * chunk / j_pad
        w = int(max(16, min(chunk, 8 * round(mean / 2))))   # ~4x mean, mult of 8
        w = max(w, 16)
    return rows, chunk, w


def pad_len(j: int) -> int:
    chunk = min(CHUNK, max(8, j))
    return -(-j // chunk) * chunk


def candidates_xla(keys: jnp.ndarray, k: int):
    """Per-row top-W compaction of a padded key vector.

    keys: (j_pad,) non-negative scores (padding must be -inf or smaller
    than any real key). Returns (cand_keys (rows*W,), cand_idx (rows*W,)
    uint32, row_min (rows,), full_cover bool) where row_min[r] is row r's
    W-th largest key — the exactness witness: if max(row_min) < tau (the
    selected k-th key), no row can hide a missed top-k entry.
    ``full_cover`` is True when W == chunk (every entry is a candidate).
    """
    j_pad = keys.shape[0]
    rows, chunk, w = row_shape(j_pad, k)
    cv, ci = jax.lax.top_k(keys.reshape(rows, chunk), w)
    gi = (jnp.arange(rows, dtype=jnp.uint32)[:, None] * jnp.uint32(chunk)
          + ci.astype(jnp.uint32))
    row_min = jnp.min(cv, axis=1)        # rows sorted desc: == cv[:, w-1]
    # NB: jnp.min over the contiguous row, NOT cv[:, w-1] — the strided
    # column slice of a sort output hits a pathological XLA CPU path.
    return cv.reshape(-1), gi.reshape(-1), row_min, w == chunk
