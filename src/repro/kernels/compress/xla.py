"""XLA execution strategy for the two-sweep fused compression pipeline.

On CPU/GPU the Pallas grid (interpret mode) costs far more than the
memory traffic it saves, so the same two-sweep contract is lowered to
fusion-friendly XLA ops instead:

- Sweep 1 is the elementwise (a, score) computation — XLA fuses it into
  one loop over the dense inputs (and into the sweep-2 operand read).
- Sweep 2 is a batched per-row ``lax.top_k``: each CHUNK-sized row emits
  its top-W |score| candidates, the row analogue of the Pallas kernel's
  per-block threshold slots. W is sized ~4x the expected per-row share
  of the caller's packing budget (k for exact selection, hist_capacity
  for the histogram selector — ops passes the budget as ``k``), so the
  candidate set provably covers the true top-budget unless a row's W-th
  candidate reaches the selection threshold (the exact k-th key, or the
  histogram bin edge below it — the witness ops checks before trusting
  the compaction). The histogram selector needs NO dense histogram on
  this strategy: its tau is key_bin_edge(k-th |score|), computable from
  the same trimmed candidates (kernel.key_bin_edge docstring).

Cost: O(J log W) compute in one O(J) read — no full-array O(J log k)
sort, and no second sort for packing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 8192


def row_shape(j_pad: int, k: int, density_len: int = 0,
              slack: float = 4.0) -> tuple:
    """(rows, chunk, W) for the candidate sweep over a padded length.

    ``slack`` scales W relative to the expected per-row share (default
    4x — the historical provisioning; the allocated adaptive path passes
    2x, since its per-segment cap already embeds the ADAPTIVE_CLIP**2
    headroom over the typically-realized count and the row_min witness
    + fallback guard the tail).

    ``density_len`` (default: j_pad) is the length the selection density
    k/density_len is measured over. The bucketed pipeline passes the
    GLOBAL length here: a bucket's rows are provisioned exactly like the
    flat path's rows (4x the global-density share), so bucketing costs
    no extra candidate slots — row-level concentration beyond W is
    caught by the row_min witness and falls back, identically to flat.
    The allocated per-segment path (DESIGN.md §2.6) instead passes its
    per-segment cap as ``k`` with density_len=0: an adaptive segment may
    hold up to cap_l of the budget regardless of global density, so its
    rows are provisioned for the segment's own worst case (caps are
    clipped to ~ADAPTIVE_CLIP**2 x the proportional share, keeping total
    slots O(k)).
    """
    chunk = min(CHUNK, j_pad)
    rows = j_pad // chunk
    dl = density_len or j_pad
    if rows <= 1 and dl == j_pad:
        # single row over the whole vector: take k (+ slack so the
        # overflow check can pass)
        w = min(chunk, k + 8)
    else:
        mean = k * chunk / dl
        # ~slack x mean, multiple of 8 (slack=4 == the original
        # 8 * round(mean / 2))
        w = int(max(16, min(chunk, 8 * round(slack * mean / 8))))
        w = min(chunk, max(w, 16))      # tiny buckets: chunk itself can be < 16
    return rows, chunk, w


def pad_len(j: int) -> int:
    chunk = min(CHUNK, max(8, j))
    return -(-j // chunk) * chunk


def pad_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """Pad a key vector to its row-aligned length with -inf sentinels.

    -inf keys can never out-rank a real |score| (>= 0), so padded slots
    are inert in the per-row top-W compaction; the bucketed pipeline pads
    each bucket independently (the padding of bucket b must not alias
    bucket b+1's index range with a selectable key).
    """
    j = keys.shape[0]
    j_pad = pad_len(j)
    if j_pad == j:
        return keys
    return jnp.concatenate(
        [keys, jnp.full((j_pad - j,), -jnp.inf, jnp.float32)])


def candidates_xla(keys: jnp.ndarray, k: int, density_len: int = 0,
                   slack: float = 4.0):
    """Per-row top-W compaction of a padded key vector.

    keys: (j_pad,) non-negative scores (padding must be -inf or smaller
    than any real key). Returns (cand_keys (rows*W,), cand_idx (rows*W,)
    uint32, row_min (rows,), full_cover bool) where row_min[r] is row r's
    W-th largest key — the exactness witness: if max(row_min) < tau (the
    selected k-th key), no row can hide a missed top-k entry.
    ``full_cover`` is True when W == chunk (every entry is a candidate).
    ``density_len``: see row_shape (bucketed callers pass the global J).
    """
    j_pad = keys.shape[0]
    rows, chunk, w = row_shape(j_pad, k, density_len, slack)
    cv, ci = jax.lax.top_k(keys.reshape(rows, chunk), w)
    gi = (jnp.arange(rows, dtype=jnp.uint32)[:, None] * jnp.uint32(chunk)
          + ci.astype(jnp.uint32))
    row_min = jnp.min(cv, axis=1)        # rows sorted desc: == cv[:, w-1]
    # NB: jnp.min over the contiguous row, NOT cv[:, w-1] — the strided
    # column slice of a sort output hits a pathological XLA CPU path.
    return cv.reshape(-1), gi.reshape(-1), row_min, w == chunk
