"""Pure-jnp dense oracle for the fused compression pipeline.

Mirrors ``core.sparsify.compress`` (pipeline="reference",
selector="exact") on the *fused* state layout, so kernel/ops tests can
check parity without round-tripping through the dense state dict:

    a     = err_prev + g       (err_prev = a^{t-1} * (1 - s^{t-1}),
                                maintained by the O(k) scatter-zero)
    score = a * tanh(|1 + Delta| / mu),  Delta from the O(k) posterior
    top-k by |score| with lax.top_k tie-break (value desc, index asc)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import safe_denom


def dense_scores_ref(g, err_prev, step, *, kind: str, omega: float = 1.0,
                     mu: float = 0.1, Q: float = 0.0, momentum: float = 0.9,
                     mom=None, idx_prev=None, a_prev_sel=None,
                     g_prev_sel=None, nsel=None):
    """(a, score, mom_out) for the fused state layout, dense math.

    The previous support is densified from ``idx_prev`` (the O(k)
    posterior already carries it; ``nsel`` marks the live-slot count of
    the histogram selector's fixed-capacity layout — pad slots alias
    index 0 and must not densify as support members)."""
    err = err_prev.astype(jnp.float32)
    g = g.astype(jnp.float32)
    mom_out = mom
    if kind == "dgc":
        mom_out = momentum * mom.astype(jnp.float32) + g
        a = err + mom_out
    else:
        a = err + g
    if kind != "regtopk":
        return a, a, mom_out
    j = a.shape[0]
    # densify the O(k) posterior (oracle only; the pipeline never does)
    idx_w = idx_prev.astype(jnp.int32)
    if nsel is not None:
        from repro.core.bigvec import live_idx
        live = jnp.arange(idx_w.shape[0], dtype=jnp.int32) < nsel
        idx_w = live_idx(idx_w, live, j).astype(jnp.int32)  # pads dropped
    s = jnp.zeros((j,), jnp.float32).at[idx_w].set(1.0, mode="drop")
    a_prev_d = jnp.zeros((j,), jnp.float32).at[idx_w].set(
        a_prev_sel.astype(jnp.float32), mode="drop")
    g_agg_d = jnp.zeros((j,), jnp.float32).at[idx_w].set(
        g_prev_sel.astype(jnp.float32), mode="drop")
    safe = safe_denom(omega * a)
    delta = s * ((g_agg_d - omega * a_prev_d) / safe) + Q * (1.0 - s)
    score = a * jnp.tanh(jnp.abs(1.0 + delta) / mu)
    score = jnp.where(step == 0, a, score)
    return a, score, mom_out


def exact_topk_ref(score, k: int):
    """(values_of_|score|, indices) with lax.top_k tie-break."""
    return jax.lax.top_k(jnp.abs(score.astype(jnp.float32)), k)


def hist_select_ref(score, k: int, kcap: int):
    """Dense oracle for the fused histogram selector (DESIGN.md §2.5).

    tau = key_bin_edge(k-th largest |score|) — identical to the sweep-1
    bit-pattern histogram threshold at target k — and the selection is
    the min(count(|score| >= tau), kcap) largest entries, i.e. all
    entries >= tau capped at the fixed packed capacity. Returns
    (tau, mask_bool (J,)).
    """
    from repro.kernels.compress.kernel import key_bin_edge
    keys = jnp.abs(score.astype(jnp.float32))
    kv, ki = jax.lax.top_k(keys, int(min(kcap, keys.shape[0])))
    tau = key_bin_edge(kv[k - 1])
    sel = ki[kv >= tau]
    mask = jnp.zeros(keys.shape, bool).at[sel].set(True)
    return tau, mask


def bucket_hists_ref(keys, bounds, bins: int = 2048):
    """Per-bucket bit-pattern histograms, dense oracle (DESIGN.md §2.4).

    The merge invariant the bucketed pipeline rests on: bit_bin is a
    pure function of the value, so summing these per-bucket histograms
    reproduces the flat histogram of ``keys`` exactly, for any
    contiguous partition ``bounds``.
    """
    from repro.kernels.compress.kernel import bit_bin
    keys = jnp.abs(keys.astype(jnp.float32))
    return [jnp.zeros((bins,), jnp.int32).at[bit_bin(keys[o:o + s])].add(1)
            for o, s in bounds]
