"""Two-sweep fused compression pipeline (DESIGN.md §2.2).

Executes the entire TOP-k / DGC / REGTOP-k compression step in two
O(J) sweeps over the flat gradient — total — instead of the ~8 HBM
passes plus two O(J log k) ``lax.top_k`` sorts the reference path
performs:

- **Sweep 1** reads the dense inputs (g, err_prev [, mom]) exactly once
  and emits ``a`` (the error-compensated gradient) and the selection
  ``score``. ``err_prev`` is the ONE J-sized state vector: the previous
  step's error feedback a^{t-1} * (1 - s^{t-1}), maintained by the O(k)
  scatter-zero that closes each step — no dense mask or ``a_prev`` copy
  exists in the state, and no traversal is ever spent writing next-step
  state. The Pallas kernel additionally accumulates the bit-pattern
  histogram the TPU threshold is derived from, plus per-block amax (a
  diagnostic witness exercised by the kernel tests; the threshold
  itself needs no amax, since bit-pattern bins are scale-free).
- **Sweep 2** compacts per-block top-candidate (value, index) slots; a
  small O(candidates) trim then selects the exact top-k with
  ``lax.top_k`` tie-break semantics (value desc, index asc). REGTOP-k's
  O(k) posterior corrections (Algorithm 1 line 5) are applied in
  candidate space, never densely — ``idx_prev`` doubles as the support
  set for the candidate/support membership test.

Execution strategies (auto-selected from the JAX backend by ``ops``):

- ``pallas``:  native Pallas kernels (TPU). Threshold from the
  accumulated bit-pattern histogram; compaction via per-block slots.
- ``xla``:     batched-row ``lax.top_k`` compaction (CPU/GPU). Same
  candidate contract, no interpret-mode overhead.
- ``pallas_interpret``: the Pallas kernels under ``interpret=True`` —
  used by tests to validate the kernel bodies on CPU.

Both strategies verify exactness (per-block overflow + boundary-tie
ambiguity) and fall back to a full ``lax.top_k`` under ``lax.cond`` on
the rare adversarial inputs where the compacted candidate set cannot be
proven to cover the true top-k.

Density allocation (DESIGN.md §2.6, ``core/allocate.py``): with
``SparsifierConfig.allocation`` in {"proportional", "adaptive"} the
budget splits sum(k_l) == k across contiguous segments and the global
trim becomes per-segment trims with per-segment thresholds — same two
sweeps, same O(k) state tail, same k-pair wire format. Contract tests:
tests/test_compress_pipeline.py (exact parity), tests/test_bucketed.py
(bucketing invariance), tests/test_fused_configs.py (capability
matrix), tests/test_state_traffic.py (2-traversal audit),
tests/test_allocate.py (budget conservation + allocated parity).
"""
from repro.kernels.compress.dispatch import (  # noqa: F401
    CompressDispatch,
    dispatch,
    effective_comm_mode,
    hist_capacity,
    packed_len,
)
from repro.kernels.compress.ops import (  # noqa: F401
    fused_compress_arrays,
    sweep_plan,
)
