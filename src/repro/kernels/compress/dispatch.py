"""Capability table + dispatch decisions for the compression pipelines.

The seed gated the fused two-sweep pipeline behind one opaque boolean
(``_fused_supported``), so every config outside {topk, dgc, regtopk} x
selector="exact" x fp32 error feedback silently took the ~7-sweep
reference path. This module replaces that gate with an explicit,
queryable table (DESIGN.md §2.5):

- :func:`dispatch` returns which execution path serves a config and —
  when it is the reference path — the reason, so "why is this config
  slow" is a lookup, not a debugging session.
- :func:`packed_len` is the static length of the fixed-size packed
  ``(values, indices)`` pairs a config's compress step emits (the unit
  the sparse all-gather moves).
- :func:`effective_comm_mode` is the communication mode a config
  ACTUALLY realizes: ``comm_mode="sparse"`` degrades to a dense
  simulate all-reduce when compress packs no pairs (reference-pipeline
  histogram selectors), and ``core.aggregate`` warns about it once at
  trace time instead of silently changing the comm volume.

Fused selection contracts per selector:

- ``exact``: selected support BIT-identical to the reference exact
  selector (``lax.top_k`` tie-break, value desc / index asc).
- ``histogram``: threshold selection at the bit-pattern bin lower edge
  of the exact k-th |score| (``kernel.key_bin_edge`` — identical to the
  sweep-1 2048-bin histogram threshold at target k). Over-selects by
  design: count in [k, k*(1+HIST_SLACK)], capped at ``hist_capacity``
  so the packed pairs stay fixed-size; pad slots are inert (0.0 at
  index 0). NOT bit-identical to the reference histogram selector,
  which buckets |score|/amax into LINEAR bins — both satisfy the same
  count contract.

``ef_dtype="bfloat16"`` stores the J-sized EF state (``err_prev``, and
``mom`` for DGC) in bf16 with all sweep math in fp32 registers; it
tracks the fp32 reference within bf16 rounding (DESIGN.md §2.5 states
the tolerance contract the parity tests pin).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

# kinds the fused two-sweep pipeline implements. "randk" is selection-
# score-free (one elementwise sweep + O(k) random gather) and ignores
# the selector; "thresholdk" shares the plain-score path with "topk".
FUSED_KINDS = ("topk", "dgc", "regtopk", "randk", "thresholdk")
FUSED_SELECTORS = ("exact", "histogram")
FUSED_EF_DTYPES = ("float32", "bfloat16")

# fused histogram over-selection cap: count <= k * (1 + HIST_SLACK).
# The reference histogram selector's over-selection is one bin's
# population (unbounded on adversarial inputs); the fused path trims to
# the hist_capacity largest >= tau so the packed pairs stay fixed-size.
HIST_SLACK = 1.0


@dataclass(frozen=True)
class CompressDispatch:
    """One config's execution-path decision (queryable, trace-free)."""
    path: str          # "fused" | "reference"
    reason: str        # "" when fused; why the reference path serves it
    packs_pairs: bool  # compress emits fixed-size packed (values, indices)
    exact_parity: bool  # selection bit-identical to reference selector="exact"
    selection: str = "local"  # "local" | "global" | "sketch" | "none"
    wire: str = "pairs"       # "pairs" | "values" | "dense"


def _selection_wire(cfg):
    """Where the top-k decision is made and what travels on the sparse
    wire (DESIGN.md §2.9). "local" selection ships packed (values,
    indices) pairs; "sketch" coordination yields one SHARED mask, so
    only the (k,) values travel ("values"); "global"/"none" selection
    has no per-worker sparse payload at all ("dense")."""
    if cfg.kind == "none":
        return "none", "dense"
    if cfg.kind == "globaltopk":
        return "global", "dense"
    if cfg.kind == "sketchtopk":
        return "sketch", "values"
    return "local", "pairs"


def _fused_reason(cfg) -> str:
    """Why cfg does NOT take the fused path ("" = it does)."""
    if cfg.pipeline != "fused":
        return f"pipeline={cfg.pipeline!r} requested"
    if cfg.kind == "sketchtopk":
        # the CountSketch ENCODE folds into sweep 1 (ops.
        # fused_sketch_encode); selection itself is aggregate-level
        # (shared mask after the sketch all-reduce), so the selector
        # only has to exist for the shared-mask decode
        if cfg.selector not in FUSED_SELECTORS:
            return (f"selector={cfg.selector!r} has no shared-mask "
                    "decode on the fused sketch path")
        if str(cfg.ef_dtype) not in FUSED_EF_DTYPES:
            return (f"ef_dtype={cfg.ef_dtype!r} has no fused state layout "
                    "(fp32 and bf16 only)")
        return ""
    if cfg.kind not in FUSED_KINDS:
        return (f"kind={cfg.kind!r} has no per-worker compress step the "
                "two-sweep pipeline can serve (aggregate-level "
                "selection)")
    if cfg.kind != "randk" and cfg.selector not in FUSED_SELECTORS:
        return (f"selector={cfg.selector!r} is served by kernels/topk_select "
                "on the reference path")
    if str(cfg.ef_dtype) not in FUSED_EF_DTYPES:
        return (f"ef_dtype={cfg.ef_dtype!r} has no fused state layout "
                "(fp32 and bf16 only)")
    return ""


def dispatch(cfg) -> CompressDispatch:
    """Execution-path decision for a SparsifierConfig (DESIGN.md §2.5).

    Pure python over static config fields (trace-free, O(1)); the
    contract rows are pinned by tests/test_fused_configs.py::
    TestDispatchTable. ``cfg.allocation`` does not change the path — both
    pipelines serve every allocation mode for the kinds
    allocate.ALLOCATED_KINDS (allocate.check_allocation raises for the
    rest; DESIGN.md §2.6). ``selection``/``wire`` are what
    core.aggregate.GradientSync branches on — sync never looks at
    cfg.kind directly (DESIGN.md §2.9)."""
    sel, wire = _selection_wire(cfg)
    reason = _fused_reason(cfg)
    if not reason:
        if cfg.kind == "sketchtopk":
            # encode-in-sweep-1; no packed pairs — the shared mask
            # implies the index list, only values travel
            return CompressDispatch("fused", "", False,
                                    cfg.selector == "exact", sel, wire)
        exact = cfg.kind == "randk" or cfg.selector == "exact"
        return CompressDispatch("fused", "", True, exact, sel, wire)
    # reference path: packed pairs exist only for fixed-count selection —
    # selector="exact", randk (selector-free), and regtopk's O(k) sparse
    # state layout (whose packing is exact-k regardless of cfg.selector:
    # _compress_regtopk_sparse selects via topk_indices unconditionally)
    exact_count = (cfg.selector == "exact" or cfg.kind == "randk"
                   or (cfg.kind == "regtopk"
                       and cfg.state_format == "sparse"))
    packs = exact_count and cfg.kind in ("topk", "dgc", "regtopk",
                                         "thresholdk", "randk")
    return CompressDispatch("reference", reason, packs, exact_count,
                            sel, wire)


def hist_capacity(k: int, j: int) -> int:
    """Static packed capacity of the fused histogram selector:
    min(j, k + ceil(k * HIST_SLACK)), never below k + 1 so the
    over-selection contract count >= k is satisfiable with slack."""
    k = int(min(k, j))
    return int(min(j, k + max(1, int(math.ceil(k * HIST_SLACK)))))


def packed_len(cfg, j: int) -> int:
    """Length of the packed (values, indices) arrays compress emits for
    this config — k for exact-count selection, hist_capacity(k, j) for
    the fused histogram selector (tail slots inert-padded). This is the
    per-worker unit the sparse all-gather moves: (packed_len,) fp32-or-
    wire_dtype values + (packed_len,) uint32 indices. Density allocation
    (DESIGN.md §2.6) never changes it — every mode conserves
    sum(k_l) == k, so the wire format is allocation-invariant
    (tests/test_allocate.py::TestSyncGradient pins this)."""
    from repro.core.sparsify import resolve_k
    k = resolve_k(cfg, j)
    d = dispatch(cfg)
    if d.wire == "values":
        return k            # shared-mask payload: exactly k values (§2.9)
    if d.path == "fused" and cfg.kind != "randk" and \
            cfg.selector == "histogram":
        return hist_capacity(k, j)
    return k


def check_overlap(cfg) -> None:
    """Validate ``cfg.overlap`` (DESIGN.md §2.8) — raises ValueError,
    never silently downgrades.

    ``overlap="backward"`` streams the gradient into compression per
    layer-aligned segment, which only the fused two-sweep pipeline
    supports (the reference path materializes dense intermediates whose
    math does not partition). A config the capability table routes to
    the reference path must therefore not request streaming."""
    overlap = getattr(cfg, "overlap", "none")
    if overlap not in ("none", "backward"):
        raise ValueError(f"overlap={overlap!r} (expected 'none' or "
                         "'backward')")
    if overlap == "backward":
        d = dispatch(cfg)
        if d.selection == "sketch":
            raise ValueError(
                "overlap='backward' is not defined for sketch-coordinated "
                "selection (kind='sketchtopk'): the sketch all-reduce is a "
                "pre-selection barrier over the WHOLE accumulated gradient, "
                "so no per-segment stream can launch before the shared "
                "mask exists (DESIGN.md §2.9)")
        if d.path != "fused":
            raise ValueError(
                "overlap='backward' requires the fused pipeline; this "
                f"config dispatches to the reference path ({d.reason})")


def effective_comm_mode(cfg) -> str:
    """The communication mode cfg actually realizes in sync_gradient.

    comm_mode="sparse" needs a fixed-size sparse payload; configs whose
    compress step emits none (reference-pipeline histogram selectors)
    degrade to a dense simulate all-reduce — explicitly, with a
    trace-time warning from core.aggregate. Dense-wire selection
    ("none"/"globaltopk") all-reduces densely regardless; sketch
    coordination ships the shared-mask values-only payload, which is
    sparse on both pipelines (DESIGN.md §2.9).
    """
    if cfg.comm_mode != "sparse":
        return cfg.comm_mode
    d = dispatch(cfg)
    if d.wire == "dense":
        return "dense"
    if d.wire == "values":
        return "sparse"
    return "sparse" if d.packs_pairs else "simulate"
