"""Traced-shape audit: count O(J) HBM traversals of a jitted function.

Walks the jaxpr of a function and models XLA's loop fusion to estimate
how many full J-sized streaming passes over HBM the computation
performs — and, since the write-traffic PR, how many J-sized WRITES it
lands. Used by the sweep-count regression test and the compression
benchmark, so the two-sweep pipeline's pass count is measured, not
asserted by hand.

Model (intentionally simple, deterministic, and version-stable):

- *Elementwise* equations (adds, multiplies, selects, converts, pads,
  concats, broadcasts, ...) over sizable operands fuse into connected
  groups; one group = one streaming traversal, regardless of how many
  sizable arrays it reads or writes (``traversals``), with the bytes it
  touches accounted separately (``read_units`` — J-fp32-equivalents of
  distinct sizable group inputs).
- *Barrier* equations — sort/top_k, reductions, cumsums, scans,
  pallas_call — each count as one traversal and read their sizable
  operands.
- Scatter equations with small (O(k)) updates and gather equations with
  small outputs are O(k) random accesses, not streaming passes.
- ``cond`` contributes the *minimum* over its branches: the fused
  pipeline's exact-top-k fallback branch exists for adversarial inputs
  only, and the audit measures the steady-state path.

Write accounting (``write_units``, J-fp32-equivalents of streamed
writes — the half of a streaming kernel's HBM traffic the read-only
audit used to leave invisible):

- An elementwise group writes each sizable array it produces that
  ESCAPES the group — is consumed by a barrier/scatter/gather/cond or
  returned from the jaxpr. Fusion-internal temporaries stay in
  registers and cost nothing, mirroring the read model.
- Barriers and sizable gathers write their sizable outputs.
- Scatters with O(k) updates are O(k) random writes — free — UNLESS the
  scattered-into operand is an UNDONATED function input: XLA cannot
  mutate a caller-visible argument in place, so the scatter pays a
  defensive O(J) copy (billed as its write volume). Donated inputs
  (``audit_fn(..., donate_argnums=...)``, matching
  ``jax.jit(donate_argnums=...)``) and intermediates update in place
  and stay free — which is exactly the err_prev/mom in-place update the
  donated train step relies on.
- Pass-through outputs (a returned input, or a view of one) were never
  produced and cost nothing.

Traversals are **J-equivalents** (DESIGN.md §2.3): each group/barrier is
weighted by its largest operand's size relative to the threshold ``j``,
so the bucketed pipeline's num_buckets sweeps of J/num_buckets elements
correctly total ~1 traversal instead of either vanishing below a "big"
cutoff or counting num_buckets times — and their partial writes sum the
same way (bytes-weighted). Gathers are weighted by their OUTPUT size
(random access, not a stream over the operand). Arrays smaller than
max(1024, j/16) stay free (O(k) packing fix-ups, per-row candidate
slots, O(candidates) trim arrays); the audit therefore resolves
bucketings up to ~16 buckets.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import numpy as np

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "rem", "and", "or",
    "xor", "not", "neg", "sign", "abs", "exp", "log", "tanh", "sqrt",
    "rsqrt", "integer_pow", "select_n", "convert_element_type", "clamp",
    "eq", "ne", "ge", "gt", "le", "lt", "stop_gradient", "pad",
    "concatenate", "broadcast_in_dim", "iota", "bitcast_convert_type",
    "shift_right_logical", "shift_left", "is_finite", "square", "copy",
    "nextafter", "floor", "ceil", "round",
}
_FREE = {"reshape", "squeeze", "expand_dims", "transpose", "rev",
         "slice", "dynamic_slice"}
_BARRIERS = {
    "sort", "top_k", "approx_top_k", "reduce_sum", "reduce_max",
    "reduce_min", "reduce_prod", "reduce_and", "reduce_or", "argmax",
    "argmin", "cumsum", "cumlogsumexp", "cummax", "cummin", "cumprod",
    "scan", "while", "pallas_call", "reduce_precision", "clz",
}


def _size(var) -> int:
    try:
        return int(np.prod(var.aval.shape)) if var.aval.shape else 1
    except Exception:
        return 1


def _bytes(var) -> int:
    try:
        return _size(var) * var.aval.dtype.itemsize
    except Exception:
        return 0


class _UnionFind:
    def __init__(self):
        self.parent = {}

    def find(self, x):
        self.parent.setdefault(x, x)
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def audit_jaxpr(jaxpr, j: int, unit_bytes: int = 4,
                donated=frozenset()) -> dict:
    """Count traversals/read-units/write-units of a ClosedJaxpr for
    threshold size j.

    Returns {"traversals": float, "read_units": float,
    "write_units": float}: traversals are J-equivalent streaming passes
    (a pass over J/B elements weighs 1/B); read_units is sizable-input
    bytes / (j * unit_bytes) — J-fp32-equivalents of streamed reads;
    write_units the same for streamed writes (see module docstring for
    what counts as a write). ``donated`` is a set of input vars whose
    buffers the caller donates (in-place scatter updates of them are
    free; undonated inputs pay a defensive copy).
    """
    floor = max(1024, j // 16)
    sizable = lambda v: _size(v) >= floor
    frac = lambda v: _size(v) / float(j)
    uf = _UnionFind()
    group_of_var = {}
    barrier_weight = 0.0
    read_bytes = 0.0
    write_bytes = 0.0
    produced = set()
    escaped = set()
    # alias root: tracks which vars are (views of) function inputs, for
    # the donated-in-place vs defensive-copy scatter distinction
    invars = set(jaxpr.jaxpr.invars) | set(jaxpr.jaxpr.constvars)
    alias_root = {v: v for v in invars}

    def _mark_escapes(eqn):
        for v in eqn.invars:
            if hasattr(v, "aval") and sizable(v) and v in produced:
                escaped.add(v)

    def handle(eqns):
        nonlocal barrier_weight, read_bytes, write_bytes
        for eqn in eqns:
            prim = eqn.primitive.name
            if prim in ("pjit", "closed_call", "custom_jvp_call",
                        "custom_vjp_call", "custom_vjp_call_jaxpr",
                        "remat", "checkpoint"):
                # the sub-jaxpr's vars are disjoint from the outer ones,
                # so a produced array feeding the call crosses an HBM
                # boundary in this model (groups already break here)
                _mark_escapes(eqn)
                sub = eqn.params.get("jaxpr")
                if sub is not None:
                    handle(sub.jaxpr.eqns if hasattr(sub, "jaxpr")
                           else sub.eqns)
                continue
            if prim == "cond":
                # min over branches (steady-state path; the exact-top-k
                # fallback branch is adversarial-input-only)
                _mark_escapes(eqn)
                results = []
                for br in eqn.params["branches"]:
                    # thread donation through: a branch invar aliases the
                    # outer operand it binds, so a donated (or view-of-
                    # donated) operand stays donated inside the branch
                    don_br = {bv for bv, ov in zip(br.jaxpr.invars,
                                                   eqn.invars[1:])
                              if not isinstance(ov, jax.core.Literal)
                              and alias_root.get(ov) in donated}
                    results.append(audit_jaxpr(br, j, unit_bytes,
                                               donated=frozenset(don_br)))
                best = min(results, key=lambda r: (r["traversals"],
                                                   r["read_units"],
                                                   r["write_units"]))
                barrier_weight += best["traversals"]
                read_bytes += best["read_units"] * j * unit_bytes
                write_bytes += best["write_units"] * j * unit_bytes
                continue
            big_in = [v for v in eqn.invars
                      if hasattr(v, "aval") and sizable(v)]
            big_out = [v for v in eqn.outvars if sizable(v)]
            if not big_in and not big_out:
                continue
            weight = max(frac(v) for v in big_in + big_out)
            if prim in _FREE:
                # view-ish: propagate group membership through; a view of
                # a produced array is itself produced (its bytes were
                # already written in-stream — counting the view as an
                # external group input would double-bill bucket slices)
                for vo in big_out:
                    for vi in big_in:
                        if vi in group_of_var:
                            group_of_var[vo] = group_of_var[vi]
                        if vi in produced:
                            produced.add(vo)
                        if vi in alias_root:
                            alias_root[vo] = alias_root[vi]
                continue
            if prim == "gather":
                _mark_escapes(eqn)
                if not big_out:
                    continue                   # O(k) random reads
                # random access costs its output volume, not a stream
                # over the (possibly J-sized) operand
                barrier_weight += max(frac(v) for v in big_out)
                read_bytes += sum(_bytes(v) for v in big_out)
                write_bytes += sum(_bytes(v) for v in big_out)
                continue
            if prim == "scatter" or prim.startswith("scatter-"):
                _mark_escapes(eqn)
                upd = eqn.invars[-1] if eqn.invars else None
                if upd is not None and not sizable(upd):
                    # O(k) random writes — free in place. The operand
                    # buffer must exist, though: an UNDONATED function
                    # input cannot be mutated, so XLA copies it first
                    # (an O(J) write the donated path never pays).
                    op = eqn.invars[0] if eqn.invars else None
                    root = alias_root.get(op)
                    if (root is not None and root not in donated
                            and op is not None and sizable(op)):
                        write_bytes += _bytes(op)
                    continue
                barrier_weight += weight
                read_bytes += sum(_bytes(v) for v in big_in)
                write_bytes += sum(_bytes(v) for v in big_out)
                continue
            if prim in _ELEMENTWISE:
                key = ("eqn", id(eqn))
                uf.find(key)
                for v in big_in + big_out:
                    if v in group_of_var:
                        uf.union(key, group_of_var[v])
                    group_of_var[v] = key
                for v in big_out:
                    produced.add(v)
                continue
            # everything else (sorts, reductions, pallas, unknown prims
            # touching sizable data) is a barrier traversal weighted by
            # its largest operand
            _mark_escapes(eqn)
            barrier_weight += weight
            read_bytes += sum(_bytes(v) for v in big_in)
            write_bytes += sum(_bytes(v) for v in big_out)

    handle(jaxpr.jaxpr.eqns)

    # group accounting: each fused elementwise group = 1 J-equivalent
    # traversal weighted by its largest array, reading its distinct
    # sizable external inputs and writing the produced arrays that
    # escape the fused loop (barrier/scatter/gather consumers, or the
    # jaxpr outputs)
    outvars = {v for v in jaxpr.jaxpr.outvars if hasattr(v, "aval")}
    groups = defaultdict(set)
    for v, key in group_of_var.items():
        groups[uf.find(key)].add(v)
    group_weight = 0.0
    for root, vars_ in groups.items():
        group_weight += max(frac(v) for v in vars_)
        for v in vars_:
            if v not in produced:              # external sizable input
                read_bytes += _bytes(v)
            elif v in escaped or v in outvars:
                write_bytes += _bytes(v)
    return {"traversals": round(barrier_weight + group_weight, 3),
            "read_units": round(read_bytes / float(j * unit_bytes), 3),
            "write_units": round(write_bytes / float(j * unit_bytes), 3)}


def audit_fn(fn, *args, j: int, donate_argnums=(), **kwargs) -> dict:
    """Audit a python function by tracing it with jax.make_jaxpr.

    ``donate_argnums`` mirrors ``jax.jit``'s: the flattened leaves of
    those positional args are treated as donated buffers, so O(k)
    scatter updates INTO them audit as free in-place writes instead of
    paying the undonated defensive copy.
    """
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    donated = set()
    if donate_argnums:
        donate_argnums = set(donate_argnums)
        flat_invars = list(jaxpr.jaxpr.invars)
        pos = 0
        for i, a in enumerate(args):
            n = len(jax.tree_util.tree_leaves(a))
            if i in donate_argnums:
                donated.update(flat_invars[pos:pos + n])
            pos += n
    return audit_jaxpr(jaxpr, j, donated=frozenset(donated))
