"""Traced-shape audit: count O(J) HBM traversals of a jitted function.

Walks the jaxpr of a function and models XLA's loop fusion to estimate
how many full J-sized streaming passes over HBM the computation
performs. Used by the sweep-count regression test and the compression
benchmark, so the two-sweep pipeline's pass count is measured, not
asserted by hand.

Model (intentionally simple, deterministic, and version-stable):

- *Elementwise* equations (adds, multiplies, selects, converts, pads,
  concats, broadcasts, ...) over sizable operands fuse into connected
  groups; one group = one streaming traversal, regardless of how many
  sizable arrays it reads or writes (``traversals``), with the bytes it
  touches accounted separately (``read_units`` — J-fp32-equivalents of
  distinct sizable group inputs).
- *Barrier* equations — sort/top_k, reductions, cumsums, scans,
  pallas_call — each count as one traversal and read their sizable
  operands.
- Scatter equations with small (O(k)) updates and gather equations with
  small outputs are O(k) random accesses, not streaming passes.
- ``cond`` contributes the *minimum* over its branches: the fused
  pipeline's exact-top-k fallback branch exists for adversarial inputs
  only, and the audit measures the steady-state path.

Traversals are **J-equivalents** (DESIGN.md §2.3): each group/barrier is
weighted by its largest operand's size relative to the threshold ``j``,
so the bucketed pipeline's num_buckets sweeps of J/num_buckets elements
correctly total ~1 traversal instead of either vanishing below a "big"
cutoff or counting num_buckets times. Gathers are weighted by their
OUTPUT size (random access, not a stream over the operand). Arrays
smaller than max(1024, j/16) stay free (O(k) packing fix-ups, per-row
candidate slots, O(candidates) trim arrays); the audit therefore
resolves bucketings up to ~16 buckets — far finer than the seed's
0.9*J cutoff, which saw nothing smaller than the whole vector.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import numpy as np

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "rem", "and", "or",
    "xor", "not", "neg", "sign", "abs", "exp", "log", "tanh", "sqrt",
    "rsqrt", "integer_pow", "select_n", "convert_element_type", "clamp",
    "eq", "ne", "ge", "gt", "le", "lt", "stop_gradient", "pad",
    "concatenate", "broadcast_in_dim", "iota", "bitcast_convert_type",
    "shift_right_logical", "shift_left", "is_finite", "square", "copy",
    "nextafter", "floor", "ceil", "round",
}
_FREE = {"reshape", "squeeze", "expand_dims", "transpose", "rev",
         "slice", "dynamic_slice"}
_BARRIERS = {
    "sort", "top_k", "approx_top_k", "reduce_sum", "reduce_max",
    "reduce_min", "reduce_prod", "reduce_and", "reduce_or", "argmax",
    "argmin", "cumsum", "cumlogsumexp", "cummax", "cummin", "cumprod",
    "scan", "while", "pallas_call", "reduce_precision", "clz",
}


def _size(var) -> int:
    try:
        return int(np.prod(var.aval.shape)) if var.aval.shape else 1
    except Exception:
        return 1


def _bytes(var) -> int:
    try:
        return _size(var) * var.aval.dtype.itemsize
    except Exception:
        return 0


class _UnionFind:
    def __init__(self):
        self.parent = {}

    def find(self, x):
        self.parent.setdefault(x, x)
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def audit_jaxpr(jaxpr, j: int, unit_bytes: int = 4) -> dict:
    """Count traversals/read-units of a ClosedJaxpr for threshold size j.

    Returns {"traversals": float, "read_units": float}: traversals are
    J-equivalent streaming passes (a pass over J/B elements weighs 1/B);
    read_units is sizable-input bytes / (j * unit_bytes) —
    J-fp32-equivalents of streamed reads.
    """
    floor = max(1024, j // 16)
    sizable = lambda v: _size(v) >= floor
    frac = lambda v: _size(v) / float(j)
    uf = _UnionFind()
    group_of_var = {}
    barrier_weight = 0.0
    read_bytes = 0.0
    produced = set()

    def handle(eqns):
        nonlocal barrier_weight, read_bytes
        for eqn in eqns:
            prim = eqn.primitive.name
            if prim in ("pjit", "closed_call", "custom_jvp_call",
                        "custom_vjp_call", "custom_vjp_call_jaxpr",
                        "remat", "checkpoint"):
                sub = eqn.params.get("jaxpr")
                if sub is not None:
                    handle(sub.jaxpr.eqns if hasattr(sub, "jaxpr")
                           else sub.eqns)
                continue
            if prim == "cond":
                # min over branches (steady-state path; the exact-top-k
                # fallback branch is adversarial-input-only)
                results = []
                for br in eqn.params["branches"]:
                    results.append(audit_jaxpr(br, j, unit_bytes))
                best = min(results, key=lambda r: (r["traversals"],
                                                   r["read_units"]))
                barrier_weight += best["traversals"]
                read_bytes += best["read_units"] * j * unit_bytes
                continue
            big_in = [v for v in eqn.invars
                      if hasattr(v, "aval") and sizable(v)]
            big_out = [v for v in eqn.outvars if sizable(v)]
            if not big_in and not big_out:
                continue
            weight = max(frac(v) for v in big_in + big_out)
            if prim in _FREE:
                # view-ish: propagate group membership through; a view of
                # a produced array is itself produced (its bytes were
                # already written in-stream — counting the view as an
                # external group input would double-bill bucket slices)
                for vo in big_out:
                    for vi in big_in:
                        if vi in group_of_var:
                            group_of_var[vo] = group_of_var[vi]
                        if vi in produced:
                            produced.add(vo)
                continue
            if prim == "gather":
                if not big_out:
                    continue                   # O(k) random reads
                # random access costs its output volume, not a stream
                # over the (possibly J-sized) operand
                barrier_weight += max(frac(v) for v in big_out)
                read_bytes += sum(_bytes(v) for v in big_out)
                continue
            if prim == "scatter" or prim.startswith("scatter-"):
                upd = eqn.invars[-1] if eqn.invars else None
                if upd is not None and not sizable(upd):
                    continue                   # O(k) random writes
                barrier_weight += weight
                read_bytes += sum(_bytes(v) for v in big_in)
                continue
            if prim in _ELEMENTWISE:
                key = ("eqn", id(eqn))
                uf.find(key)
                for v in big_in + big_out:
                    if v in group_of_var:
                        uf.union(key, group_of_var[v])
                    group_of_var[v] = key
                for v in big_out:
                    produced.add(v)
                continue
            # everything else (sorts, reductions, pallas, unknown prims
            # touching sizable data) is a barrier traversal weighted by
            # its largest operand
            barrier_weight += weight
            read_bytes += sum(_bytes(v) for v in big_in)

    handle(jaxpr.jaxpr.eqns)

    # group accounting: each fused elementwise group = 1 J-equivalent
    # traversal weighted by its largest array, reading its distinct
    # sizable external inputs
    groups = defaultdict(set)
    for v, key in group_of_var.items():
        groups[uf.find(key)].add(v)
    group_weight = 0.0
    for root, vars_ in groups.items():
        group_weight += max(frac(v) for v in vars_)
        for v in vars_:
            if v not in produced:              # external sizable input
                read_bytes += _bytes(v)
    return {"traversals": round(barrier_weight + group_weight, 3),
            "read_units": round(read_bytes / float(j * unit_bytes), 3)}


def audit_fn(fn, *args, j: int, **kwargs) -> dict:
    """Audit a python function by tracing it with jax.make_jaxpr."""
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return audit_jaxpr(jaxpr, j)
