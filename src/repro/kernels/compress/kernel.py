"""Pallas TPU kernels for the two-sweep fused compression pipeline.

Sweep 1 (``sweep1_pallas``): one VMEM-tiled pass over the dense inputs.
Per (1, BLOCK) grid step it

- reads the ONE J-sized state vector ``err_prev`` (the previous step's
  error feedback, already zeroed at the selected support by the O(k)
  scatter that closes each step — no dense mask exists in the fused
  state),
- emits ``a = err_prev + g`` and the selection ``score`` (``a * c`` with
  ``c`` the off-support REGTOP-k regularizer, 1 for plain TOP-k / DGC /
  step 0),
- emits the per-block amax of |score| and accumulates a BINS-bin
  *bit-pattern* histogram of |score| (top bits of the fp32 encoding —
  monotone in magnitude, so no separate amax pass is needed to scale the
  bins; this folds the reference selector's amax + histogram passes into
  the same sweep). The histogram uses an in-register bincount
  (scatter-add into the accumulated block) rather than the O(BLOCK*BINS)
  one-hot compare the ``topk_select`` kernel historically used.

Sweep 2 (``sweep2_pallas``): one pass over ``score``. Per grid step it
compacts candidate ``(value, index)`` pairs with ``|score| >= tau`` into
a fixed per-block slot region of width ``MAXPB`` (static base
``i * MAXPB`` — TPU-friendly: no cross-block running offset), plus the
per-block candidate count used by the exactness check, and optionally
the uint8 threshold mask (the fused pipeline skips it and rebuilds the
exact mask as an O(k) scatter). The O(candidates) exact-k trim runs
outside the kernel (ops.py).

Scalars (step flag, tau) travel as (1, 1) inputs; static config (mode,
regularizer constant, bins) is baked into the kernel body.

TPU-native (non-interpret) validation is an open ROADMAP item; tests
exercise these kernels under ``interpret=True`` on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128 * 4      # 4096 fp32 elements per grid step, VMEM tile-aligned
BINS = 2048              # 2^11 bit-pattern bins: exponent + 3 mantissa bits
_BIN_SHIFT = 20          # fp32 bits >> 20 -> [0, 2047] for non-negative floats
INVALID_IDX = 0xFFFFFFFF     # python int: kernels must not capture arrays


def bit_bin(absx: jnp.ndarray) -> jnp.ndarray:
    """Histogram bin of a non-negative fp32 value: top 11 bits of its IEEE-754
    encoding. Monotone: x <= y  =>  bit_bin(x) <= bit_bin(y)."""
    bits = jax.lax.bitcast_convert_type(absx.astype(jnp.float32), jnp.uint32)
    return (bits >> _BIN_SHIFT).astype(jnp.int32)


def bin_lower_edge(b: jnp.ndarray) -> jnp.ndarray:
    """Smallest fp32 value mapping to bin b (the bin's lower edge)."""
    return jax.lax.bitcast_convert_type(
        (b.astype(jnp.uint32) << _BIN_SHIFT), jnp.float32)


def key_bin_edge(x: jnp.ndarray) -> jnp.ndarray:
    """Lower edge of x's bit-pattern bin. For x = the exact k-th largest
    |score| this IS the histogram-selector threshold: the largest bin b
    with tail count >= k is exactly bit_bin(x) (every key above x's bin
    is > x, and there are < k of those), so
    key_bin_edge(kth) == threshold_from_hist(hist, k) — which is what
    lets the XLA strategy serve selector="histogram" without computing
    a dense histogram, and keeps both strategies' tau identical."""
    return bin_lower_edge(bit_bin(x))


# ---------------------------------------------------------------------------
# Sweep 1
# ---------------------------------------------------------------------------

def _sweep1_kernel(c_ref, *refs, mode: str, momentum: float, bins: int,
                   gated: bool = False):
    # dgc mode threads the momentum buffer; plain mode omits it entirely
    # (no dead O(J) passthrough streams on the non-dgc path). gated dgc
    # (elastic participation, DESIGN.md §2.7) prepends one more (1, 1)
    # scalar operand: the worker's participation gate.
    if gated:
        gate_ref, *refs = refs
    if mode == "dgc":
        (g_ref, err_ref, mom_ref,
         a_ref, score_ref, mom_out_ref, amax_ref, hist_ref) = refs
    else:
        (g_ref, err_ref,
         a_ref, score_ref, amax_ref, hist_ref) = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    g = g_ref[...].astype(jnp.float32)
    err = err_ref[...].astype(jnp.float32)     # one state read: err_prev
    if mode == "dgc":
        mom = momentum * mom_ref[...].astype(jnp.float32) + g
        mom_out_ref[...] = mom
        if gated:
            # sitting-out worker: a = err (pre-decayed by the caller's
            # input masking) while mom_out still advances to
            # momentum * mom (g arrives pre-masked to zero). The select
            # — not a multiply — keeps 0 * inf from minting NaNs and is
            # a bitwise pass-through when the gate is on.
            a = err + jnp.where(gate_ref[0, 0] > 0.5, mom, 0.0)
        else:
            a = err + mom
    else:
        a = err + g
    score = a * c_ref[0, 0]
    a_ref[...] = a
    score_ref[...] = score
    keys = jnp.abs(score)
    amax_ref[0, 0] = jnp.max(keys)
    # in-register bincount of the block's bit-pattern bins
    bidx = bit_bin(keys)                                       # (1, BLOCK)
    hist_ref[...] += jnp.zeros((1, bins), jnp.int32).at[
        0, bidx[0]].add(1)


def sweep1_pallas(g, err_prev, c, *, mode: str = "plain",
                  momentum: float = 0.0, mom=None, gate=None,
                  bins: int = BINS, interpret: bool = True):
    """All dense inputs (J,) with J % BLOCK == 0 (caller pads).

    ``err_prev`` is the ONE J-sized state vector of the fused layout —
    the previous step's error feedback, already zero at the selected
    support (the O(k) scatter-zero that closes each step maintains the
    EF invariant err = a * (1 - s) without a dense mask).
    ``c`` is the (traced) off-support score factor: the REGTOP-k
    regularizer constant tanh(|1+Q|/mu), or 1 for TOP-k / DGC / step 0.
    ``gate`` (mode="dgc" only) is the traced elastic-participation
    scalar (DESIGN.md §2.7): when given, a = err + where(gate, mom, 0)
    so a sitting-out worker's ``a`` excludes the momentum stream while
    ``mom_out`` still advances; None keeps the ungated kernel verbatim.
    Returns (a, score, mom_out, block_amax (rows,), hist (bins,));
    mom_out is None unless mode="dgc" (which requires ``mom``).
    """
    j = g.shape[0]
    assert j % BLOCK == 0, j
    rows = j // BLOCK
    rs = lambda x: x.astype(jnp.float32).reshape(rows, BLOCK)
    spec = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    dgc = mode == "dgc"
    gated = gate is not None
    assert not gated or dgc, "gate is a dgc-mode operand"
    vec_out = jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32)
    inputs = ([jnp.asarray(c, jnp.float32).reshape(1, 1)]
              + ([jnp.asarray(gate, jnp.float32).reshape(1, 1)]
                 if gated else [])
              + [rs(g), rs(err_prev)] + ([rs(mom)] if dgc else []))
    outs = pl.pallas_call(
        functools.partial(_sweep1_kernel, mode=mode,
                          momentum=float(momentum), bins=bins,
                          gated=gated),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0))]      # factor c
                 * (2 if gated else 1)                         # (+ gate)
                 + [spec] * (3 if dgc else 2),
        out_specs=[spec] * (3 if dgc else 2) + [
            pl.BlockSpec((1, 1), lambda i: (i, 0)),        # per-block amax
            pl.BlockSpec((1, bins), lambda i: (0, 0)),     # accumulated hist
        ],
        out_shape=[vec_out] * (3 if dgc else 2) + [
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, bins), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    if dgc:
        a, score, mom_out, amax, hist = outs
        mom_out = mom_out.reshape(-1)
    else:
        a, score, amax, hist = outs
        mom_out = None
    return (a.reshape(-1), score.reshape(-1), mom_out,
            amax.reshape(-1), hist[0])


def threshold_from_hist(hist: jnp.ndarray, target) -> jnp.ndarray:
    """Lower edge of the largest bin b whose tail count >= target.

    Guarantees count(|score| >= tau) >= target (0 when target exceeds the
    histogram mass, which routes the caller to the exact fallback).
    ``target`` may be traced — the allocated per-segment path (DESIGN.md
    §2.6) derives each segment's OWN tau from its sweep-1 histogram at a
    per-segment target, instead of one merged-histogram global tau.
    """
    from repro.core.select import hist_tail_bin
    b = hist_tail_bin(hist, target)
    return jnp.where(b >= 0, bin_lower_edge(jnp.maximum(b, 0)), 0.0)


def merge_bucket_hists(hists) -> jnp.ndarray:
    """O(num_buckets x BINS) global-k histogram merge (DESIGN.md §2.4).

    Bit-pattern bins are position-independent (bin of an element depends
    only on its value), so the sum of per-bucket histograms IS the
    histogram of the whole vector: the threshold picked from the merged
    histogram is identical to the flat single-sweep threshold for any
    bucketing, which is what makes the union of per-bucket >=tau
    selections cover the exact global top-k.
    """
    merged = hists[0]
    for h in hists[1:]:
        merged = merged + h
    return merged


def threshold_from_bucket_hists(hists, target) -> jnp.ndarray:
    """Global threshold tau from per-bucket histograms (merge + tail scan)."""
    return threshold_from_hist(merge_bucket_hists(hists), target)


# ---------------------------------------------------------------------------
# Sweep 2
# ---------------------------------------------------------------------------

def _sweep2_kernel(tau_ref, score_ref, *refs, maxpb: int,
                   want_mask: bool):
    if want_mask:
        mask_ref, vals_ref, idx_ref, cnt_ref = refs
    else:
        vals_ref, idx_ref, cnt_ref = refs
    i = pl.program_id(0)
    score = score_ref[...].astype(jnp.float32)                 # (1, BLOCK)
    keys = jnp.abs(score)
    tau = tau_ref[0, 0]
    flags = keys >= tau
    if want_mask:
        mask_ref[...] = flags.astype(jnp.uint8)
    cnt = jnp.sum(flags.astype(jnp.int32))
    cnt_ref[0, 0] = cnt
    # compact candidates into this block's static MAXPB slot region;
    # overflow beyond maxpb is dropped and flagged via cnt > maxpb
    pos = jnp.cumsum(flags[0].astype(jnp.int32)) - 1           # (BLOCK,)
    pos = jnp.where(flags[0], pos, maxpb)                      # drop lanes
    lane = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK,), 0)
    gidx = jax.lax.convert_element_type(i, jnp.uint32) * BLOCK + lane
    vals_ref[...] = jnp.full((1, maxpb), -jnp.inf, jnp.float32).at[
        0, pos].set(keys[0], mode="drop")
    idx_ref[...] = jnp.full((1, maxpb), INVALID_IDX, jnp.uint32).at[
        0, pos].set(gidx, mode="drop")


def sweep2_pallas(score, tau, *, maxpb: int, interpret: bool = True,
                  want_mask: bool = True):
    """score: (J,) fp32, J % BLOCK == 0. Returns
    (mask_u8 (J,) or None, cand_vals (rows*maxpb,), cand_idx
    (rows*maxpb,), block_counts (rows,)). Candidate slots hold |score|
    (key order) and global indices; invalid slots are (-inf,
    INVALID_IDX). want_mask=False skips the dense threshold-mask write
    (callers that rebuild the exact mask as an O(k) scatter)."""
    j = score.shape[0]
    assert j % BLOCK == 0, j
    rows = j // BLOCK
    rs = lambda x: x.astype(jnp.float32).reshape(rows, BLOCK)
    spec = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    mask_specs = [spec] if want_mask else []
    mask_shapes = ([jax.ShapeDtypeStruct((rows, BLOCK), jnp.uint8)]
                   if want_mask else [])
    outs = pl.pallas_call(
        functools.partial(_sweep2_kernel, maxpb=maxpb, want_mask=want_mask),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), spec],
        out_specs=mask_specs + [
            pl.BlockSpec((1, maxpb), lambda i: (i, 0)),
            pl.BlockSpec((1, maxpb), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=mask_shapes + [
            jax.ShapeDtypeStruct((rows, maxpb), jnp.float32),
            jax.ShapeDtypeStruct((rows, maxpb), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(tau, jnp.float32).reshape(1, 1), rs(score))
    if want_mask:
        mask, vals, idx, cnt = outs
        mask = mask.reshape(-1)
    else:
        (vals, idx, cnt), mask = outs, None
    return mask, vals.reshape(-1), idx.reshape(-1), cnt.reshape(-1)


# ---------------------------------------------------------------------------
# CountSketch encode (sweep-1 fold, DESIGN.md §2.9)
# ---------------------------------------------------------------------------

# sketch-encode NATIVE grid step: 32x the sweep block. The encode
# touches each element once and accumulates into the tiny (rows, width)
# output, so a fat block keeps the grid short without growing any
# J-sized intermediate. Interpret mode widens further (_sketch_grid).
SKETCH_BLOCK = 32 * BLOCK


def _sketch_accum(a, base, sk_ref, *, rows: int, width: int, block: int,
                  mults, adds):
    """Accumulate one (block,) slice of ``a`` into the (rows, width)
    sketch ref. Hashing is BIT-identical to core.sketch._hashes: the
    uint32 index stream through the same multiplicative-hash constants
    (baked as python ints — kernels must not capture arrays).

    Each row scatters into its own 1D (width,) accumulator: XLA lowers
    a 1D scatter-add measurably faster than the batched/2D form the
    legacy vmap encode takes (~25% at J = 2^24 on CPU), and the row
    loop is a static unroll (rows <= 8)."""
    lane = jax.lax.broadcasted_iota(jnp.uint32, (block,), 0)
    gidx = base + lane                       # uint32 global element index
    for r in range(rows):
        x = gidx * jnp.uint32(mults[r]) + jnp.uint32(adds[r])
        h = ((x >> 8) % jnp.uint32(width)).astype(jnp.int32)
        s = ((x >> 31) & 1).astype(jnp.float32) * 2.0 - 1.0
        sk_ref[r, :] += jnp.zeros((width,), jnp.float32).at[h].add(s * a)


def _sketch_encode_kernel(a_ref, sk_ref, *, rows: int, width: int,
                          block: int, mults, adds):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sk_ref[...] = jnp.zeros_like(sk_ref)

    a = a_ref[...].astype(jnp.float32)[0]                      # (block,)
    base = jax.lax.convert_element_type(i, jnp.uint32) * block
    _sketch_accum(a, base, sk_ref, rows=rows, width=width, block=block,
                  mults=mults, adds=adds)


def _sweep1_sketch_kernel(g_ref, err_ref, a_ref, sk_ref, *, rows: int,
                          width: int, block: int, mults, adds):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sk_ref[...] = jnp.zeros_like(sk_ref)

    g = g_ref[...].astype(jnp.float32)
    err = err_ref[...].astype(jnp.float32)     # one state read: err_prev
    a = err + g
    a_ref[...] = a
    base = jax.lax.convert_element_type(i, jnp.uint32) * block
    _sketch_accum(a[0], base, sk_ref, rows=rows, width=width, block=block,
                  mults=mults, adds=adds)


def _sketch_grid(j: int, interpret: bool = True):
    """(block, padded J) for the sketch-encode grid: lane-aligned block.
    Pad elements carry a = 0.0, so they add s * 0 to whatever bucket
    their (well-defined) hash picks — inert.

    Native blocks cap at SKETCH_BLOCK (VMEM-bounded). Interpret mode
    has no VMEM ceiling but pays a fixed per-grid-step dispatch cost
    (the emulated block load + scatter launches), so it widens the
    block to keep the grid at <= 8 steps at any J."""
    cap = SKETCH_BLOCK
    if interpret:
        cap = max(cap, -(-j // (8 * 128)) * 128)
    block = min(cap, -(-j // 128) * 128)
    return block, -(-j // block) * block


def sketch_encode_pallas(a, *, rows: int, width: int, mults, adds,
                         interpret: bool = True):
    """a (J,) -> CountSketch (rows, width), bit-identical to
    core.sketch.encode at the same constants. ONE pallas barrier: the
    per-block scatter-adds accumulate into the (rows, width) output
    block, so no (rows, J) hash/sign intermediate is ever materialized
    (the legacy encode's dominant traffic)."""
    j = a.shape[0]
    block, j_pad = _sketch_grid(j, interpret)
    if j_pad != j:
        a = jnp.pad(a.astype(jnp.float32), (0, j_pad - j))
    grid = j_pad // block
    sk = pl.pallas_call(
        functools.partial(_sketch_encode_kernel, rows=rows, width=width,
                          block=block, mults=tuple(mults),
                          adds=tuple(adds)),
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, width), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32).reshape(grid, block))
    return sk


def sweep1_sketch_pallas(g, err_prev, *, rows: int, width: int, mults,
                         adds, interpret: bool = True):
    """Sweep 1 with the CountSketch encode folded in: one pass over
    (g, err_prev) emits both a = err_prev + g AND its sketch, so the
    Pallas strategy pays a single traversal for accumulate + encode
    (DESIGN.md §2.9). Returns (a (J,) fp32, sketch (rows, width))."""
    j = g.shape[0]
    block, j_pad = _sketch_grid(j, interpret)
    if j_pad != j:
        g = jnp.pad(g.astype(jnp.float32), (0, j_pad - j))
        err_prev = jnp.pad(err_prev.astype(jnp.float32), (0, j_pad - j))
    grid = j_pad // block
    rs = lambda x: x.astype(jnp.float32).reshape(grid, block)
    spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    a, sk = pl.pallas_call(
        functools.partial(_sweep1_sketch_kernel, rows=rows, width=width,
                          block=block, mults=tuple(mults),
                          adds=tuple(adds)),
        grid=(grid,),
        in_specs=[spec, spec],
        out_specs=[spec, pl.BlockSpec((rows, width), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((grid, block), jnp.float32),
                   jax.ShapeDtypeStruct((rows, width), jnp.float32)],
        interpret=interpret,
    )(rs(g), rs(err_prev))
    return a.reshape(-1)[:j], sk
