"""Pure-jnp oracle for the fused error-feedback kernels (matches
core/sparsify.py REGTOP-k math exactly)."""
from __future__ import annotations

import jax.numpy as jnp

_TINY = 1e-12


def scores_ref(g, err, a_prev, g_agg, s_prev, *, omega, mu, q):
    g = g.astype(jnp.float32)
    a = err.astype(jnp.float32) + g
    denom = omega * a
    safe = jnp.where(jnp.abs(denom) > _TINY, denom,
                     jnp.sign(denom) * _TINY + _TINY)
    delta_sent = (g_agg.astype(jnp.float32) - omega * a_prev.astype(jnp.float32)) / safe
    delta = s_prev * delta_sent + q * (1.0 - s_prev)
    reg = jnp.tanh(jnp.abs(1.0 + delta) / mu)
    return a, a * reg


def apply_ref(a, mask):
    a = a.astype(jnp.float32)
    ghat = mask.astype(jnp.float32) * a
    return ghat, a - ghat
