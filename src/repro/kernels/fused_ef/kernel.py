"""Pallas TPU kernels: fused REGTOP-k error-feedback passes.

Superseded as the production fused path by repro.kernels.compress (the
two-sweep pipeline behind SparsifierConfig.pipeline="fused"); kept as
standalone, individually-testable building blocks.

Two elementwise fused passes over the flat gradient (DESIGN.md §2.2):

1. ``scores``: a = err + g; Delta = s_prev*(g_agg - w*a_prev)/(w*a) +
   Q*(1-s_prev); score = a * tanh(|1+Delta|/mu). One read per input, one
   write per output — replaces ~6 XLA-boundary HBM passes.
2. ``apply``: ghat = mask*a; err' = a - ghat.

Scalars (omega, mu, Q) are compile-time constants (config values), baked
into the kernel body. Block layout: rows of (1, BLOCK) fp32, VMEM-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128 * 4
_TINY = 1e-12


def _scores_kernel(g_ref, err_ref, a_prev_ref, g_agg_ref, s_prev_ref,
                   a_ref, score_ref, *, omega: float, mu: float, q: float):
    g = g_ref[...].astype(jnp.float32)
    err = err_ref[...].astype(jnp.float32)
    a_prev = a_prev_ref[...].astype(jnp.float32)
    g_agg = g_agg_ref[...].astype(jnp.float32)
    s_prev = s_prev_ref[...].astype(jnp.float32)
    a = err + g
    denom = omega * a
    safe = jnp.where(jnp.abs(denom) > _TINY, denom,
                     jnp.sign(denom) * _TINY + _TINY)
    delta_sent = (g_agg - omega * a_prev) / safe
    delta = s_prev * delta_sent + q * (1.0 - s_prev)
    reg = jnp.tanh(jnp.abs(1.0 + delta) / mu)
    a_ref[...] = a
    score_ref[...] = a * reg


def _apply_kernel(a_ref, mask_ref, ghat_ref, err_ref):
    a = a_ref[...].astype(jnp.float32)
    m = mask_ref[...].astype(jnp.float32)
    ghat = m * a
    ghat_ref[...] = ghat
    err_ref[...] = a - ghat


def _rows(j: int) -> int:
    assert j % BLOCK == 0, j
    return j // BLOCK


def scores_pallas(g, err, a_prev, g_agg, s_prev, *, omega: float, mu: float,
                  q: float, interpret=None):
    """All inputs (J,) fp32, J % BLOCK == 0. Returns (a, score).

    interpret=None auto-selects from the JAX backend."""
    if interpret is None:
        from repro.kernels.common import auto_interpret
        interpret = auto_interpret()
    rows = _rows(g.shape[0])
    rs = lambda x: x.reshape(rows, BLOCK)
    spec = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    a, score = pl.pallas_call(
        functools.partial(_scores_kernel, omega=omega, mu=mu, q=q),
        grid=(rows,),
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32)] * 2,
        interpret=interpret,
    )(rs(g), rs(err), rs(a_prev), rs(g_agg), rs(s_prev))
    return a.reshape(-1), score.reshape(-1)


def apply_pallas(a, mask, *, interpret=None):
    if interpret is None:
        from repro.kernels.common import auto_interpret
        interpret = auto_interpret()
    rows = _rows(a.shape[0])
    rs = lambda x: x.reshape(rows, BLOCK)
    spec = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    ghat, err = pl.pallas_call(
        _apply_kernel,
        grid=(rows,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32)] * 2,
        interpret=interpret,
    )(rs(a), rs(mask))
    return ghat.reshape(-1), err.reshape(-1)
