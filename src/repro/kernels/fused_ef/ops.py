"""Jit-friendly wrappers for the fused error-feedback Pallas kernels:
padding to block multiples + interpret-mode selection (CPU validation runs
the kernel body under interpret=True; on TPU it compiles natively).

NB: these kernels fuse the score chain of the REFERENCE pipeline's
DENSE REGTOP-k layout (a_prev / s_prev / g_agg_prev J-vectors,
state_format="dense") — they are NOT part of the two-sweep fused
pipeline, whose state retired those vectors for err_prev + the O(k)
posterior (kernels/compress, DESIGN.md §2.2).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import auto_interpret as _interpret
from repro.kernels.fused_ef.kernel import BLOCK, apply_pallas, scores_pallas


def _pad(x, j_pad):
    return jnp.pad(x.astype(jnp.float32), (0, j_pad - x.shape[0]))


def fused_regtopk_scores(g, err, a_prev, g_agg, s_prev, *, omega, mu, Q):
    """(a, score) for the REGTOP-k selector; inputs (J,) any float dtype."""
    j = g.shape[0]
    j_pad = -(-j // BLOCK) * BLOCK
    a, score = scores_pallas(
        _pad(g, j_pad), _pad(err, j_pad), _pad(a_prev, j_pad),
        _pad(g_agg, j_pad), _pad(s_prev, j_pad),
        omega=float(omega), mu=float(mu), q=float(Q),
        interpret=_interpret())
    return a[:j], score[:j]


def fused_apply_mask(a, mask):
    """(ghat, err_new) = (mask*a, a - mask*a)."""
    j = a.shape[0]
    j_pad = -(-j // BLOCK) * BLOCK
    ghat, err = apply_pallas(_pad(a, j_pad), _pad(mask, j_pad),
                             interpret=_interpret())
    return ghat[:j], err[:j]
