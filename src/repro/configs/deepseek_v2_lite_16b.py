"""DeepSeek-V2-Lite 16B: MLA (kv_lora=512) + MoE 64e top-6, 2 shared.
[arXiv:2405.04434]

Layer 0 uses a dense FFN (n_dense_prefix=1), layers 1..26 are MoE.
Assignment numeric field "64e top-6" taken as canonical over the note's
"160 routed" (DESIGN.md §3).
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("deepseek-v2-lite-16b")
def deepseek_v2_lite() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        source="arXiv:2405.04434",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944,  # dense-prefix FFN dim (dsv2-lite intermediate)
        vocab_size=102400,
        rope=True, rope_theta=10_000.0,
        qkv_bias=False, norm="rmsnorm", act="silu",
        attn_kind="mla", kv_lora_rank=512, q_lora_rank=0,
        rope_head_dim=64, head_dim=128, v_head_dim=128,
        n_dense_prefix=1,
        moe=MoEConfig(n_experts=64, n_shared_experts=2, top_k=6,
                      d_expert=1408, moe_every=1),
    )
