"""Granite-MoE 3B (800M active): 40 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base]

Assignment sheet says "MoE 40e top-8" in the numeric field and "32 experts"
in the model-card note; the numeric field is taken as canonical (DESIGN.md §3).
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("granite-moe-3b-a800m")
def granite_moe() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155,
        rope=True, rope_theta=10_000.0,
        qkv_bias=False, norm="rmsnorm", act="silu",
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, moe_every=1),
    )
