from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SparsifierConfig,
    OptimizerConfig, MeshConfig, RunConfig, SHAPES,
    get_config, list_archs, register, reduced_config,
)
