"""Phi-3-vision: phi3-mini decoder + CLIP vision stub.
[hf:microsoft/Phi-3-vision-128k-instruct]

The vision tower (CLIP ViT-L/14) is a STUB per the assignment: input_specs()
provides precomputed patch embeddings (n_frontend_tokens x d_model) which the
decoder consumes prepended to the token embeddings.
"""
from repro.configs.base import ModelConfig, register


@register("phi-3-vision-4.2b")
def phi_3_vision() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        source="hf:microsoft/Phi-3-vision-128k-instruct",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        rope=True, rope_theta=10_000.0,
        qkv_bias=False, norm="rmsnorm", act="silu",
        frontend="vision_stub", n_frontend_tokens=576,  # 24x24 CLIP patches
    )
