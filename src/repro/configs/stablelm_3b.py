"""StableLM-2-3B-class dense decoder. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig, register


@register("stablelm-3b")
def stablelm_3b() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        rope=True, rotary_pct=0.25, rope_theta=10_000.0,
        qkv_bias=False, norm="layernorm", act="silu",
    )
