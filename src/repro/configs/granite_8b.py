"""Granite-8B code model, llama-arch, GQA kv=8. [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig, register


@register("granite-8b")
def granite_8b() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        source="arXiv:2405.04324",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=49152,
        rope=True, rope_theta=10_000.0,
        qkv_bias=False, norm="rmsnorm", act="silu",
    )
