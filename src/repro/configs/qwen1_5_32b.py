"""Qwen1.5-32B dense decoder with QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig, register


@register("qwen1.5-32b")
def qwen1_5_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        source="hf:Qwen/Qwen1.5-0.5B",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab_size=152064,
        rope=True, rope_theta=1_000_000.0,
        qkv_bias=True, norm="rmsnorm", act="silu",
    )
