"""Configuration dataclasses + registry for the repro framework.

Every assigned architecture registers a :class:`ModelConfig` via
:func:`register`. Input shapes are global (:data:`SHAPES`). Reduced ("smoke")
variants of every architecture are derived mechanically by
:func:`reduced_config` so CPU tests exercise the same code paths as the full
configs lowered in the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    n_shared_experts: int = 0     # always-on experts (deepseek-v2 style)
    top_k: int = 0
    d_expert: int = 0             # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    moe_every: int = 1            # MoE FFN on layers where
                                  # (idx % moe_every == moe_offset)
    moe_offset: int = 0
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"           # mamba | xlstm
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2               # d_inner = expand * d_model
    dt_rank: int = 0              # 0 -> ceil(d_model/16)
    # xlstm
    slstm_proj_factor: float = 4 / 3
    mlstm_proj_factor: float = 2.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    source: str                   # citation from the assignment sheet
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention
    rope: bool = True
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0       # stablelm uses partial rotary (0.25)
    qkv_bias: bool = False
    attn_kind: str = "full"       # full | sliding | mla
    window: int = 8192            # sliding window size
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0           # 0 -> head_dim
    mla_absorb: bool = False      # absorbed attention: score/combine in the
                                  # compressed kv_lora space (perf variant;
                                  # never materializes per-head K/V)
    # block structure
    attn_every: int = 1           # period of attention layers (jamba: 8); rest are SSM
    attn_offset: int = 0          # position of attn layer within the period
    n_dense_prefix: int = 0       # leading layers with dense FFN even if MoE (dsv2: 1)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_causal: bool = False
    # modality frontend stubs
    frontend: str = "none"        # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0    # patches (vlm) / frames (audio)
    # numerics / misc
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "silu"             # silu (swiglu) | gelu (plain mlp)
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 20
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """True if decode at 500k context needs no full-attention KV cache."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True  # jamba: attention layers still cache, but 1/8 of layers
        return self.attn_kind == "sliding"

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}


# ---------------------------------------------------------------------------
# Sparsifier / training configuration (the paper's knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SparsifierConfig:
    kind: str = "regtopk"         # none|topk|regtopk|randk|thresholdk|globaltopk|dgc
    sparsity: float = 0.01        # S = k / J
    k: int = 0                    # explicit k; 0 -> derive from sparsity
    mu: float = 0.1               # REGTOP-k regularizer temperature
    Q: float = 0.0                # posterior distortion for never-sent entries
    momentum: float = 0.9         # dgc momentum correction
    # density allocation (DESIGN.md §2.6, core/allocate.py): how the
    # global budget k = round(sparsity * J) splits across contiguous
    # segments of the flat gradient BEFORE selection.
    # - "global":       one global top-k (the paper; bit-identical to
    #   the pre-allocation pipeline — the allocation machinery is never
    #   entered).
    # - "proportional": k_l ~ k * J_l / J per segment (largest-remainder
    #   apportionment; per-layer top-k at uniform density when segments
    #   are layer-aligned).
    # - "adaptive":     k_l from per-segment second-moment (top-mass)
    #   statistics of the selection score, a la Adaptive Top-K — O(S)
    #   from sweep products the pipeline already makes, intensity-
    #   clipped to a bounded deviation from proportional.
    # Every mode conserves sum(k_l) == k exactly, so the packed pairs
    # and sparse-comm wire bytes are unchanged. Requires kind in
    # {topk, dgc, regtopk, thresholdk, randk} and selector="exact"
    # (allocate.check_allocation raises otherwise, never silent).
    allocation: str = "global"    # global | proportional | adaptive
    # segment count for allocation != "global": 0 resolves to the bucket
    # partition when num_buckets > 1 (segments follow buckets) else
    # allocate.DEFAULT_SEGMENTS; the train step overrides the near-equal
    # cut with layer-aligned TreeFlattener bounds (allocate.
    # layer_segments), which this count caps.
    num_segments: int = 0
    comm_mode: str = "simulate"   # simulate | sparse | dense
    selector: str = "exact"       # exact | histogram (threshold selection,
                                  # count in [k, k*(1+slack)]; fused via the
                                  # sweep-1 bit-pattern histogram)
    ef_dtype: str = "float32"     # error-feedback accumulator dtype
    # wire dtype of the PACKED VALUES in comm_mode="sparse": the
    # all-gather payload is cast (values only — indices stay uint32)
    # before the collective and upcast to fp32 in the scatter-add
    # combine. "bfloat16" cuts sparse wire bytes by 25% (8 -> 6 bytes
    # per pair) at bf16 rounding of the combined g_agg (tolerance
    # contract in tests/test_fused_configs.py::TestWireBf16). Identical
    # on every rank, so REGTOP-k's shared-g_agg assumption holds.
    wire_dtype: str = "float32"   # float32 | bfloat16
    # sketchtopk (beyond-paper): CountSketch-coordinated global TOP-k
    sketch_rows: int = 3
    sketch_width: int = 0         # 0 -> min(max(4k, 256), 2^22)
    # regtopk posterior-state layout: "dense" keeps 3 extra J-sized fp32
    # vectors (paper-literal); "sparse" stores only the k selected entries
    # (a_prev, g_agg_prev needed ONLY where s_prev=1 — Algorithm 1 line 5),
    # cutting state memory from 4J fp32 to J + O(k). Bit-identical updates.
    state_format: str = "dense"   # dense | sparse
    # compression execution pipeline (DESIGN.md §2.2, capability table
    # §2.5 / kernels.compress.dispatch):
    # - "reference": dense paper-literal math + cfg.selector selection.
    #   The parity oracle; O(J log k) selection and ~8 O(J) HBM passes
    #   per step.
    # - "fused": two-sweep pipeline (kernels/compress). Sweep 1 reads the
    #   dense inputs exactly once and emits (a, score); sweep 2 compacts
    #   fixed-size (values, indices) without a full-array sort. The only
    #   J-sized state is err_prev = a * (1 - s), written by an O(k)
    #   scatter-zero (no dense mask exists; the whole step is 2 O(J)
    #   traversals), and the posterior state is
    #   O(k). Serves kind in {topk, dgc, regtopk, randk, thresholdk},
    #   selector in {exact, histogram}, ef_dtype in {float32, bfloat16}:
    #   selector="exact" is bit-identical to "reference"; "histogram"
    #   keeps the threshold contract (count in [k, k*(1+slack)], tau at
    #   a bit-pattern bin edge); bf16 EF stores the J-sized state in
    #   bf16 with fp32 in-register sweep math (bf16-rounding tolerance
    #   vs the fp32 reference). Configs outside the table use the
    #   reference path — the decision and its reason are queryable via
    #   kernels.compress.dispatch.dispatch(cfg), never silent.
    pipeline: str = "reference"   # reference | fused
    # bucketed compression (DESIGN.md §2.4): partition the flat gradient
    # into num_buckets contiguous buckets; the fused sweeps run per bucket
    # with an O(num_buckets x BINS) histogram-merge global threshold, and
    # comm_mode="sparse" all-gathers the packed pairs in num_buckets
    # chunks so bucket i's collective overlaps bucket i+1's local
    # scatter-add compaction. Selection semantics are bucketing-invariant
    # (bit-identical to num_buckets=1); 1 disables bucketing; 0 auto-tunes
    # the count from the sparse-collective payload vs the interconnect
    # latency floor (roofline.analysis.auto_num_buckets — resolved where
    # the data-parallel worker count is known, deterministically, so 0 is
    # bit-identical to passing the resolved value manually).
    num_buckets: int = 1
    # elastic aggregation (DESIGN.md §2.7): EF decay applied to a
    # worker's err_prev (and dgc momentum) on steps it sits out of the
    # sync (err' = err_decay * err). 1.0 freezes the state untouched;
    # < 1.0 bleeds off stale error mass so a rejoining worker does not
    # inject an exploded correction. Irrelevant (never applied) at full
    # participation.
    err_decay: float = 1.0
    # combine rule for the sparse all-gather under partial
    # participation: "mean" divides the summed dense vector by
    # n_active (== today's sum/n at full participation, bit-identical
    # when the participation mask is None/all-ones); "support" divides
    # each coordinate by the count of workers that actually SELECTED
    # it (rTop-k's estimation view), falling back to 0 where no worker
    # selected.
    combine: str = "mean"         # mean | support
    # backward-overlapped streaming compression (DESIGN.md §2.8):
    # "backward" feeds the gradient into the fused pipeline per
    # layer-aligned segment as the VJP emits it — each segment's sweep-1
    # (+ EF fold + adaptive-allocation statistics) depends only on that
    # segment's leaves, so XLA schedules it behind the remaining
    # backward work; the global trim/pack and the sparse collective are
    # the only tail barrier. Selection, packed order, and err_prev are
    # bit-identical to "none" (streaming reorders WHEN sweeps run, not
    # how many — the 2-traversal / 2-write-unit audit budget is
    # unchanged). Requires pipeline="fused" and a fused-dispatch config
    # (kernels.compress.dispatch.check_overlap raises otherwise, never
    # silent); segment granularity follows SparsifierConfig.num_segments
    # via the layer-aligned bounds the train step already builds.
    overlap: str = "none"         # none | backward


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"             # sgd | momentum | adam | adamw
    lr: float = 1e-2
    momentum: float = 0.9
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    warmup_steps: int = 0
    schedule: str = "constant"    # constant | cosine
    total_steps: int = 10_000
    zero1: bool = True            # shard optimizer state over data axis


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def axes(self):
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")

    @property
    def shape(self):
        return ((self.pods, self.data, self.model) if self.pods > 1
                else (self.data, self.model))

    @property
    def n_devices(self) -> int:
        return self.pods * self.data * self.model


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    sparsifier: SparsifierConfig = SparsifierConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    mesh: MeshConfig = MeshConfig()
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    microbatch: int = 0           # RESERVED (grad accumulation) — not implemented
    attn_override: str = ""       # e.g. "sliding" for long_500k on dense archs
    checkpoint_dir: str = ""
    checkpoint_every: int = 0
    # fault-injection schedule spec (core/faults.py grammar: "iid:0.3",
    # "bursty:period=16,outage=4,workers=1+3", "permanent:step=8",
    # "" = always-on full participation).
    fault_schedule: str = ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "stablelm_3b", "starcoder2_7b", "qwen1_5_32b", "phi_3_vision_4_2b",
    "granite_8b", "granite_moe_3b_a800m", "xlstm_125m", "whisper_small",
    "jamba_v0_1_52b", "deepseek_v2_lite_16b",
]


def _load_all() -> None:
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


# ---------------------------------------------------------------------------
# Reduced (smoke) variants
# ---------------------------------------------------------------------------

def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny member of the same architecture family for CPU smoke tests.

    2 layers (one full super-block period if heterogeneous), d_model<=256,
    <=4 experts, small vocab. Exercises every code path of the full config.
    """
    period = max(cfg.attn_every, 2 if cfg.family == "ssm" else 1)
    if cfg.moe is not None:
        period = max(period, cfg.moe.moe_every)
    n_layers = max(2, period) + cfg.n_dense_prefix
    d_model = 128
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, n_heads)
    if n_heads % n_kv:
        n_kv = 2
    moe = None
    if cfg.moe is not None:
        moe = replace(cfg.moe, n_experts=4, top_k=min(2, cfg.moe.top_k),
                      n_shared_experts=min(1, cfg.moe.n_shared_experts),
                      d_expert=64)
    ssm = cfg.ssm
    if ssm is not None:
        ssm = replace(ssm, d_state=8, d_conv=4)
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        q_lora_rank=0,
        rope_head_dim=16 if cfg.kv_lora_rank else 64,
        v_head_dim=32 if cfg.v_head_dim else 0,
        n_dense_prefix=cfg.n_dense_prefix,
        moe=moe,
        ssm=ssm,
        n_frontend_tokens=(min(cfg.n_frontend_tokens, 16)
                           if cfg.n_frontend_tokens else 0),
        window=64,
        dtype="float32",
        max_seq_len=4096,
    )
