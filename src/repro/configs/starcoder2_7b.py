"""StarCoder2-7B dense decoder, GQA kv=4, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig, register


@register("starcoder2-7b")
def starcoder2_7b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        source="arXiv:2402.19173",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18432, vocab_size=49152,
        rope=True, rope_theta=100_000.0,
        qkv_bias=True, norm="layernorm", act="gelu",
    )
