"""Whisper-small encoder-decoder. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
input_specs() provides precomputed frame embeddings (1500 x d_model) for the
encoder; encoder (12L, bidirectional) and decoder (12L, causal + cross-attn)
transformers are fully implemented.
"""
from repro.configs.base import ModelConfig, register


@register("whisper-small")
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        source="arXiv:2212.04356",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        rope=False, norm="layernorm", act="gelu",
        qkv_bias=True,
        is_encoder_decoder=True, n_enc_layers=12,
        frontend="audio_stub", n_frontend_tokens=1500,
    )
