"""Jamba-v0.1 52B hybrid: Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]

Super-block of 8 layers: attention at position 4 (attn_every=8, attn_offset=4),
Mamba elsewhere; MoE FFN on odd positions (moe_every=2, moe_offset=1).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register


@register("jamba-v0.1-52b")
def jamba() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        source="arXiv:2403.19887",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        rope=False,  # jamba uses no positional encoding (Mamba provides order)
        qkv_bias=False, norm="rmsnorm", act="silu",
        attn_every=8, attn_offset=4,
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336,
                      moe_every=2, moe_offset=1),
        ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
    )
