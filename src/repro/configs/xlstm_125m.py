"""xLSTM-125M: alternating mLSTM / sLSTM blocks. [arXiv:2405.04517]

d_ff=0 in the assignment: blocks carry their own up/down projections
(mLSTM proj factor 2, sLSTM proj factor 4/3), no separate FFN.
"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("xlstm-125m")
def xlstm_125m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        source="arXiv:2405.04517",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        rope=False, norm="layernorm", act="gelu",
        attn_every=0,  # no attention layers at all
        ssm=SSMConfig(kind="xlstm", d_state=16, d_conv=4),
    )
