"""Pytree <-> flat-vector utilities for whole-model sparsification.

The paper treats the model as a single J-dimensional vector (flat-J
sparsification). ``TreeFlattener`` caches the unravel function and leaf
layout so the hot path is a single concatenate / split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class TreeFlattener:
    """Flattens a gradient pytree to one fp vector and back.

    Built once from an abstract (or concrete) example tree; ``flatten`` and
    ``unflatten`` are then pure jnp ops safe under jit/shard_map.
    """

    def __init__(self, example_tree, dtype=jnp.float32):
        leaves, self.treedef = jax.tree_util.tree_flatten(example_tree)
        self.shapes = [l.shape for l in leaves]
        self.sizes = [int(l.size) for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.offsets = []
        off = 0
        for s in self.sizes:
            self.offsets.append(off)
            off += s
        self.total = off
        self.dtype = dtype

    def flatten(self, tree) -> jnp.ndarray:
        leaves = jax.tree_util.tree_leaves(tree)
        if not leaves:
            return jnp.zeros((0,), self.dtype)
        return jnp.concatenate(
            [jnp.ravel(l).astype(self.dtype) for l in leaves])

    def unflatten(self, vec: jnp.ndarray):
        leaves = []
        for off, size, shape, dt in zip(self.offsets, self.sizes,
                                        self.shapes, self.dtypes):
            leaves.append(jax.lax.dynamic_slice_in_dim(
                vec, off, size).reshape(shape).astype(dt))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def flatten_segments(self, tree, bounds) -> list:
        """Per-segment flats for streaming compression (DESIGN.md §2.8).

        ``bounds`` is a leaf-aligned contiguous partition of [0, total)
        — (offset, size) pairs such as ``core.allocate.layer_segments``
        over :meth:`layer_bounds`. Returns one flat array per segment,
        each built ONLY from that segment's leaves (no global
        concatenate), so a segment's compression sweep depends on
        nothing produced after its last leaf's gradient — which is what
        lets XLA schedule it behind the remaining backward pass under
        ``overlap="backward"``. ``concatenate(result) == flatten(tree)``
        bitwise."""
        leaves = jax.tree_util.tree_leaves(tree)
        segs, li = [], 0
        for off, size in bounds:
            if li >= len(self.offsets) or self.offsets[li] != off:
                raise ValueError(
                    f"segment offset {off} is not leaf-aligned "
                    f"(leaf offsets: {self.offsets[li:li + 2]}...)")
            parts, have = [], 0
            while have < size:
                parts.append(jnp.ravel(leaves[li]).astype(self.dtype))
                have += self.sizes[li]
                li += 1
            if have != size:
                raise ValueError(
                    f"segment (off={off}, size={size}) cuts inside a leaf")
            segs.append(parts[0] if len(parts) == 1
                        else jnp.concatenate(parts))
        if li != len(leaves):
            raise ValueError("bounds do not cover every leaf")
        return segs

    def layer_bounds(self) -> list:
        """Per-leaf (offset, size) metadata of the flat vector — the
        layer-aligned segmentation source for density allocation:
        ``core.allocate.layer_segments`` groups these into the segment
        bounds the train step hands ``aggregate.sync_gradient`` when
        ``SparsifierConfig.allocation != "global"`` (DESIGN.md §2.6).
        Static Python ints (safe to bake into traced code)."""
        return list(zip(self.offsets, self.sizes))


def bucket_bounds(j: int, num_buckets: int) -> list:
    """Contiguous near-equal partition of [0, j) into buckets.

    Returns [(offset, size), ...] with sizes differing by at most one and
    sum(sizes) == j. The bucketed compression pipeline (DESIGN.md §2.4)
    sweeps each bucket independently and merges their bit-pattern
    histograms into one global threshold, so the partition must be
    deterministic and order-preserving (global index = offset + local).
    num_buckets is clamped to [1, j] (a bucket is never empty).

    The density-allocation subsystem (DESIGN.md §2.6) reuses this exact
    rule for its near-equal segment cut (``core.allocate.segment_bounds``
    delegates here), so segments and buckets coincide whenever
    ``num_segments`` follows ``num_buckets``.
    """
    b = max(1, min(int(num_buckets), max(j, 1)))
    base, rem = divmod(j, b)
    bounds, off = [], 0
    for i in range(b):
        size = base + (1 if i < rem else 0)
        bounds.append((off, size))
        off += size
    return bounds


def tree_size(tree) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(tree))


def ravel(tree):
    """One-shot ravel (test convenience)."""
    vec, unravel = ravel_pytree(tree)
    return vec, unravel
