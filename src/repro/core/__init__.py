"""Core: the paper's contribution — Bayesian gradient sparsification (REGTOP-k)."""
from repro.core.sparsify import (
    CompressOut, compress, init_state, observe_aggregate, resolve_k,
    sparsified_round,
)
from repro.core.aggregate import (
    GradientSync, comm_bytes_per_step, dense_allreduce,
    sparse_allgather_combine, sync_gradient,
)
from repro.core.select import topk_mask, topk_mask_exact, histogram_threshold
from repro.core.flatten import TreeFlattener, tree_size
