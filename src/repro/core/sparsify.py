"""Gradient sparsifiers: TOP-k, REGTOP-k (the paper, Algorithm 1), and baselines.

All sparsifiers are functional and operate on a flat fp32 vector ``g`` (one
data-parallel worker's gradient, or its model-parallel shard). The state is a
small pytree carried through the training loop.

Protocol per step (worker n):

    out = compress(cfg, state, g, key)     # local: mask + sparsified gradient
    g_agg = aggregate(out.ghat over data axis)   # see core/aggregate.py
    state = observe_aggregate(cfg, out.state, g_agg)  # REGTOP-k stores g^t

``observe_aggregate`` is a no-op for history-free sparsifiers.

REGTOP-k (Algorithm 1 of the paper):
    a^t      = eps^t + g^t
    Delta^t  = s^{t-1} * (g_agg^{t-1} - w_n a^{t-1}) / (w_n a^t) + Q (1 - s^{t-1})
    s^t      = Top_k( a^t * tanh(|1 + Delta^t| / mu) )
    ghat^t   = s^t * a^t
    eps^{t+1}= a^t - ghat^t
with plain TOP-k at t=0. mu -> 0 recovers TOP-k exactly.

Execution pipelines (cfg.pipeline, DESIGN.md §2.2):

- "reference": the dense math above, selection via cfg.selector. Oracle.
- "fused": two-sweep pipeline (repro.kernels.compress) for kind in
  {topk, dgc, regtopk, randk, thresholdk}. The ONLY J-sized state is
  ``err_prev`` = eps^{t+1} = a^t * (1 - s^t), written by an O(k)
  scatter that zeroes the selected slots of ``a`` after the trim — no
  dense mask exists anywhere (CompressOut.mask is None on this path;
  reconstruct one on demand with :func:`dense_mask`), and REGTOP-k's
  posterior is O(k) (idx_prev, a_prev_sel, g_prev_sel), since
  Algorithm 1 line 5 reads a^{t-1} and g^{t-1} only at the support of
  s^{t-1} — idx_prev doubles as that support set. With
  selector="exact" the selected support is bit-identical to
  "reference"; selector="histogram" keeps the threshold-selection
  contract (count in [k, k*(1+HIST_SLACK)], tau at a bit-pattern bin
  edge); ef_dtype="bfloat16" stores the J-sized EF state in bf16 with
  fp32 in-register sweep math. In comm_mode="sparse" no dense ghat is
  materialized (CompressOut.ghat is None and the packed
  (values, indices) drive the all-gather) and the whole step is TWO
  O(J) traversals (DESIGN.md §2.2). Which path serves a config is an
  explicit table — repro.kernels.compress.dispatch (DESIGN.md §2.5) —
  not an opaque boolean.

Density allocation (cfg.allocation, DESIGN.md §2.6, core/allocate.py):
both pipelines can split the budget sum(k_l) == k across contiguous
segments (near-equal, or layer-aligned bounds passed by the train step)
before selection — "proportional" (k_l ~ J_l) and "adaptive" (k_l from
per-segment second-moment statistics). "global" is the default and is
bit-identical to the pre-allocation pipeline. State layouts, packed
shapes, and wire bytes are allocation-invariant.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SparsifierConfig
from repro.core import select
from repro.core.numerics import safe_denom


@dataclass
class CompressOut:
    ghat: Optional[jnp.ndarray]  # dense sparsified gradient (J,); None for
                                 # pipeline="fused" + comm_mode="sparse"
                                 # (reconstructible from values/indices)
    mask: Optional[jnp.ndarray]  # dense 0/1 selection mask (J,) on the
                                 # reference path; None on the fused path
                                 # (no dense mask is ever materialized —
                                 # derive one on demand via dense_mask())
    state: Any               # updated state (pre-aggregation)
    values: Optional[jnp.ndarray] = None  # (k,) packed values (exact selector)
    indices: Optional[jnp.ndarray] = None  # (k,) uint32 indices
    count: Optional[jnp.ndarray] = None   # live packed slots (() int32);
                                          # None means all slots are live


def resolve_k(cfg: SparsifierConfig, j: int) -> int:
    if cfg.k:
        return int(min(cfg.k, j))
    return max(1, int(round(cfg.sparsity * j)))


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def resolve_num_buckets(cfg: SparsifierConfig, j: int,
                        n_workers: int = 1) -> int:
    """cfg.num_buckets, with 0 resolved to the auto-tuned value.

    The auto-tune (ROADMAP item, DESIGN.md §2.4) derives the bucket
    count from the sparse-collective payload this config moves —
    n_workers * packed_len * 8 bytes — against the interconnect latency
    floor, via the roofline pipelined-overlap model
    (roofline.analysis.auto_num_buckets). Deterministic in (cfg, j,
    n_workers), so a manual ``num_buckets=<resolved>`` flag reproduces
    the auto choice bit-for-bit (bucketing never changes selection
    semantics either way)."""
    if cfg.num_buckets != 0:
        return max(1, int(cfg.num_buckets))
    from repro.kernels.compress.dispatch import packed_len
    from repro.roofline.analysis import auto_num_buckets
    return auto_num_buckets(packed_len(cfg, j), n_workers)


def _workers_from_omega(omega) -> int:
    """Equal-weight worker count implied by omega = 1/N (the only
    information a bare compress() call has for the bucket auto-tune;
    sync_gradient resolves from the real mesh axis size instead). A
    TRACED omega is a hard error, not a silent N=1: auto_num_buckets
    would mis-tune the payload by the real worker count — resolve the
    bucket count upstream (resolve_num_buckets / sync_gradient) in
    that case."""
    if isinstance(omega, jax.core.Tracer):
        raise TypeError(
            "num_buckets=0 auto-tune inside compress() needs a concrete "
            "omega (= 1/N) to infer the worker count; with a traced "
            "omega, resolve the bucket count upstream via "
            "sparsify.resolve_num_buckets or aggregate.sync_gradient.")
    try:
        return max(1, int(round(1.0 / float(omega))))
    except (TypeError, ValueError, ZeroDivisionError):
        return 1


def init_state(cfg: SparsifierConfig, j: int) -> dict:
    """Zero-initialized per-worker sparsifier state for a J-length flat
    gradient.

    Shapes/dtypes by layout (all vectors cfg.ef_dtype unless noted):

    - fused (dispatch(cfg).path == "fused"): ``err_prev`` (J,) — the ONE
      J-sized vector — plus ``step`` () int32; DGC adds ``mom`` (J,);
      REGTOP-k adds the O(k) posterior ``idx_prev`` (kp,) uint32 /
      ``a_prev_sel`` / ``g_prev_sel`` (kp,) with kp = packed_len(cfg, j)
      (and ``nsel`` () int32 for the histogram selector's live count).
    - reference: ``err`` (J,) for the EF kinds; DGC adds ``mom`` (J,);
      REGTOP-k state_format="dense" adds (a_prev, s_prev, g_agg_prev)
      (J,) each, state_format="sparse" the O(k) triple instead.

    Layout parity across pipelines is pinned by
    tests/test_state_traffic.py (err_prev == reference err bitwise) and
    tests/test_checkpoint.py (round-trip + legacy migration). Density
    allocation adds NO state — every mode reuses these layouts.
    """
    from repro.kernels.compress.dispatch import dispatch
    dt = jnp.dtype(cfg.ef_dtype)
    z = jnp.zeros((j,), dt)
    if dispatch(cfg).path == "fused":
        # ONE J-sized state vector: err_prev = a^{t-1} * (1 - s^{t-1}),
        # maintained by the O(k) scatter-zero that closes each step (no
        # dense mask exists in the fused layout)
        st = {
            "err_prev": z,
            "step": jnp.zeros((), jnp.int32),
        }
        if cfg.kind == "dgc":
            st["mom"] = z
        if cfg.kind == "regtopk":
            from repro.kernels.compress.dispatch import packed_len
            kp = packed_len(cfg, j)   # k, or hist_capacity for histogram
            st["idx_prev"] = jnp.zeros((kp,), jnp.uint32)
            st["a_prev_sel"] = jnp.zeros((kp,), dt)
            st["g_prev_sel"] = jnp.zeros((kp,), dt)
            if cfg.selector == "histogram":
                # live-slot count of the fixed-capacity posterior state
                st["nsel"] = jnp.zeros((), jnp.int32)
        return st
    if cfg.kind in ("none", "globaltopk"):
        return {"step": jnp.zeros((), jnp.int32)}
    if cfg.kind in ("topk", "randk", "thresholdk", "sketchtopk"):
        return {"err": z, "step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "dgc":
        return {"err": z, "mom": z, "step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "regtopk":
        if cfg.state_format == "sparse":
            k = resolve_k(cfg, j)
            return {
                "err": z,                                  # eps^t
                "idx_prev": jnp.zeros((k,), jnp.uint32),   # support of s^{t-1}
                "a_prev_sel": jnp.zeros((k,), dt),         # a^{t-1}[idx]
                "g_prev_sel": jnp.zeros((k,), dt),         # g^{t-1}[idx]
                "step": jnp.zeros((), jnp.int32),
            }
        return {
            "err": z,                  # eps^t
            "a_prev": z,               # a^{t-1}
            "s_prev": jnp.zeros((j,), dt),   # s^{t-1}
            "g_agg_prev": z,           # g^{t-1} (aggregated, observed)
            "step": jnp.zeros((), jnp.int32),
        }
    raise ValueError(f"unknown sparsifier {cfg.kind!r}")


# ---------------------------------------------------------------------------
# Compressors
# ---------------------------------------------------------------------------

def _pack(a: jnp.ndarray, score: jnp.ndarray, k: int):
    from repro.core import bigvec
    idx = select.topk_indices(score, k)       # uint32 (J may exceed int32)
    vals = bigvec.gather(a, idx)
    return vals, idx


def _mask_from(score: jnp.ndarray, k: int, method: str) -> jnp.ndarray:
    return select.topk_mask(score, k, method)


def _reference_select(cfg: SparsifierConfig, a: jnp.ndarray,
                      score: jnp.ndarray, k: int, seg_bounds=None):
    """(mask, vals, idx) for the reference pipeline's score-based kinds.

    allocation="global": cfg.selector selection over the whole vector
    (vals/idx packed for selector="exact" only). Other allocation modes
    (DESIGN.md §2.6) select per segment via the shared allocated
    selector — exact-count by construction, so packed pairs always
    exist. ``a`` is the error-compensated accumulator the packed values
    are read from; ``score`` the (possibly REGTOP-k-corrected) selection
    score."""
    if cfg.allocation != "global":
        from repro.core import allocate
        return allocate.reference_allocated_select(cfg, a, score, k,
                                                   seg_bounds=seg_bounds)
    mask = _mask_from(score, k, cfg.selector)
    vals = idx = None
    if cfg.selector == "exact":
        vals, idx = _pack(a, score, k)
    return mask, vals, idx


def compress(cfg: SparsifierConfig, state: dict, g: jnp.ndarray,
             key: Optional[jax.Array] = None, omega: float = 1.0,
             seg_bounds=None, participate=None,
             g_segments=None) -> CompressOut:
    """Sparsify one worker's flat gradient. omega = this worker's weight w_n.

    Inputs: ``g`` (J,) fp gradient (cast to cfg.ef_dtype); ``state`` the
    pytree from :func:`init_state`. Returns a :class:`CompressOut`; cost
    is O(J) sweeps + O(k) packing on both pipelines (2 O(J) traversals
    fused sparse-comm, ~8 reference — DESIGN.md §2.2/§2.3, pinned by
    tests/test_state_traffic.py and tests/test_bucketed.py).

    cfg.pipeline selects the execution path: "reference" (dense math,
    cfg.selector) or "fused" (two-sweep kernels/compress pipeline). The
    dispatch decision is the explicit capability table in
    repro.kernels.compress.dispatch (DESIGN.md §2.5); configs outside it
    use the reference path, with the reason queryable via dispatch(cfg).

    cfg.allocation != "global" (DESIGN.md §2.6) splits the budget
    sum(k_l) == k across contiguous segments before selection on BOTH
    pipelines — ``seg_bounds`` optionally pins the segmentation (static
    [(offset, size), ...], e.g. layer-aligned bounds from
    TreeFlattener.layer_bounds); by default segments are the near-equal
    allocate.resolve_num_segments cut. Unsupported allocation combos
    raise ValueError here (allocate.check_allocation), never degrade
    silently.

    ``participate`` (DESIGN.md §2.7): optional traced () bool — this
    worker's elastic participation bit for the step. None (default) is
    literally the pre-elastic code path. With a bit, a sitting-out
    worker returns an inert payload (zero values/mask/ghat, count 0),
    its error feedback decays in place (err' = cfg.err_decay * err, DGC
    mom' = cfg.momentum * mom), and REGTOP-k's posterior freezes;
    ``participate=True`` is a bitwise pass-through. Both pipelines share
    the masked-input helper (kernels.compress.ops.masked_inputs), so
    their post-step states stay bit-comparable under any mask.

    cfg.overlap="backward" (DESIGN.md §2.8): the fused sweeps partition
    by the stream segments so compression can run behind the backward
    pass. ``g_segments`` feeds the gradient as per-segment arrays (the
    train step's streaming form; ``g`` must then be None); with a flat
    ``g`` the vector is sliced into the resolved stream partition
    internally, so benches and audits see the streaming program without
    a train loop. Output is BIT-identical to overlap="none" either way
    (selection is partition-invariant); unsupported configs raise via
    kernels.compress.dispatch.check_overlap, never degrade silently.
    """
    if g_segments is not None:
        if g is not None:
            raise ValueError("pass g or g_segments, not both")
        if cfg.overlap != "backward":
            raise ValueError("g_segments requires overlap='backward'")
        j = int(sum(gs.shape[0] for gs in g_segments))
    else:
        j = g.shape[0]
    k = resolve_k(cfg, j)
    dt = jnp.dtype(cfg.ef_dtype)
    if g is not None:
        g = g.astype(dt)
    pf = None
    if participate is not None:
        pf = jnp.asarray(participate, jnp.bool_)
    if cfg.num_buckets == 0:
        cfg = dataclasses.replace(cfg, num_buckets=resolve_num_buckets(
            cfg, j, _workers_from_omega(omega)))
    if cfg.allocation != "global":
        # AFTER bucket auto-resolution: num_segments=0 follows the
        # RESOLVED bucket count (segments and buckets coincide)
        from repro.core import allocate
        allocate.check_allocation(cfg)
        if seg_bounds is None and g_segments is None:
            seg_bounds = allocate.segment_bounds(
                j, allocate.resolve_num_segments(cfg, j))

    stream_bounds = None
    if cfg.overlap != "none":
        from repro.kernels.compress.dispatch import check_overlap
        check_overlap(cfg)           # fused-dispatch configs only
        if g_segments is not None:
            g_segments = [gs.astype(dt) for gs in g_segments]
            off = 0
            stream_bounds = []
            for gs in g_segments:
                stream_bounds.append((off, gs.shape[0]))
                off += gs.shape[0]
            if cfg.allocation != "global":
                # one partition drives both the stream and the
                # allocation (the train step builds them from the same
                # layer-aligned bounds)
                if seg_bounds is None:
                    seg_bounds = stream_bounds
                elif [tuple(b) for b in seg_bounds] != stream_bounds:
                    raise ValueError(
                        "streaming with allocation != 'global' needs "
                        "seg_bounds == the g_segments partition")
        else:
            # flat g + overlap="backward": slice into the stream
            # partition here so the streaming program structure is
            # exercised (and audited) without a segment-feeding caller
            if cfg.allocation != "global":
                stream_bounds = [tuple(b) for b in seg_bounds]
            else:
                from repro.core import allocate
                stream_bounds = allocate.segment_bounds(
                    j, allocate.resolve_num_segments(cfg, j))
            g_segments = [g[o:o + sz] for o, sz in stream_bounds]
            g = None

    from repro.kernels.compress.dispatch import dispatch
    if dispatch(cfg).path == "fused":
        return _compress_fused(cfg, state, g, k, omega, key, seg_bounds,
                               participate=pf, g_segments=g_segments,
                               stream_bounds=stream_bounds)

    if pf is not None and "err" in state:
        # reference oracle under elastic participation: the SAME masked
        # effective inputs as the fused pipeline (g_eff = where(p, g, 0),
        # err_eff = where(p, err, err_decay * err)), so both pipelines'
        # post-step states stay bit-comparable under any mask
        from repro.kernels.compress import ops as _cops
        g, err_eff, pf = _cops.masked_inputs(g, state["err"], pf,
                                             cfg.err_decay)
        state = dict(state, err=err_eff)

    if cfg.kind == "none":
        ones = jnp.ones((j,), dt)
        if pf is not None:
            g = jnp.where(pf, g, jnp.zeros_like(g))
            ones = jnp.where(pf, ones, jnp.zeros_like(ones))
        return CompressOut(g, ones, {"step": state["step"] + 1})

    if cfg.kind == "globaltopk":
        # Genie sparsifier: the mask is decoded from the AGGREGATED
        # accumulated gradient, so there is no per-worker compress step —
        # aggregate.GradientSync serves it (dispatch selection="global").
        raise RuntimeError("globaltopk is aggregate-level; run it through "
                           "aggregate.GradientSync (sync or round)")

    if cfg.kind == "sketchtopk":
        # Sketch-coordinated selection: the shared mask exists only after
        # the sketch all-reduce — aggregate.GradientSync runs the whole
        # step (dispatch selection="sketch"; the per-worker half is
        # kernels.compress.ops.fused_sketch_encode).
        raise RuntimeError("sketchtopk selection is aggregate-level; run "
                           "it through aggregate.GradientSync (sync or "
                           "round)")

    if cfg.kind == "topk":
        a = state["err"] + g
        mask, vals, idx = _reference_select(cfg, a, a, k, seg_bounds)
        mask, vals, idx, count = _mask_elastic(pf, mask, vals, idx, k)
        ghat = mask * a
        new = {"err": a - ghat, "step": state["step"] + 1}
        return CompressOut(ghat, mask, new, vals, idx, count)

    if cfg.kind == "randk":
        a = state["err"] + g
        assert key is not None, "randk needs a PRNG key"
        # uint32 indices + bigvec indexing end to end: select.randk_indices
        # samples the k-subset as top-k of random bits (J > 2^31 safe —
        # no int32-bound jax.random.choice permutation sort)
        from repro.core import bigvec
        if cfg.allocation != "global":
            # score-free selection: allocation draws a uniform k_l-subset
            # per segment with the PROPORTIONAL counts (same shared
            # sampler as the fused path -> identical index streams)
            from repro.core import allocate
            counts = allocate.proportional_counts(
                k, [sz for _, sz in seg_bounds])
            idx = allocate.randk_allocated_indices(key, seg_bounds, counts)
        else:
            idx = select.randk_indices(key, j, k)
        mask = bigvec.mask_from_indices(j, idx, dt)
        vals = bigvec.gather(a, idx)
        mask, vals, idx, count = _mask_elastic(pf, mask, vals, idx, k)
        ghat = mask * a
        return CompressOut(ghat, mask,
                           {"err": a - ghat, "step": state["step"] + 1},
                           vals, idx, count)

    if cfg.kind == "thresholdk":
        # Strom'15-style magnitude thresholding, ADAPTIVE per step: the
        # threshold is re-derived from the current accumulator every step
        # (the k-th magnitude for selector="exact", the histogram bin edge
        # for selector="histogram") — not Strom's original fixed
        # first-step threshold, which stalls under shifting gradient
        # scales. Selection therefore coincides with topk; the kind
        # exists as the threshold-family baseline.
        a = state["err"] + g
        mask, vals, idx = _reference_select(cfg, a, a, k, seg_bounds)
        mask, vals, idx, count = _mask_elastic(pf, mask, vals, idx, k)
        ghat = mask * a
        new = {"err": a - ghat, "step": state["step"] + 1}
        return CompressOut(ghat, mask, new, vals, idx, count)

    if cfg.kind == "dgc":
        # Deep Gradient Compression [Lin et al. '18]: momentum correction.
        mom = cfg.momentum * state["mom"] + g
        # elastic gate, same select as the fused sweep: a sitting-out
        # worker's a excludes the momentum stream (so err decays in
        # place) while mom still advances to cfg.momentum * mom
        am = mom if pf is None else jnp.where(pf, mom, 0.0)
        a = state["err"] + am
        mask, vals, idx = _reference_select(cfg, a, a, k, seg_bounds)
        mask, vals, idx, count = _mask_elastic(pf, mask, vals, idx, k)
        ghat = mask * a
        new = {"err": a - ghat, "mom": mom * (1.0 - mask), "step": state["step"] + 1}
        return CompressOut(ghat, mask, new, vals, idx, count)

    if cfg.kind == "regtopk":
        if cfg.state_format == "sparse":
            return _compress_regtopk_sparse(cfg, state, g, k, omega, pf)
        a = state["err"] + g
        # posterior distortion (Algorithm 1, line 5); safe-divide where a ~ 0
        safe = safe_denom(omega * a)
        delta_sent = (state["g_agg_prev"] - omega * state["a_prev"]) / safe
        delta = state["s_prev"] * delta_sent + cfg.Q * (1.0 - state["s_prev"])
        reg = jnp.tanh(jnp.abs(1.0 + delta) / cfg.mu)
        score = a * reg
        is_first = state["step"] == 0
        score = jnp.where(is_first, a, score)   # t=0: plain TOP-k
        mask, vals, idx = _reference_select(cfg, a, score, k, seg_bounds)
        mask, vals, idx, count = _mask_elastic(pf, mask, vals, idx, k)
        ghat = mask * a
        new = {
            "err": a - ghat,
            "a_prev": a,
            "s_prev": mask,
            "g_agg_prev": state["g_agg_prev"],  # replaced by observe_aggregate
            "step": state["step"] + 1,
        }
        if pf is not None:
            # posterior freeze: a sitting-out worker neither sent nor
            # observed anything, so Algorithm 1's t-1 quantities stay
            # those of its LAST participating step
            new["a_prev"] = jnp.where(pf, a, state["a_prev"])
            new["s_prev"] = jnp.where(pf, mask, state["s_prev"])
        return CompressOut(ghat, mask, new, vals, idx, count)

    raise ValueError(f"unknown sparsifier {cfg.kind!r}")


def _mask_elastic(pf, mask, vals, idx, k: int):
    """Reference-path elastic payload masking (DESIGN.md §2.7): a
    sitting-out worker's dense mask and packed pairs come back inert
    (mask 0, values 0.0, indices 0, count 0). pf=None (or a True bit)
    passes everything through bitwise; count is None when all slots are
    unconditionally live (the pre-elastic contract)."""
    if pf is None:
        return mask, vals, idx, None
    mask = jnp.where(pf, mask, jnp.zeros_like(mask))
    count = jnp.where(pf, jnp.asarray(k, jnp.int32), 0)
    if vals is not None:
        vals = jnp.where(pf, vals, jnp.zeros_like(vals))
        idx = jnp.where(pf, idx, jnp.zeros_like(idx))
    return mask, vals, idx, count


def _compress_regtopk_sparse(cfg: SparsifierConfig, state: dict,
                             g: jnp.ndarray, k: int, omega: float,
                             pf=None) -> CompressOut:
    """REGTOP-k with O(k) posterior state (state_format="sparse").

    Algorithm 1 line 5 reads a^{t-1} and g^{t-1} ONLY at the support of
    s^{t-1}; everywhere else Delta = Q. So the dense (a_prev, s_prev,
    g_agg_prev) vectors reduce to three k-sized arrays — 4J fp32 of state
    becomes J (+O(k)), which is what lets the 32B-class configs fit HBM.
    Update math is identical to the dense path.
    """
    dt = jnp.dtype(cfg.ef_dtype)
    a = state["err"].astype(dt) + g.astype(dt)
    idx_p = state["idx_prev"]
    from repro.core import bigvec as _bv
    a_sel = _bv.gather(a, idx_p)
    safe = safe_denom(omega * a_sel)
    delta_sel = (state["g_prev_sel"] - omega * state["a_prev_sel"]) / safe
    reg_sel = jnp.tanh(jnp.abs(1.0 + delta_sel) / cfg.mu)
    reg_q = jnp.tanh(jnp.abs(1.0 + cfg.Q) / cfg.mu).astype(dt)
    from repro.core import bigvec
    reg = bigvec.scatter_set(jnp.full(a.shape, reg_q, dt), idx_p,
                             reg_sel.astype(dt))
    score = jnp.where(state["step"] == 0, a, a * reg)
    from repro.core import select as _select
    idx = _select.topk_indices(score, k)
    vals = bigvec.gather(a, idx)
    if pf is None:
        err_new = bigvec.scatter_set(a, idx, 0.0)
        mask = bigvec.mask_from_indices(a.shape[0], idx, a.dtype)
        count = None
        idx_prev_new, a_prev_new = idx.astype(jnp.uint32), vals
    else:
        # elastic sit-out: skip the scatter-zero (err keeps the decayed
        # a), freeze the O(k) posterior, ship an inert payload
        err_new = bigvec.scatter_set(
            a, bigvec.live_idx(idx, pf, a.shape[0]), 0.0, mode="drop")
        idx_prev_new = jnp.where(pf, idx.astype(jnp.uint32),
                                 state["idx_prev"])
        a_prev_new = jnp.where(pf, vals, state["a_prev_sel"])
        vals = jnp.where(pf, vals, jnp.zeros_like(vals))
        idx = jnp.where(pf, idx, jnp.zeros_like(idx))
        count = jnp.where(pf, jnp.asarray(k, jnp.int32), 0)
        mask = jnp.where(pf, bigvec.mask_from_indices(a.shape[0], idx, a.dtype),
                         jnp.zeros_like(a))
    ghat = bigvec.scatter_set(jnp.zeros_like(a), idx, vals)
    new = {
        "err": err_new,
        "idx_prev": idx_prev_new,
        "a_prev_sel": a_prev_new,
        "g_prev_sel": state["g_prev_sel"],   # filled by observe_aggregate
        "step": state["step"] + 1,
    }
    return CompressOut(ghat, mask, new, vals, idx, count)


def _compress_fused(cfg: SparsifierConfig, state: dict, g: jnp.ndarray,
                    k: int, omega: float, key=None,
                    seg_bounds=None, participate=None, g_segments=None,
                    stream_bounds=None) -> CompressOut:
    """Two-sweep fused pipeline (repro.kernels.compress, DESIGN.md §2.2).

    selector="exact": reference-parity top-k semantics;
    selector="histogram": threshold selection at the bit-pattern bin
    edge with fixed-capacity packed pairs (inert pads, DESIGN.md §2.5).
    ef_dtype="bfloat16" keeps the J-sized state in bf16 (sweep math is
    fp32 in-register). In comm_mode="sparse" no dense ghat is
    materialized — the packed (values, indices) drive the sparse
    all-gather and CompressOut.ghat is None. The state update is O(k):
    ops scatter-zeroes the selected slots of ``a`` into the next
    ``err_prev`` (and masks DGC's momentum the same way), so the step is
    two O(J) traversals end to end and no dense mask is written
    (CompressOut.mask is None — use dense_mask() on demand).
    cfg.num_buckets > 1 runs the sweeps per contiguous bucket with a
    histogram-merge global threshold (DESIGN.md §2.4); selection, packed
    order, and post-step state stay bit-identical to num_buckets=1.
    """
    from repro.kernels.compress import ops as cops
    hist = cfg.selector == "histogram" and cfg.kind != "randk"
    kwargs = {}
    if cfg.kind == "regtopk":
        kwargs = dict(idx_prev=state["idx_prev"],
                      a_prev_sel=state["a_prev_sel"].astype(jnp.float32),
                      g_prev_sel=state["g_prev_sel"].astype(jnp.float32))
        if hist:
            kwargs["nsel_prev"] = state["nsel"]
    if cfg.kind == "dgc":
        kwargs["mom"] = state["mom"]
    out = cops.fused_compress_arrays(
        cfg.kind, g, state["err_prev"], state["step"],
        k=k, omega=omega, mu=cfg.mu, Q=cfg.Q, momentum=cfg.momentum,
        want_ghat=cfg.comm_mode != "sparse", selector=cfg.selector,
        ef_dtype=cfg.ef_dtype, key=key, num_buckets=cfg.num_buckets,
        allocation=cfg.allocation, seg_bounds=seg_bounds,
        participate=participate, err_decay=cfg.err_decay,
        g_segments=g_segments, stream_bounds=stream_bounds,
        **kwargs)
    dt = jnp.dtype(cfg.ef_dtype)
    new = {"err_prev": out["err"], "step": state["step"] + 1}
    if cfg.kind == "dgc":
        new["mom"] = out["mom"]              # selection-masked, ef_dtype
    if cfg.kind == "regtopk":
        new["idx_prev"] = out["indices"]
        new["a_prev_sel"] = out["values"].astype(dt)
        new["g_prev_sel"] = jnp.zeros_like(state["g_prev_sel"])  # observe_aggregate
        if hist:
            new["nsel"] = out["count"]
        if participate is not None:
            # posterior freeze (O(k) selects): a sitting-out worker's
            # t-1 support/values stay those of its last participating
            # step — observe_aggregate applies the matching freeze to
            # g_prev_sel
            pf = jnp.asarray(participate, jnp.bool_)
            new["idx_prev"] = jnp.where(pf, out["indices"],
                                        state["idx_prev"])
            new["a_prev_sel"] = jnp.where(pf, out["values"].astype(dt),
                                          state["a_prev_sel"])
            new["g_prev_sel"] = jnp.where(pf, new["g_prev_sel"],
                                          state["g_prev_sel"])
            if hist:
                new["nsel"] = jnp.where(pf, out["count"], state["nsel"])
    return CompressOut(out["ghat"], None, new,
                       out["values"], out["indices"], out["count"])


def observe_aggregate(cfg: SparsifierConfig, state: dict, g_agg: jnp.ndarray,
                      participate=None) -> dict:
    """Store the aggregated gradient g^t the server 'broadcasts'
    (footnote 1). No-op except for REGTOP-k, where it is O(k) on the
    fused/sparse layouts (one gather at the support) and one O(J) cast
    on the dense reference layout. g_agg: (J,) — must be rank-identical
    (the sparse combine guarantees it; DESIGN.md §2.1).

    ``participate`` (DESIGN.md §2.7): a sitting-out worker observed
    nothing, so its posterior keeps the g^{t-1} of its last
    participating step (matching the compress-side posterior freeze)."""
    if cfg.kind == "regtopk":
        state = dict(state)
        pf = None if participate is None else jnp.asarray(participate,
                                                          jnp.bool_)
        from repro.kernels.compress.dispatch import dispatch
        if dispatch(cfg).path == "fused" or cfg.state_format == "sparse":
            # O(k) posterior: g^{t-1} is read only at the support of s^{t-1}
            from repro.core import bigvec
            gsel = bigvec.gather(g_agg, state["idx_prev"]).astype(
                jnp.dtype(cfg.ef_dtype))
            state["g_prev_sel"] = gsel if pf is None else jnp.where(
                pf, gsel, state["g_prev_sel"])
        else:
            gobs = g_agg.astype(jnp.dtype(cfg.ef_dtype))
            state["g_agg_prev"] = gobs if pf is None else jnp.where(
                pf, gobs, state["g_agg_prev"])
    return state


def dense_mask(out: CompressOut, j: int, dtype=jnp.float32) -> jnp.ndarray:
    """Dense 0/1 selection mask for a CompressOut, in the requested dtype.

    The ONE shared reconstruction both pipelines funnel through: the
    reference path carries a dense mask (returned cast), the fused path
    carries none — its mask is derived from the packed indices by an
    O(k) scatter. Histogram-selector outputs pad their fixed-capacity
    tail with inert (index 0) slots; ``out.count`` marks the live
    prefix, and pads are routed to an out-of-range sentinel + dropped
    (a duplicate write at index 0 would corrupt the mask there).
    """
    if out.mask is not None:
        return out.mask.astype(dtype)
    from repro.core import bigvec
    idx = out.indices.astype(jnp.uint32)
    if out.count is not None:
        live = jnp.arange(idx.shape[0], dtype=jnp.int32) < out.count
        idx = bigvec.live_idx(idx, live, j)
    return bigvec.scatter_set(jnp.zeros((j,), dtype), idx,
                              jnp.ones(idx.shape, dtype), mode="drop")


def dense_ghat(out: CompressOut, j: int) -> jnp.ndarray:
    """Dense sparsified gradient from a CompressOut, reconstructing from the
    packed (values, indices) when the fused sparse-comm path skipped it.
    Scatter-ADD, not set: the histogram selector's fixed-capacity packing
    pads its tail with inert (index 0, value 0.0) pairs, and a duplicate
    scatter-set at index 0 would be order-undefined; live indices are
    unique, so add == set for them."""
    if out.ghat is not None:
        return out.ghat
    from repro.core import bigvec
    return bigvec.scatter_add(jnp.zeros((j,), out.values.dtype),
                              out.indices, out.values)


# ---------------------------------------------------------------------------
# Single-process multi-worker reference driver (tests / paper experiments)
# ---------------------------------------------------------------------------

def make_round_fn(cfg: SparsifierConfig, n_workers: int):
    """Jitted vmapped aggregation round over stacked worker states/grads.

    Thin delegate to :meth:`core.aggregate.GradientSync.make_round_fn`
    (the unified simulation surface — one code path for the train step,
    the round drivers, and the tests): states_stacked is a pytree with
    leading (N,) axis, grads (N, J); returns (g_agg (J,),
    new_states_stacked). Equal weights w_n = 1/N. The returned function
    takes an optional trailing PRNG ``key``; each worker i compresses
    with ``fold_in(key, i)`` (matching ``sparsified_round``) — required
    for kind="randk", ignored by the deterministic sparsifiers.
    """
    from repro.core import aggregate
    return aggregate.GradientSync(cfg, None).make_round_fn(n_workers)


def stack_states(states: list):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def sparsified_round(cfg: SparsifierConfig, states: list, grads: list,
                     omegas: Optional[list] = None, key=None,
                     participate: Optional[list] = None):
    """One aggregation round over N in-process workers (validation path).

    Thin delegate to :meth:`core.aggregate.GradientSync.round` — the
    round logic lives on the same GradientSync object the production
    train step builds (axes=None runs the combine in-process), so tests,
    the paper-experiment benchmarks, and the train path exercise one
    code path. Returns (g_agg, new_states).

    ``participate`` (DESIGN.md §2.7): optional per-worker participation
    bits. Sitting-out workers contribute nothing; the combine divides by
    n_active (cfg.combine="mean") or per-coordinate selection counts
    (cfg.combine="support"), mirroring sync_gradient's elastic paths.
    """
    from repro.core import aggregate
    return aggregate.GradientSync(cfg, None).round(
        states, grads, omegas=omegas, key=key, participate=participate)
