"""Gather/scatter on flat vectors longer than int32 range.

jnp advanced indexing normalizes indices in int32 (without x64), which
overflows for J > 2^31-1 (qwen-32b's per-rank flat gradient at tp<=16).
These helpers reshape to (rows, cols) with cols < 2^31 and index with two
int32 arrays (row < 32, col < 2^27), which XLA handles natively.
"""
from __future__ import annotations

import jax.numpy as jnp

_I32_MAX = 2 ** 31 - 1
COLS = 1 << 27


def _needs_big(j: int) -> bool:
    return j > _I32_MAX


def _rc(idx, cols):
    idx = idx.astype(jnp.uint32)
    return ((idx // cols).astype(jnp.int32), (idx % cols).astype(jnp.int32))


def _pad2d(a, cols):
    j = a.shape[0]
    rows = -(-j // cols)
    return jnp.pad(a, (0, rows * cols - j)).reshape(rows, cols), j


def gather(a: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    if not _needs_big(a.shape[0]):
        return a[idx.astype(jnp.int32)]
    a2, _ = _pad2d(a, COLS)
    r, c = _rc(idx, COLS)
    return a2[r, c]


def scatter_set(a: jnp.ndarray, idx: jnp.ndarray, vals,
                mode: str | None = None) -> jnp.ndarray:
    """``mode="drop"`` discards out-of-range indices (callers use the
    sentinel ``idx = len(a)`` for inert pad slots instead of aliasing a
    real position — duplicate writes of different values at one index
    are order-undefined in XLA scatter)."""
    if not _needs_big(a.shape[0]):
        return a.at[idx.astype(jnp.int32)].set(vals, mode=mode)
    a2, j = _pad2d(a, COLS)
    r, c = _rc(idx, COLS)
    return a2.at[r, c].set(vals, mode=mode).reshape(-1)[:j]


def scatter_add(a: jnp.ndarray, idx: jnp.ndarray, vals,
                mode: str | None = None) -> jnp.ndarray:
    """Same ``mode="drop"`` sentinel contract as :func:`scatter_set`."""
    if not _needs_big(a.shape[0]):
        return a.at[idx.astype(jnp.int32)].add(vals, mode=mode)
    a2, j = _pad2d(a, COLS)
    r, c = _rc(idx, COLS)
    return a2.at[r, c].add(vals, mode=mode).reshape(-1)[:j]


def mask_from_indices(j: int, idx: jnp.ndarray, dtype) -> jnp.ndarray:
    return scatter_set(jnp.zeros((j,), dtype), idx, jnp.ones(idx.shape, dtype))


def live_idx(idx: jnp.ndarray, live: jnp.ndarray, j: int) -> jnp.ndarray:
    """Route non-live slots of a fixed-capacity index array OUT OF RANGE
    (sentinel ``j``) so a ``mode="drop"`` scatter skips them.

    This is THE way to scatter through packed indices with inert pad
    slots: pads alias index 0, and a duplicate scatter write of a
    different value at one index is order-undefined in XLA — the
    sentinel + drop makes them true no-ops instead."""
    return jnp.where(live, idx.astype(jnp.uint32), jnp.uint32(j))
