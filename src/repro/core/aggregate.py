"""Gradient aggregation paths over the data-parallel mesh axes.

Three communication modes (DESIGN.md §2.1), all used inside ``shard_map``:

- ``dense``    : plain all-reduce (``psum``) of the raw gradient. Baseline.
- ``simulate`` : sparsify locally, all-reduce the (mostly-zero) dense vector.
                 Exact sparsified-training numerics; comm volume unchanged.
                 Used for CPU validation of the paper's claims.
- ``sparse``   : all-gather fixed-k (values, indices) pairs over the data axes
                 and scatter-add locally. Comm per step = N*k*8 bytes instead
                 of ~2*J*4 — the production path whose collective-term drop
                 the roofline quantifies.

Sketch-coordinated selection (dispatch ``selection="sketch"``, DESIGN.md
§2.9) adds a pre-selection collective — one all-reduce of per-worker
CountSketches — after which every rank decodes the SAME top-k mask, so
the sparse exchange ships VALUES ONLY (``shared_mask_allgather_combine``;
indices are implied by the coordinated mask): N*k*4 bytes, half the
packed-pair wire, compounding with ``wire_dtype="bfloat16"``.

Which path serves a config is entirely the dispatch decision
(``CompressDispatch.selection`` / ``.wire``); the sync code never
branches on ``cfg.kind``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro.configs.base import SparsifierConfig
from repro.core import sketch, sparsify
from repro.kernels.compress.dispatch import (  # noqa: F401  (re-export)
    dispatch as compress_dispatch,
    effective_comm_mode,
)

AxisNames = Union[str, Sequence[str]]

# (kind, selector, pipeline) combos already warned about — the sparse ->
# simulate degrade is surfaced once per config per process, at trace time
_DEGRADE_WARNED: set = set()


def _warn_sparse_degrade(cfg: SparsifierConfig) -> None:
    keyc = (cfg.kind, cfg.selector, cfg.pipeline)
    if keyc in _DEGRADE_WARNED:
        return
    _DEGRADE_WARNED.add(keyc)
    d = compress_dispatch(cfg)
    # only advise switching pipelines when that actually helps: the
    # fused-pipeline variant of this config must dispatch fused
    fused_var = dataclasses.replace(cfg, pipeline="fused")
    hint = (" pipeline='fused' serves this config sparsely."
            if compress_dispatch(fused_var).path == "fused" else "")
    warnings.warn(
        f"comm_mode='sparse' with kind={cfg.kind!r} selector={cfg.selector!r}"
        f" pipeline={cfg.pipeline!r} packs no fixed-size (values, indices)"
        f" pairs ({d.reason or 'no packed output'}); degrading to a dense"
        " simulate all-reduce (effective_comm_mode(cfg) == 'simulate')."
        + hint,
        RuntimeWarning, stacklevel=3)


def _axis_size(axes: AxisNames) -> jnp.ndarray:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n = n * jax.lax.axis_size(a)
    return n


def dense_allreduce(g: jnp.ndarray, axes: AxisNames) -> jnp.ndarray:
    return jax.lax.pmean(g, axes)


def simulate_allreduce(ghat: jnp.ndarray, axes: AxisNames) -> jnp.ndarray:
    return jax.lax.pmean(ghat, axes)


def sparse_allgather_combine(values: jnp.ndarray, indices: jnp.ndarray,
                             j: int, axes: AxisNames,
                             num_buckets: int = 1,
                             wire_dtype: str = "float32",
                             participate=None, count=None,
                             combine: str = "mean") -> jnp.ndarray:
    """All-gather (k,) sparse contributions over `axes`; dense-combine locally.

    Every worker ends up with g_agg = (1/N) sum_n scatter(values_n, idx_n),
    identical on all data ranks (required: REGTOP-k's posterior distortion
    assumes the same g^t is observed everywhere).

    ``num_buckets > 1`` (DESIGN.md §2.4) splits the packed pairs into
    that many fixed-size chunks and issues ONE collective per chunk:
    chunk b's local scatter-add depends only on chunk b's gather, so
    XLA's latency-hiding scheduler overlaps chunk b+1's all-gather with
    chunk b's compaction instead of serializing one monolithic gather
    ahead of one monolithic scatter. The combined g_agg is the same sum
    (chunking only reorders additions at duplicate indices).

    ``wire_dtype="bfloat16"`` casts the packed VALUES (never the
    indices) right before each chunk's all-gather and upcasts in the
    scatter-add combine: 6 wire bytes per pair instead of 8. Every rank
    applies the same cast, so g_agg stays rank-identical.

    ``participate`` (DESIGN.md §2.7) is this rank's per-step liveness, a
    traced () bool. The collective stays fixed-shape — a sitting-out
    worker ships its (inert) payload like everyone else — but its slots
    are routed out of range and dropped in the combine, and the
    normalizer becomes the ACTIVE worker count. ``count`` marks the live
    packed prefix (None = all k slots); one position test
    ``p_w & (pos < count_w)`` handles histogram-capacity pads and
    chunk-tail pads uniformly. ``combine="support"`` divides each
    coordinate by the number of active workers that selected it instead
    of by n_active (coordinates nobody selected stay 0).
    """
    if isinstance(axes, str):
        axes = (axes,)
    n = _axis_size(axes)
    from repro.core import bigvec
    k = values.shape[0]
    num_buckets = max(1, int(num_buckets))   # 0 (auto) is resolved upstream
    if k <= num_buckets:
        num_buckets = 1          # degenerate: one pair per chunk gains nothing
    chunk = -(-k // num_buckets)
    pad = chunk * num_buckets - k
    if pad:
        # inert tail: scatter-add of 0.0 at index 0
        values = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
        indices = jnp.concatenate([indices, jnp.zeros((pad,), indices.dtype)])
    acc_dtype = values.dtype
    wire_dt = jnp.dtype(wire_dtype)
    dense = jnp.zeros((j,), acc_dtype)
    if participate is None and combine == "mean":
        for b in range(num_buckets):
            vb = values[b * chunk:(b + 1) * chunk].astype(wire_dt)
            ib = indices[b * chunk:(b + 1) * chunk]
            for a in axes:
                vb = jax.lax.all_gather(vb, a)     # stacks leading axis
                ib = jax.lax.all_gather(ib, a)
            dense = bigvec.scatter_add(dense, ib.reshape(-1),
                                       vb.reshape(-1).astype(acc_dtype))
        return dense / n
    if combine not in ("mean", "support"):
        raise ValueError(f"unknown combine={combine!r} (mean | support)")
    # elastic path: two extra scalars per worker on the wire (liveness
    # bit + live count) — the payload collectives are unchanged
    p = (jnp.ones((), jnp.bool_) if participate is None
         else jnp.asarray(participate, jnp.bool_).reshape(()))
    cnt = (jnp.asarray(k if count is None else count, jnp.int32)
           .reshape(()))
    cnt = jnp.where(p, cnt, 0)
    pall = p.astype(jnp.float32)
    call = cnt
    for a in axes:
        pall = jax.lax.all_gather(pall, a)
        call = jax.lax.all_gather(call, a)
    pall = pall.reshape(-1) > 0.5                  # (n,) worker liveness
    call = call.reshape(-1)                        # (n,) live prefix length
    counts = jnp.zeros((j,), jnp.float32) if combine == "support" else None
    for b in range(num_buckets):
        vb = values[b * chunk:(b + 1) * chunk].astype(wire_dt)
        ib = indices[b * chunk:(b + 1) * chunk]
        for a in axes:
            vb = jax.lax.all_gather(vb, a)
            ib = jax.lax.all_gather(ib, a)
        pos = jnp.arange(b * chunk, (b + 1) * chunk, dtype=jnp.int32)
        live = pall[:, None] & (pos[None, :] < call[:, None])   # (n, chunk)
        il = bigvec.live_idx(ib.reshape(n, chunk), live, j).reshape(-1)
        dense = bigvec.scatter_add(dense, il,
                                   vb.reshape(-1).astype(acc_dtype),
                                   mode="drop")
        if counts is not None:
            counts = bigvec.scatter_add(counts, il,
                                        jnp.ones(il.shape, jnp.float32),
                                        mode="drop")
    if combine == "support":
        return jnp.where(counts > 0,
                         dense / jnp.maximum(counts, 1.0).astype(acc_dtype),
                         jnp.zeros((), acc_dtype))
    n_active = jnp.sum(pall.astype(jnp.float32))
    return dense / jnp.maximum(n_active, 1.0).astype(acc_dtype)


def shared_mask_allgather_combine(values: jnp.ndarray, indices: jnp.ndarray,
                                  j: int, axes: AxisNames,
                                  num_buckets: int = 1,
                                  wire_dtype: str = "float32",
                                  participate=None) -> jnp.ndarray:
    """All-gather (k,) VALUES under a COORDINATED shared mask; combine
    locally (DESIGN.md §2.9).

    Every rank holds the SAME index list — decoded from the all-reduced
    sketch — so the indices never travel: wire bytes are n * k *
    value_bytes, HALF the packed (values, indices) exchange at fp32,
    compounding with ``wire_dtype="bfloat16"`` (n * k * 2). ``indices``
    is that shared list; it only steers the local scatter.

    Because the support coincides on every rank, the per-coordinate
    support count equals the active worker count — ``combine="support"``
    and ``"mean"`` coincide, so there is exactly one combine:
    sum / n_active. ``num_buckets > 1`` chunks the gather like
    :func:`sparse_allgather_combine` (same latency-hiding rationale).

    ``participate``: this rank's liveness bit. A sitting-out worker's
    values arrive pre-zeroed by the caller (its slots are inert — the
    index list is shared, so no per-worker routing is needed), and the
    normalizer becomes the active count via one scalar psum. With
    ``participate=None`` the normalizer is the same float n, so an
    all-ones mask is bit-identical.
    """
    if isinstance(axes, str):
        axes = (axes,)
    n = _axis_size(axes)
    from repro.core import bigvec
    k = values.shape[0]
    num_buckets = max(1, int(num_buckets))
    if k <= num_buckets:
        num_buckets = 1
    chunk = -(-k // num_buckets)
    pad = chunk * num_buckets - k
    if pad:
        # inert tail: scatter-add of 0.0 at (shared) index 0
        values = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
        indices = jnp.concatenate([indices, jnp.zeros((pad,), indices.dtype)])
    acc_dtype = values.dtype
    wire_dt = jnp.dtype(wire_dtype)
    dense = jnp.zeros((j,), acc_dtype)
    for b in range(num_buckets):
        vb = values[b * chunk:(b + 1) * chunk].astype(wire_dt)
        for a in axes:
            vb = jax.lax.all_gather(vb, a)     # stacks leading axis
        vsum = jnp.sum(vb.reshape(-1, chunk).astype(acc_dtype), axis=0)
        dense = bigvec.scatter_add(dense, indices[b * chunk:(b + 1) * chunk],
                                   vsum)
    if participate is None:
        return dense / jnp.float32(n).astype(acc_dtype)
    p = jnp.asarray(participate, jnp.bool_).reshape(())
    na = jax.lax.psum(p.astype(jnp.float32), axes)
    return dense / jnp.maximum(na, 1.0).astype(acc_dtype)


class GradientSync:
    """Per-run gradient-sync surface: static fields bound once, per-step
    work through ``__call__`` or the ``begin()/feed_segment()/finish()``
    streaming interface (DESIGN.md §2.8).

    ``sync_gradient`` had accreted eight positional/keyword parameters,
    most of them static per run (cfg, axes, seg_bounds) — and streaming
    adds more. GradientSync splits the two lifetimes: construction takes
    the static fields and validates them ONCE (allocation combos,
    ``cfg.overlap`` capability, optional bucket auto-resolution when the
    problem size + worker count are known), per-step calls take only the
    traced values.

    Per-step surfaces (inside ``shard_map``; ``axes`` required):

    - ``sync(state, g, key=..., participate=...)`` — flat-gradient step,
      the exact ``sync_gradient`` semantics (returns ``(g_agg,
      new_state)``, plus stats with ``with_stats=True``).
    - ``begin(state, ...)`` → stream; ``stream.feed_segment(g_seg)`` per
      layer-aligned segment as the backward pass emits it;
      ``stream.finish()`` runs the global trim/pack, the sparse
      collective, and ``observe_aggregate`` — the only tail barrier.
      Requires ``cfg.overlap == "backward"``; output is BIT-identical to
      the flat call (selection is partition-invariant, DESIGN.md §2.8).

    In-process simulation surfaces (``axes=None`` is fine — the combine
    runs locally): :meth:`round` over lists of per-worker states/grads
    and :meth:`make_round_fn` for the jitted vmapped variant. These
    absorb the former ``sparsify.sparsified_round`` / ``_elastic_round``
    / ``make_round_fn`` trio so the tests, the paper-experiment
    benchmarks, and the production train step exercise one code path.

    Semantics carried over verbatim from ``sync_gradient`` (that name
    remains as a deprecated shim):

    - pipeline/fused dispatch, chunked bucket collectives (§2.4), density
      allocation with layer-aligned ``seg_bounds`` (§2.6) — wire format
      allocation-invariant.
    - ``participate`` elastic liveness (§2.7): inert payloads, EF decay,
      active-set normalization, non-finite payload demotion,
      ``with_stats`` health counters as rank-identical psums.
    """

    def __init__(self, cfg: SparsifierConfig, axes,
                 *, j: int = None, n_workers: int = None, seg_bounds=None):
        if cfg.allocation != "global":
            from repro.core import allocate
            allocate.check_allocation(cfg)     # explicit build-time error
        from repro.kernels.compress.dispatch import check_overlap
        check_overlap(cfg)                     # overlap="backward" capability
        if (cfg.num_buckets == 0 and j is not None and n_workers is not None
                and compress_dispatch(cfg).selection != "none"):
            # bucket auto-tune resolved at build time when the problem
            # size and fleet size are concrete; otherwise deferred to the
            # per-step call where the mesh axis size is known
            cfg = dataclasses.replace(
                cfg, num_buckets=sparsify.resolve_num_buckets(cfg, j,
                                                              n_workers))
        self.cfg = cfg
        self.axes = axes
        self.j = j
        self.n_workers = n_workers
        self.seg_bounds = seg_bounds

    def __call__(self, state: dict, g: jnp.ndarray, *, key=None,
                 participate=None, with_stats: bool = False):
        """One flat-gradient sync step: returns (g_agg, new_state[, stats])."""
        return self._sync(state, g=g, key=key, participate=participate,
                          with_stats=with_stats)

    def begin(self, state: dict, *, key=None, participate=None):
        """Open a streaming step (cfg.overlap='backward' only): feed
        gradient segments in layer order as the backward pass emits
        them, then ``finish()``."""
        if getattr(self.cfg, "overlap", "none") != "backward":
            raise ValueError(
                "begin()/feed_segment streaming needs overlap='backward' "
                f"(got overlap={getattr(self.cfg, 'overlap', 'none')!r})")
        return _GradientStream(self, state, key, participate)

    # -- per-step core (refactored sync_gradient body) ------------------

    def _sync(self, state: dict, g=None, g_segments=None, key=None,
              participate=None, with_stats: bool = False):
        cfg, axes = self.cfg, self.axes
        if axes is None:
            raise ValueError(
                "this GradientSync was built without mesh axes (in-process "
                "simulation only); per-step sync runs inside shard_map and "
                "needs the data-parallel axis name(s) — use round() / "
                "make_round_fn() for axis-free aggregation rounds")
        streaming = g_segments is not None
        j = (int(sum(gs.shape[0] for gs in g_segments)) if streaming
             else g.shape[0])
        p = None if participate is None else (
            jnp.asarray(participate, jnp.bool_).reshape(()))
        n = _axis_size(axes)
        zero = jnp.zeros((), jnp.float32)

        def _ret(g_agg, new_state, p_eff, dropped_local):
            if not with_stats:
                return g_agg, new_state
            if p_eff is None:
                stats = {"n_active": jnp.float32(n),
                         "dropped_nonfinite": zero}
            else:
                stats = {"n_active": jax.lax.psum(p_eff.astype(jnp.float32),
                                                  axes),
                         "dropped_nonfinite": jax.lax.psum(dropped_local,
                                                           axes)}
            return g_agg, new_state, stats

        d = compress_dispatch(cfg)
        if d.selection == "none":
            gd = g.astype(jnp.dtype(cfg.ef_dtype))
            if p is None:
                g_agg = dense_allreduce(gd, axes)
            else:
                dsum = jax.lax.psum(jnp.where(p, gd, jnp.zeros((), gd.dtype)),
                                    axes)
                na = jax.lax.psum(p.astype(jnp.float32), axes)
                g_agg = dsum / jnp.maximum(na, 1.0).astype(gd.dtype)
            return _ret(g_agg, {"step": state["step"] + 1}, p, zero)
        if cfg.num_buckets == 0:
            # auto-tune (DESIGN.md §2.4): resolved here, where the real
            # data-parallel axis size is known, so the compress sweeps and
            # the chunked collective share one concrete bucket count
            cfg = dataclasses.replace(
                cfg, num_buckets=sparsify.resolve_num_buckets(cfg, j, n))
        omega = 1.0 / n
        if d.selection == "global":
            # genie baseline: TOP-k on the true aggregated accumulated
            # gradient
            from repro.core import select as _select
            gf = g.astype(jnp.float32)
            if p is None:
                a_agg = dense_allreduce(gf, axes)
            else:
                a_agg = jax.lax.psum(jnp.where(p, gf, 0.0), axes)
                na = jax.lax.psum(p.astype(jnp.float32), axes)
                a_agg = a_agg / jnp.maximum(na, 1.0)
            k = sparsify.resolve_k(cfg, j)
            mask = _select.topk_mask(a_agg, k, cfg.selector)
            return _ret(mask * a_agg, {"step": state["step"] + 1}, p, zero)
        if d.selection == "sketch":
            return self._sync_sketch(cfg, d, state, g, p, n, _ret)

        out = sparsify.compress(cfg, state, g, key=key, omega=omega,
                                seg_bounds=self.seg_bounds, participate=p,
                                g_segments=g_segments)
        p_eff, dropped = p, zero
        if p is not None and out.values is not None:
            # non-finite payload guard: a worker whose packed values went
            # NaN/Inf is dropped for this step (its EF state already
            # updated under plain participation — one-step posterior
            # skew, §2.7)
            finite = jnp.all(jnp.isfinite(out.values.astype(jnp.float32)))
            p_eff = p & finite
            dropped = (p & ~finite).astype(jnp.float32)
        elastic = p is not None or cfg.combine != "mean"
        if cfg.comm_mode == "sparse" and out.values is not None:
            if elastic:
                g_agg = sparse_allgather_combine(out.values, out.indices,
                                                 j, axes,
                                                 num_buckets=cfg.num_buckets,
                                                 wire_dtype=cfg.wire_dtype,
                                                 participate=p_eff,
                                                 count=out.count,
                                                 combine=cfg.combine)
            else:
                g_agg = sparse_allgather_combine(out.values, out.indices,
                                                 j, axes,
                                                 num_buckets=cfg.num_buckets,
                                                 wire_dtype=cfg.wire_dtype)
        else:
            if cfg.comm_mode == "sparse":
                # explicit, not silent: this config emits no packed pairs,
                # so the sparse path cannot run — warn once (trace time)
                # and surface the realized mode via effective_comm_mode
                _warn_sparse_degrade(cfg)
            ghat = sparsify.dense_ghat(out, j)
            if p is not None and out.values is None:
                finite = jnp.all(jnp.isfinite(ghat.astype(jnp.float32)))
                p_eff = p & finite
                dropped = (p & ~finite).astype(jnp.float32)
            if not elastic:
                g_agg = simulate_allreduce(ghat, axes)
            else:
                pe = jnp.ones((), jnp.bool_) if p_eff is None else p_eff
                dsum = jax.lax.psum(
                    jnp.where(pe, ghat, jnp.zeros((), ghat.dtype)), axes)
                if cfg.combine == "support":
                    m = sparsify.dense_mask(out, j)
                    cnts = jax.lax.psum(
                        jnp.where(pe, m, jnp.zeros((), m.dtype)), axes)
                    g_agg = jnp.where(
                        cnts > 0,
                        dsum / jnp.maximum(cnts, 1.0).astype(ghat.dtype),
                        jnp.zeros((), ghat.dtype))
                else:
                    na = jax.lax.psum(pe.astype(jnp.float32), axes)
                    g_agg = dsum / jnp.maximum(na, 1.0).astype(ghat.dtype)
        new_state = sparsify.observe_aggregate(cfg, out.state, g_agg,
                                               participate=p_eff)
        return _ret(g_agg, new_state, p_eff, dropped)

    def _sync_sketch(self, cfg, d, state, g, p, n, _ret):
        """Sketch-coordinated global top-k step (DESIGN.md §2.9).

        1. encode: a = err + g into a (rows, width) CountSketch — folded
           into sweep 1 on the fused path (ops.fused_sketch_encode, one
           traversal on Pallas, two under the XLA strategy), legacy
           two-pass encode on the reference path;
        2. pre-selection collective: ONE all-reduce of the linear
           sketches. Elastic: absent workers contribute ZERO sketches
           and the combine renormalizes by the active count (an
           all-ones mask is bit-identical to p=None — the psum operands
           pass through bitwise and the normalizer is the same float n);
        3. decode: identical magnitude estimates on every rank ->
           the SAME shared top-k mask everywhere;
        4. exchange: comm_mode="sparse" ships the k values only via
           shared_mask_allgather_combine (indices implied by the
           coordinated mask — half the packed-pair wire); otherwise the
           dense masked ghat is averaged (simulate semantics);
        5. EF closes O(k): the shared support of a is scatter-zeroed
           into the next err state (a sitting-out worker's scatter is
           sentinel-routed, so its decayed err survives verbatim).
        """
        axes = self.axes
        j = g.shape[0]
        k = sparsify.resolve_k(cfg, j)
        width = sketch.resolve_width(k, cfg.sketch_width)
        zero = jnp.zeros((), jnp.float32)
        ek = "err_prev" if d.path == "fused" else "err"
        if d.path == "fused":
            from repro.kernels.compress import ops as cops
            enc = cops.fused_sketch_encode(
                g, state[ek], rows=cfg.sketch_rows, width=width,
                participate=p, err_decay=cfg.err_decay)
            a, sk = enc["a"], enc["sketch"]
        else:
            err = state[ek]
            if p is not None:
                from repro.kernels.compress import ops as cops
                g, err, _ = cops.masked_inputs(g, err, p, cfg.err_decay)
            a = err + g.astype(jnp.dtype(cfg.ef_dtype))
            sk = sketch.encode(a, cfg.sketch_rows, width)
        if p is None:
            sk_agg = jax.lax.psum(sk, axes) / jnp.float32(n)
        else:
            sk_agg = jax.lax.psum(
                jnp.where(p, sk, jnp.zeros((), sk.dtype)), axes)
            na = jax.lax.psum(p.astype(jnp.float32), axes)
            sk_agg = sk_agg / jnp.maximum(na, 1.0)
        gmag = sketch.estimate(sk_agg, j)        # identical on all ranks
        from repro.core import select as _select
        if effective_comm_mode(cfg) == "sparse":
            from repro.core import bigvec
            idx = _select.topk_indices(gmag, k)  # the shared mask, as indices
            vals = bigvec.gather(a, idx)         # O(k)
            if p is not None:
                vals = jnp.where(p, vals, jnp.zeros((), vals.dtype))
            g_agg = shared_mask_allgather_combine(
                vals, idx, j, axes, num_buckets=cfg.num_buckets,
                wire_dtype=cfg.wire_dtype, participate=p)
            live = idx if p is None else bigvec.live_idx(idx, p, j)
            err_new = bigvec.scatter_set(a.astype(state[ek].dtype), live,
                                         0.0, mode="drop")
        else:
            mask = _select.topk_mask(gmag, k, cfg.selector)
            ghat = mask * a
            if p is None:
                g_agg = simulate_allreduce(ghat, axes)
            else:
                ghat = jnp.where(p, ghat, jnp.zeros((), ghat.dtype))
                dsum = jax.lax.psum(ghat, axes)
                na = jax.lax.psum(p.astype(jnp.float32), axes)
                g_agg = dsum / jnp.maximum(na, 1.0).astype(ghat.dtype)
            err_new = (a - ghat).astype(state[ek].dtype)
        new_state = {ek: err_new, "step": state["step"] + 1}
        return _ret(g_agg, new_state, p, zero)

    # -- in-process simulation surfaces ---------------------------------

    def round(self, states: list, grads: list, omegas=None, key=None,
              participate=None):
        """One aggregation round over N in-process workers.

        Returns (g_agg, new_states). The former sparsify.sparsified_round
        — the combine runs locally, so ``axes`` may be None.

        ``participate`` (DESIGN.md §2.7): optional per-worker
        participation bits; sitting-out workers contribute nothing and
        the combine divides by n_active (cfg.combine="mean") or
        per-coordinate selection counts ("support"), mirroring the
        per-step elastic paths.
        """
        cfg = self.cfg
        d = compress_dispatch(cfg)
        if d.selection == "sketch":
            return self._round_sketch(states, grads, omegas, key,
                                      participate)
        if d.selection == "global":
            return self._round_global(states, grads, omegas, participate)
        n = len(grads)
        omegas = omegas or [1.0 / n] * n
        j = grads[0].shape[0]
        if participate is not None:
            return self._round_elastic(states, grads, participate, key)
        outs = []
        for i in range(n):
            ki = None if key is None else jax.random.fold_in(key, i)
            outs.append(sparsify.compress(cfg, states[i], grads[i], key=ki,
                                          omega=omegas[i]))
        g_agg = sum(w * sparsify.dense_ghat(o, j)
                    for w, o in zip(omegas, outs))
        new_states = [sparsify.observe_aggregate(cfg, o.state, g_agg)
                      for o in outs]
        return g_agg, new_states

    def _round_elastic(self, states: list, grads: list, participate: list,
                       key):
        """round() under a per-worker participation mask — the in-process
        mirror of the per-step elastic combine (DESIGN.md §2.7): inert
        payloads from sitting-out workers, equal weights over the ACTIVE
        set ("mean") or per-coordinate support counts ("support"). An
        all-absent round yields g_agg = 0 and every state decays."""
        cfg = self.cfg
        n = len(grads)
        j = grads[0].shape[0]
        pfs = [jnp.asarray(p, jnp.bool_) for p in participate]
        outs = []
        for i in range(n):
            ki = None if key is None else jax.random.fold_in(key, i)
            outs.append(sparsify.compress(cfg, states[i], grads[i], key=ki,
                                          omega=1.0 / n,
                                          participate=pfs[i]))
        ghats = [sparsify.dense_ghat(o, j) for o in outs]  # inert when absent
        dense = sum(ghats)
        if cfg.combine == "support":
            counts = sum(sparsify.dense_mask(o, j) for o in outs)
            g_agg = jnp.where(counts > 0,
                              dense / jnp.maximum(counts, 1.0), 0.0)
        else:
            n_active = sum(p.astype(jnp.float32) for p in pfs)
            g_agg = dense / jnp.maximum(n_active, 1.0)
        new_states = [sparsify.observe_aggregate(cfg, o.state, g_agg,
                                                 participate=p)
                      for o, p in zip(outs, pfs)]
        return g_agg, new_states

    def _round_sketch(self, states, grads, omegas, key, participate):
        """In-process sketch-coordinated round (DESIGN.md §2.9): encode
        per worker (folded into sweep 1 on the fused path), ONE sketch
        combine, one SHARED mask, per-worker EF closed at that mask.

        Elastic participation: absent workers contribute ZERO sketches
        and zero gradient payloads, and both combines renormalize over
        the active count; a sitting-out worker's error feedback decays
        in place (masked_inputs). An all-ones mask is bit-identical to
        ``participate=None`` — the masked operands pass through bitwise
        and the normalizer is the same float n. Explicit ``omegas``
        weight the non-elastic combines only (the elastic combine is
        equal-weight over the active set, like every other elastic
        path)."""
        cfg = self.cfg
        d = compress_dispatch(cfg)
        n = len(grads)
        j = grads[0].shape[0]
        k = sparsify.resolve_k(cfg, j)
        width = sketch.resolve_width(k, cfg.sketch_width)
        ek = "err_prev" if d.path == "fused" else "err"
        if participate is not None and omegas is not None:
            raise ValueError(
                "explicit omegas with a participation mask are not "
                "defined for sketch coordination — the elastic combine "
                "renormalizes equal weights over the active set")
        pfs = (None if participate is None
               else [jnp.asarray(pi, jnp.bool_) for pi in participate])
        a_list, sk_list = [], []
        for i in range(n):
            pi = None if pfs is None else pfs[i]
            if d.path == "fused":
                from repro.kernels.compress import ops as cops
                enc = cops.fused_sketch_encode(
                    grads[i], states[i][ek], rows=cfg.sketch_rows,
                    width=width, participate=pi, err_decay=cfg.err_decay)
                a, sk = enc["a"], enc["sketch"]
            else:
                g, err = grads[i], states[i][ek]
                if pi is not None:
                    from repro.kernels.compress import ops as cops
                    g, err, _ = cops.masked_inputs(g, err, pi,
                                                   cfg.err_decay)
                a = err + g.astype(jnp.float32)
                sk = sketch.encode(a, cfg.sketch_rows, width)
            a_list.append(a)
            sk_list.append(sk)
        if pfs is not None:
            na = sum(pi.astype(jnp.float32) for pi in pfs)
            norm = jnp.maximum(na, 1.0)
            sk_agg = sum(jnp.where(pi, sk, jnp.zeros((), sk.dtype))
                         for pi, sk in zip(pfs, sk_list)) / norm
        elif omegas is None:
            sk_agg = sum(sk_list) / jnp.float32(n)
        else:
            sk_agg = sum(w * sk for w, sk in zip(omegas, sk_list))
        gmag = sketch.estimate(sk_agg, j)
        from repro.core import select as _select
        mask = _select.topk_mask(gmag, k, cfg.selector)   # SHARED
        ghats = [mask * a for a in a_list]
        if pfs is not None:
            ghats = [jnp.where(pi, gh, jnp.zeros((), gh.dtype))
                     for pi, gh in zip(pfs, ghats)]
            g_agg = sum(ghats) / norm
        elif omegas is None:
            g_agg = sum(ghats) / jnp.float32(n)
        else:
            g_agg = sum(w * gh for w, gh in zip(omegas, ghats))
        # absent workers' ghat is zero, so a - ghat keeps their decayed
        # err verbatim — same EF semantics as the per-step path
        new_states = [{ek: (a - gh).astype(st[ek].dtype),
                       "step": st["step"] + 1}
                      for a, gh, st in zip(a_list, ghats, states)]
        return g_agg, new_states

    def _round_global(self, states, grads, omegas, participate):
        """Genie-baseline round: top-k mask decoded from the true
        aggregated accumulated gradient. Elastic semantics (DESIGN.md
        §2.7/§2.9): absent workers contribute nothing, the aggregate
        renormalizes over the active count, and the genie mask is
        decoded from that active-mean aggregate; an all-ones mask is
        bit-identical to ``participate=None``. States pass through
        unchanged (the genie keeps no error feedback)."""
        cfg = self.cfg
        n = len(grads)
        j = grads[0].shape[0]
        k = sparsify.resolve_k(cfg, j)
        from repro.core import select as _select
        gfs = [g.astype(jnp.float32) for g in grads]
        if participate is not None:
            if omegas is not None:
                raise ValueError(
                    "explicit omegas with a participation mask are not "
                    "defined for the genie baseline — the elastic "
                    "combine renormalizes equal weights over the active "
                    "set")
            pfs = [jnp.asarray(pi, jnp.bool_) for pi in participate]
            na = sum(pi.astype(jnp.float32) for pi in pfs)
            a_agg = sum(jnp.where(pi, gf, jnp.zeros((), gf.dtype))
                        for pi, gf in zip(pfs, gfs))
            a_agg = a_agg / jnp.maximum(na, 1.0)
        elif omegas is None:
            a_agg = sum(gfs) / jnp.float32(n)
        else:
            a_agg = sum(w * gf for w, gf in zip(omegas, gfs))
        mask = _select.topk_mask(a_agg, k, cfg.selector)
        return mask * a_agg, states

    def make_round_fn(self, n_workers: int = None):
        """Jitted vmapped aggregation round over stacked worker
        states/grads (the former sparsify.make_round_fn).

        states_stacked: pytree with leading (N,) axis; grads: (N, J).
        Returns (g_agg (J,), new_states_stacked). Equal weights
        w_n = 1/N. The returned function takes an optional trailing PRNG
        ``key``; each worker i compresses with ``fold_in(key, i)``
        (matching :meth:`round`) — required for kind="randk", ignored by
        the deterministic sparsifiers.
        """
        cfg = self.cfg
        if n_workers is None:
            n_workers = self.n_workers
        if n_workers is None:
            raise ValueError("make_round_fn needs n_workers (at "
                             "construction or per call)")
        omega = 1.0 / n_workers

        if compress_dispatch(cfg).selection in ("sketch", "global"):
            # coordinated selection: unstack and delegate to round() —
            # the fused sketch encode is a Pallas launch, which vmap
            # cannot batch; a python loop over the N in-process workers
            # jits into the same program
            def round_coord(states, grads, key=None):
                n = grads.shape[0]
                sts = [jax.tree_util.tree_map(lambda x, i=i: x[i], states)
                       for i in range(n)]
                g_agg, new_sts = self.round(
                    sts, [grads[i] for i in range(n)], key=key)
                stacked = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *new_sts)
                return g_agg, stacked

            return jax.jit(round_coord)

        def one(state, g, k_i):
            out = sparsify.compress(cfg, state, g, key=k_i, omega=omega)
            return sparsify.dense_ghat(out, g.shape[0]), out.state

        def round_fn(states, grads, key=None):
            if key is None:
                ghats, new_states = jax.vmap(
                    lambda s, g: one(s, g, None))(states, grads)
            else:
                # per-worker folded key, matching round()'s
                # fold_in(key, i) stream
                keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
                    jnp.arange(n_workers))
                ghats, new_states = jax.vmap(one)(states, grads, keys)
            g_agg = jnp.sum(ghats, 0) * omega
            new_states = jax.vmap(
                lambda s: sparsify.observe_aggregate(cfg, s,
                                                     g_agg))(new_states)
            return g_agg, new_states

        return jax.jit(round_fn)


class _GradientStream:
    """Streaming handle from :meth:`GradientSync.begin`: feed
    layer-aligned gradient segments in emission order as the backward
    pass produces them; ``finish()`` runs the tail barrier (global
    trim/pack + sparse collective + ``observe_aggregate``) and returns
    (g_agg, new_state[, stats]). Single-shot: segments cannot be fed
    after finish()."""

    def __init__(self, sync: "GradientSync", state: dict, key, participate):
        self._gs = sync
        self._state = state
        self._key = key
        self._participate = participate
        self._segments = []
        self._done = False

    def feed_segment(self, g_seg: jnp.ndarray):
        """Append one flat gradient segment (layer order, contiguous)."""
        if self._done:
            raise RuntimeError("feed_segment() after finish()")
        self._segments.append(g_seg)
        return self

    def finish(self, *, with_stats: bool = False):
        """Tail barrier: trim/pack globally, run the collective, observe."""
        if self._done:
            raise RuntimeError("finish() called twice on one stream")
        if not self._segments:
            raise ValueError("finish() with no fed segments")
        self._done = True
        return self._gs._sync(self._state, g_segments=list(self._segments),
                              key=self._key, participate=self._participate,
                              with_stats=with_stats)


# one-shot deprecation marker for the sync_gradient shim (tests reset it)
_shim_warned = False


def sync_gradient(cfg: SparsifierConfig, state: dict, g: jnp.ndarray,
                  axes: AxisNames, key=None, seg_bounds=None,
                  participate=None, with_stats: bool = False):
    """DEPRECATED thin shim over :class:`GradientSync`.

    Bit-identical to ``GradientSync(cfg, axes, seg_bounds=seg_bounds)(
    state, g, key=key, participate=participate, with_stats=with_stats)``
    — the per-run object is the supported surface (build it once from
    the static fields; call it per step). Warns ``DeprecationWarning``
    exactly once per process.
    """
    global _shim_warned
    if not _shim_warned:
        _shim_warned = True
        warnings.warn(
            "aggregate.sync_gradient is deprecated: build an "
            "aggregate.GradientSync(cfg, axes, ...) once per run and call "
            "it per step (DESIGN.md §2.8).",
            DeprecationWarning, stacklevel=2)
    return GradientSync(cfg, axes, seg_bounds=seg_bounds)(
        state, g, key=key, participate=participate, with_stats=with_stats)


def comm_bytes_per_step(cfg: SparsifierConfig, j: int, n_workers: int,
                        n_active=None) -> dict:
    """Analytic communication volume per worker per step (benchmarks).

    Uses the EFFECTIVE comm mode (DESIGN.md §2.5): configs whose
    compress step packs no pairs move dense bytes even when
    comm_mode="sparse" was requested, and the fused histogram selector
    moves its fixed hist_capacity packed length, not k. Density
    allocation (DESIGN.md §2.6) never changes the volume — every
    allocation mode conserves sum(k_l) == k and packs exactly
    packed_len pairs; the returned dict carries ``allocation`` so
    benchmark rows can still distinguish the modes.

    ``n_active`` (DESIGN.md §2.7): expected live worker count under a
    fault schedule (may be fractional). Models the idealized elastic
    wire — absent workers transmit nothing — which is what a
    participation-aware transport would realize; the in-simulation
    fixed-shape collectives ship inert payloads instead. The ratio
    denominator stays the FULL-fleet dense all-reduce so fault rows
    remain comparable to fault-free ones.
    """
    k = sparsify.resolve_k(cfg, j)
    dense_ar = 2 * j * 4 * (n_workers - 1) / n_workers     # ring all-reduce fp32
    na = n_workers if n_active is None else min(float(n_active),
                                                float(n_workers))
    extra = {} if n_active is None else {"n_active": na}
    d = compress_dispatch(cfg)
    eff = effective_comm_mode(cfg)
    if d.selection == "none" or eff in ("dense", "simulate"):
        b = dense_ar if na <= 1 else 2 * j * 4 * (na - 1) / na
        return {"bytes": b, "k": k, "ratio": b / dense_ar,
                "effective_comm_mode": eff, "allocation": cfg.allocation,
                **extra}
    if d.selection == "sketch":
        # pre-selection sketch all-reduce (participation-invariant: an
        # absent worker's ring slot still moves, carrying zeros) + the
        # shared-mask values-only exchange (indices implied; §2.9)
        sk = sketch_allreduce_bytes(cfg, j, n_workers)
        vb = _wire_value_bytes(cfg)
        vals = na * k * vb
        b = sk + vals
        return {"bytes": b, "k": k, "ratio": b / dense_ar,
                "sketch_bytes": sk, "wire_value_bytes": vb,
                "effective_comm_mode": eff, "allocation": cfg.allocation,
                **extra}
    from repro.kernels.compress.dispatch import packed_len
    kp = packed_len(cfg, j)                 # k, or hist_capacity (fused hist)
    vb = _wire_value_bytes(cfg)             # 4, or 2 for wire_dtype=bf16
    sparse = na * kp * (vb + 4)             # allgather vals+idx, live ranks
    return {"bytes": sparse, "k": k, "packed_len": kp,
            "wire_value_bytes": vb, "ratio": sparse / dense_ar,
            "effective_comm_mode": eff, "allocation": cfg.allocation,
            **extra}


def _wire_value_bytes(cfg: SparsifierConfig) -> int:
    """Wire bytes per packed VALUE (dtype-aware; indices stay uint32)."""
    import numpy as np
    return int(np.dtype(cfg.wire_dtype).itemsize)


def sparse_gather_wire_bytes(cfg: SparsifierConfig, j: int,
                             n_workers: int, n_active=None):
    """Per-device wire bytes of the sparse gradient all-gather, or None
    when the config's EFFECTIVE comm mode is not sparse. This is the
    chunked-collective share the roofline's ``collective_exposed_s``
    overlap model scopes to (roofline/analysis.py) — dtype-aware, so a
    ``wire_dtype="bfloat16"`` run is modeled at its real 6-bytes-per-pair
    payload. Shared-mask configs (dispatch ``wire="values"``) gather
    VALUES ONLY — the coordinated mask implies the indices (§2.9); their
    pre-selection sketch collective is modeled separately
    (:func:`sketch_allreduce_bytes`)."""
    if effective_comm_mode(cfg) != "sparse":
        return None
    from repro.kernels.compress.dispatch import packed_len
    na = n_workers if n_active is None else min(float(n_active),
                                                float(n_workers))
    pair_bytes = _wire_value_bytes(cfg)
    if compress_dispatch(cfg).wire != "values":
        pair_bytes += 4                     # uint32 index rides along
    return na * packed_len(cfg, j) * pair_bytes


def sketch_allreduce_bytes(cfg: SparsifierConfig, j: int, n_workers: int):
    """Per-device wire bytes of the sketch all-reduce pre-selection
    collective (DESIGN.md §2.9), or None for non-sketch selection.
    Ring all-reduce of the (rows, width) fp32 sketch: 2 * rows * width
    * 4 * (N-1)/N. Participation-invariant — absent workers' ring slots
    still move (carrying zero sketches), so no n_active discount
    applies, unlike the values exchange."""
    if compress_dispatch(cfg).selection != "sketch":
        return None
    k = sparsify.resolve_k(cfg, j)
    width = sketch.resolve_width(k, cfg.sketch_width)
    return 2 * cfg.sketch_rows * width * 4 * (n_workers - 1) / n_workers
