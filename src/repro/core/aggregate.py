"""Gradient aggregation paths over the data-parallel mesh axes.

Three communication modes (DESIGN.md §2.1), all used inside ``shard_map``:

- ``dense``    : plain all-reduce (``psum``) of the raw gradient. Baseline.
- ``simulate`` : sparsify locally, all-reduce the (mostly-zero) dense vector.
                 Exact sparsified-training numerics; comm volume unchanged.
                 Used for CPU validation of the paper's claims.
- ``sparse``   : all-gather fixed-k (values, indices) pairs over the data axes
                 and scatter-add locally. Comm per step = N*k*8 bytes instead
                 of ~2*J*4 — the production path whose collective-term drop
                 the roofline quantifies.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro.configs.base import SparsifierConfig
from repro.core import sparsify
from repro.kernels.compress.dispatch import (  # noqa: F401  (re-export)
    dispatch as compress_dispatch,
    effective_comm_mode,
)

AxisNames = Union[str, Sequence[str]]

# (kind, selector, pipeline) combos already warned about — the sparse ->
# simulate degrade is surfaced once per config per process, at trace time
_DEGRADE_WARNED: set = set()


def _warn_sparse_degrade(cfg: SparsifierConfig) -> None:
    keyc = (cfg.kind, cfg.selector, cfg.pipeline)
    if keyc in _DEGRADE_WARNED:
        return
    _DEGRADE_WARNED.add(keyc)
    d = compress_dispatch(cfg)
    # only advise switching pipelines when that actually helps: the
    # fused-pipeline variant of this config must dispatch fused
    fused_var = dataclasses.replace(cfg, pipeline="fused")
    hint = (" pipeline='fused' serves this config sparsely."
            if compress_dispatch(fused_var).path == "fused" else "")
    warnings.warn(
        f"comm_mode='sparse' with kind={cfg.kind!r} selector={cfg.selector!r}"
        f" pipeline={cfg.pipeline!r} packs no fixed-size (values, indices)"
        f" pairs ({d.reason or 'no packed output'}); degrading to a dense"
        " simulate all-reduce (effective_comm_mode(cfg) == 'simulate')."
        + hint,
        RuntimeWarning, stacklevel=3)


def _axis_size(axes: AxisNames) -> jnp.ndarray:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n = n * jax.lax.axis_size(a)
    return n


def dense_allreduce(g: jnp.ndarray, axes: AxisNames) -> jnp.ndarray:
    return jax.lax.pmean(g, axes)


def simulate_allreduce(ghat: jnp.ndarray, axes: AxisNames) -> jnp.ndarray:
    return jax.lax.pmean(ghat, axes)


def sparse_allgather_combine(values: jnp.ndarray, indices: jnp.ndarray,
                             j: int, axes: AxisNames,
                             num_buckets: int = 1,
                             wire_dtype: str = "float32",
                             participate=None, count=None,
                             combine: str = "mean") -> jnp.ndarray:
    """All-gather (k,) sparse contributions over `axes`; dense-combine locally.

    Every worker ends up with g_agg = (1/N) sum_n scatter(values_n, idx_n),
    identical on all data ranks (required: REGTOP-k's posterior distortion
    assumes the same g^t is observed everywhere).

    ``num_buckets > 1`` (DESIGN.md §2.4) splits the packed pairs into
    that many fixed-size chunks and issues ONE collective per chunk:
    chunk b's local scatter-add depends only on chunk b's gather, so
    XLA's latency-hiding scheduler overlaps chunk b+1's all-gather with
    chunk b's compaction instead of serializing one monolithic gather
    ahead of one monolithic scatter. The combined g_agg is the same sum
    (chunking only reorders additions at duplicate indices).

    ``wire_dtype="bfloat16"`` casts the packed VALUES (never the
    indices) right before each chunk's all-gather and upcasts in the
    scatter-add combine: 6 wire bytes per pair instead of 8. Every rank
    applies the same cast, so g_agg stays rank-identical.

    ``participate`` (DESIGN.md §2.7) is this rank's per-step liveness, a
    traced () bool. The collective stays fixed-shape — a sitting-out
    worker ships its (inert) payload like everyone else — but its slots
    are routed out of range and dropped in the combine, and the
    normalizer becomes the ACTIVE worker count. ``count`` marks the live
    packed prefix (None = all k slots); one position test
    ``p_w & (pos < count_w)`` handles histogram-capacity pads and
    chunk-tail pads uniformly. ``combine="support"`` divides each
    coordinate by the number of active workers that selected it instead
    of by n_active (coordinates nobody selected stay 0).
    """
    if isinstance(axes, str):
        axes = (axes,)
    n = _axis_size(axes)
    from repro.core import bigvec
    k = values.shape[0]
    num_buckets = max(1, int(num_buckets))   # 0 (auto) is resolved upstream
    if k <= num_buckets:
        num_buckets = 1          # degenerate: one pair per chunk gains nothing
    chunk = -(-k // num_buckets)
    pad = chunk * num_buckets - k
    if pad:
        # inert tail: scatter-add of 0.0 at index 0
        values = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
        indices = jnp.concatenate([indices, jnp.zeros((pad,), indices.dtype)])
    acc_dtype = values.dtype
    wire_dt = jnp.dtype(wire_dtype)
    dense = jnp.zeros((j,), acc_dtype)
    if participate is None and combine == "mean":
        for b in range(num_buckets):
            vb = values[b * chunk:(b + 1) * chunk].astype(wire_dt)
            ib = indices[b * chunk:(b + 1) * chunk]
            for a in axes:
                vb = jax.lax.all_gather(vb, a)     # stacks leading axis
                ib = jax.lax.all_gather(ib, a)
            dense = bigvec.scatter_add(dense, ib.reshape(-1),
                                       vb.reshape(-1).astype(acc_dtype))
        return dense / n
    if combine not in ("mean", "support"):
        raise ValueError(f"unknown combine={combine!r} (mean | support)")
    # elastic path: two extra scalars per worker on the wire (liveness
    # bit + live count) — the payload collectives are unchanged
    p = (jnp.ones((), jnp.bool_) if participate is None
         else jnp.asarray(participate, jnp.bool_).reshape(()))
    cnt = (jnp.asarray(k if count is None else count, jnp.int32)
           .reshape(()))
    cnt = jnp.where(p, cnt, 0)
    pall = p.astype(jnp.float32)
    call = cnt
    for a in axes:
        pall = jax.lax.all_gather(pall, a)
        call = jax.lax.all_gather(call, a)
    pall = pall.reshape(-1) > 0.5                  # (n,) worker liveness
    call = call.reshape(-1)                        # (n,) live prefix length
    counts = jnp.zeros((j,), jnp.float32) if combine == "support" else None
    for b in range(num_buckets):
        vb = values[b * chunk:(b + 1) * chunk].astype(wire_dt)
        ib = indices[b * chunk:(b + 1) * chunk]
        for a in axes:
            vb = jax.lax.all_gather(vb, a)
            ib = jax.lax.all_gather(ib, a)
        pos = jnp.arange(b * chunk, (b + 1) * chunk, dtype=jnp.int32)
        live = pall[:, None] & (pos[None, :] < call[:, None])   # (n, chunk)
        il = bigvec.live_idx(ib.reshape(n, chunk), live, j).reshape(-1)
        dense = bigvec.scatter_add(dense, il,
                                   vb.reshape(-1).astype(acc_dtype),
                                   mode="drop")
        if counts is not None:
            counts = bigvec.scatter_add(counts, il,
                                        jnp.ones(il.shape, jnp.float32),
                                        mode="drop")
    if combine == "support":
        return jnp.where(counts > 0,
                         dense / jnp.maximum(counts, 1.0).astype(acc_dtype),
                         jnp.zeros((), acc_dtype))
    n_active = jnp.sum(pall.astype(jnp.float32))
    return dense / jnp.maximum(n_active, 1.0).astype(acc_dtype)


def sync_gradient(cfg: SparsifierConfig, state: dict, g: jnp.ndarray,
                  axes: AxisNames, key=None, seg_bounds=None,
                  participate=None, with_stats: bool = False):
    """Full per-step gradient sync for one worker shard (inside shard_map).

    Returns (g_agg, new_state). `g` is this rank's flat local gradient
    (fp32); `axes` are the data-parallel mesh axis name(s). The
    compression pipeline (reference vs fused two-sweep) is selected by
    cfg.pipeline; with pipeline="fused" + comm_mode="sparse" the dense
    ghat is never materialized and the packed (values, indices) feed the
    all-gather directly — zero extra O(J) sweeps for the sparse path.
    cfg.num_buckets > 1 additionally chunks that all-gather into
    per-bucket collectives interleaved with the local scatter-add
    combine (DESIGN.md §2.4 overlap schedule).

    cfg.allocation != "global" (DESIGN.md §2.6) splits the selection
    budget per segment BEFORE compression; ``seg_bounds`` optionally
    pins the segmentation (the train step passes layer-aligned
    TreeFlattener bounds — static python ints, safe under shard_map).
    The wire format is allocation-invariant: compress still packs
    exactly k pairs (sum(k_l) == k), so the sparse collective moves the
    same N*k*(4+wire_value_bytes) bytes in every mode
    (tests/test_allocate.py::TestSyncGradient). Unsupported combos
    raise here at trace time, never degrade silently.

    ``participate`` (DESIGN.md §2.7) is this rank's per-step liveness, a
    traced () bool — when False the rank ships an inert payload, its EF
    memory decays by cfg.err_decay, and the combine averages over the
    active set only. A rank whose packed payload turns non-finite
    (NaN/Inf) is demoted to non-participant for the step BEFORE the
    combine, so one poisoned worker cannot corrupt g_agg. With
    ``with_stats=True`` a third return carries the realized health
    counters {"n_active", "dropped_nonfinite"} (rank-identical psums).
    """
    if cfg.allocation != "global":
        from repro.core import allocate
        allocate.check_allocation(cfg)     # explicit trace-time error
    p = None if participate is None else (
        jnp.asarray(participate, jnp.bool_).reshape(()))
    n = _axis_size(axes)
    zero = jnp.zeros((), jnp.float32)

    def _ret(g_agg, new_state, p_eff, dropped_local):
        if not with_stats:
            return g_agg, new_state
        if p_eff is None:
            stats = {"n_active": jnp.float32(n), "dropped_nonfinite": zero}
        else:
            stats = {"n_active": jax.lax.psum(p_eff.astype(jnp.float32),
                                              axes),
                     "dropped_nonfinite": jax.lax.psum(dropped_local, axes)}
        return g_agg, new_state, stats

    if cfg.kind == "none":
        gd = g.astype(jnp.dtype(cfg.ef_dtype))
        if p is None:
            g_agg = dense_allreduce(gd, axes)
        else:
            dsum = jax.lax.psum(jnp.where(p, gd, jnp.zeros((), gd.dtype)),
                                axes)
            na = jax.lax.psum(p.astype(jnp.float32), axes)
            g_agg = dsum / jnp.maximum(na, 1.0).astype(gd.dtype)
        return _ret(g_agg, {"step": state["step"] + 1}, p, zero)
    if cfg.num_buckets == 0:
        # auto-tune (DESIGN.md §2.4): resolved here, where the real
        # data-parallel axis size is known, so the compress sweeps and
        # the chunked collective share one concrete bucket count
        cfg = dataclasses.replace(cfg, num_buckets=sparsify.resolve_num_buckets(
            cfg, g.shape[0], n))
    omega = 1.0 / n
    if cfg.kind == "globaltopk":
        # genie baseline: TOP-k on the true aggregated accumulated gradient
        from repro.core import select as _select
        gf = g.astype(jnp.float32)
        if p is None:
            a_agg = dense_allreduce(gf, axes)
        else:
            a_agg = jax.lax.psum(jnp.where(p, gf, 0.0), axes)
            na = jax.lax.psum(p.astype(jnp.float32), axes)
            a_agg = a_agg / jnp.maximum(na, 1.0)
        k = sparsify.resolve_k(cfg, g.shape[0])
        mask = _select.topk_mask(a_agg, k, cfg.selector)
        return _ret(mask * a_agg, {"step": state["step"] + 1}, p, zero)
    if cfg.kind == "sketchtopk":
        if p is not None:
            # the shared sketch-coordinated mask has no per-worker
            # sit-out semantics yet — refuse at trace time, never
            # silently average a stale sketch in
            raise NotImplementedError(
                "participation masks are not supported for kind='sketchtopk'")
        g_agg, new_state = _sketch_sync(cfg, state, g, axes)
        return _ret(g_agg, new_state, None, zero)

    out = sparsify.compress(cfg, state, g, key=key, omega=omega,
                            seg_bounds=seg_bounds, participate=p)
    p_eff, dropped = p, zero
    if p is not None and out.values is not None:
        # non-finite payload guard: a worker whose packed values went
        # NaN/Inf is dropped for this step (its EF state already updated
        # under plain participation — one-step posterior skew, §2.7)
        finite = jnp.all(jnp.isfinite(out.values.astype(jnp.float32)))
        p_eff = p & finite
        dropped = (p & ~finite).astype(jnp.float32)
    elastic = p is not None or cfg.combine != "mean"
    if cfg.comm_mode == "sparse" and out.values is not None:
        if elastic:
            g_agg = sparse_allgather_combine(out.values, out.indices,
                                             g.shape[0], axes,
                                             num_buckets=cfg.num_buckets,
                                             wire_dtype=cfg.wire_dtype,
                                             participate=p_eff,
                                             count=out.count,
                                             combine=cfg.combine)
        else:
            g_agg = sparse_allgather_combine(out.values, out.indices,
                                             g.shape[0], axes,
                                             num_buckets=cfg.num_buckets,
                                             wire_dtype=cfg.wire_dtype)
    else:
        if cfg.comm_mode == "sparse":
            # explicit, not silent: this config emits no packed pairs, so
            # the sparse path cannot run — warn once (trace time) and
            # surface the realized mode via effective_comm_mode(cfg)
            _warn_sparse_degrade(cfg)
        ghat = sparsify.dense_ghat(out, g.shape[0])
        if p is not None and out.values is None:
            finite = jnp.all(jnp.isfinite(ghat.astype(jnp.float32)))
            p_eff = p & finite
            dropped = (p & ~finite).astype(jnp.float32)
        if not elastic:
            g_agg = simulate_allreduce(ghat, axes)
        else:
            pe = jnp.ones((), jnp.bool_) if p_eff is None else p_eff
            dsum = jax.lax.psum(
                jnp.where(pe, ghat, jnp.zeros((), ghat.dtype)), axes)
            if cfg.combine == "support":
                m = sparsify.dense_mask(out, g.shape[0])
                cnts = jax.lax.psum(
                    jnp.where(pe, m, jnp.zeros((), m.dtype)), axes)
                g_agg = jnp.where(
                    cnts > 0,
                    dsum / jnp.maximum(cnts, 1.0).astype(ghat.dtype),
                    jnp.zeros((), ghat.dtype))
            else:
                na = jax.lax.psum(pe.astype(jnp.float32), axes)
                g_agg = dsum / jnp.maximum(na, 1.0).astype(ghat.dtype)
    new_state = sparsify.observe_aggregate(cfg, out.state, g_agg,
                                           participate=p_eff)
    return _ret(g_agg, new_state, p_eff, dropped)


def _sketch_sync(cfg: SparsifierConfig, state: dict, g: jnp.ndarray,
                 axes: AxisNames):
    """CountSketch-coordinated global TOP-k (core/sketch.py). One sketch
    all-reduce + value exchange at a SHARED mask."""
    from repro.core import select as _select
    from repro.core import sketch as _sketch
    j = g.shape[0]
    k = sparsify.resolve_k(cfg, j)
    a = state["err"] + g.astype(jnp.dtype(cfg.ef_dtype))
    width = _sketch.resolve_width(k, cfg.sketch_width)
    sk = _sketch.encode(a, cfg.sketch_rows, width)
    sk_agg = jax.lax.pmean(sk, axes)                 # linear sketch of a_agg
    gmag = _sketch.estimate(sk_agg, j)
    mask = _select.topk_mask(gmag, k, cfg.selector)  # identical on all ranks
    ghat = mask * a
    if cfg.comm_mode == "sparse":
        idx = _select.topk_indices(gmag, k)
        from repro.core import bigvec
        vals = bigvec.gather(a, idx)   # uint32-safe for J > 2^31
        g_agg = sparse_allgather_combine(vals, idx, j, axes,
                                         num_buckets=cfg.num_buckets,
                                         wire_dtype=cfg.wire_dtype)
        # combine scatters duplicate indices once per worker; mask-multiply
        # keeps only the shared-mask support (defensive; supports coincide)
        g_agg = g_agg * mask
    else:
        g_agg = jax.lax.pmean(ghat, axes)
    new_state = {"err": a - ghat, "step": state["step"] + 1}
    return g_agg, new_state


def comm_bytes_per_step(cfg: SparsifierConfig, j: int, n_workers: int,
                        n_active=None) -> dict:
    """Analytic communication volume per worker per step (benchmarks).

    Uses the EFFECTIVE comm mode (DESIGN.md §2.5): configs whose
    compress step packs no pairs move dense bytes even when
    comm_mode="sparse" was requested, and the fused histogram selector
    moves its fixed hist_capacity packed length, not k. Density
    allocation (DESIGN.md §2.6) never changes the volume — every
    allocation mode conserves sum(k_l) == k and packs exactly
    packed_len pairs; the returned dict carries ``allocation`` so
    benchmark rows can still distinguish the modes.

    ``n_active`` (DESIGN.md §2.7): expected live worker count under a
    fault schedule (may be fractional). Models the idealized elastic
    wire — absent workers transmit nothing — which is what a
    participation-aware transport would realize; the in-simulation
    fixed-shape collectives ship inert payloads instead. The ratio
    denominator stays the FULL-fleet dense all-reduce so fault rows
    remain comparable to fault-free ones.
    """
    k = sparsify.resolve_k(cfg, j)
    dense_ar = 2 * j * 4 * (n_workers - 1) / n_workers     # ring all-reduce fp32
    na = n_workers if n_active is None else min(float(n_active),
                                                float(n_workers))
    extra = {} if n_active is None else {"n_active": na}
    eff = effective_comm_mode(cfg)
    if cfg.kind == "none" or eff in ("dense", "simulate"):
        b = dense_ar if na <= 1 else 2 * j * 4 * (na - 1) / na
        return {"bytes": b, "k": k, "ratio": b / dense_ar,
                "effective_comm_mode": eff, "allocation": cfg.allocation,
                **extra}
    if cfg.kind == "sketchtopk":
        from repro.core import sketch as _sketch
        width = _sketch.resolve_width(k, cfg.sketch_width)
        sk = 2 * cfg.sketch_rows * width * 4 * (n_workers - 1) / n_workers
        vals = n_workers * k * _wire_value_bytes(cfg)       # indices implied
        b = sk + vals
        return {"bytes": b, "k": k, "ratio": b / dense_ar,
                "sketch_bytes": sk, "effective_comm_mode": eff,
                "allocation": cfg.allocation}
    from repro.kernels.compress.dispatch import packed_len
    kp = packed_len(cfg, j)                 # k, or hist_capacity (fused hist)
    vb = _wire_value_bytes(cfg)             # 4, or 2 for wire_dtype=bf16
    sparse = na * kp * (vb + 4)             # allgather vals+idx, live ranks
    return {"bytes": sparse, "k": k, "packed_len": kp,
            "wire_value_bytes": vb, "ratio": sparse / dense_ar,
            "effective_comm_mode": eff, "allocation": cfg.allocation,
            **extra}


def _wire_value_bytes(cfg: SparsifierConfig) -> int:
    """Wire bytes per packed VALUE (dtype-aware; indices stay uint32)."""
    import numpy as np
    return int(np.dtype(cfg.wire_dtype).itemsize)


def sparse_gather_wire_bytes(cfg: SparsifierConfig, j: int,
                             n_workers: int, n_active=None):
    """Per-device wire bytes of the sparse gradient all-gather, or None
    when the config's EFFECTIVE comm mode is not sparse. This is the
    chunked-collective share the roofline's ``collective_exposed_s``
    overlap model scopes to (roofline/analysis.py) — dtype-aware, so a
    ``wire_dtype="bfloat16"`` run is modeled at its real 6-bytes-per-pair
    payload."""
    # sketchtopk's sketch-coordinated exchange is modeled separately
    # (comm_bytes_per_step); every other non-sparse case already reports
    # itself via effective_comm_mode
    if effective_comm_mode(cfg) != "sparse" or cfg.kind == "sketchtopk":
        return None
    from repro.kernels.compress.dispatch import packed_len
    na = n_workers if n_active is None else min(float(n_active),
                                                float(n_workers))
    return na * packed_len(cfg, j) * (_wire_value_bytes(cfg) + 4)
