"""Top-k mask selection over flat score vectors.

Two selectors:

- ``exact``: ``jax.lax.top_k`` on |score|. Exactly k entries; O(J log k).
  Used on CPU, for small J, and as the oracle for the histogram path.
- ``histogram``: magnitude-histogram threshold (the TPU-native adaptation,
  DESIGN.md §2.2) backed by the Pallas kernel in ``repro.kernels.topk_select``
  with a pure-jnp fallback of identical semantics. Selects all entries with
  |score| >= tau where tau is the histogram-estimated k-th magnitude; the
  selected count is in [k, k*(1+binwidth_slack)].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

HIST_BINS = 2048


# lax.top_k returns int32 indices -> overflows for J > 2^31-1 (qwen-32b's
# per-rank flat gradient is 2.28e9 entries). Above this row size we run a
# TWO-STAGE exact top-k: top-k per row of a (rows, cols) reshape, then top-k
# over the row candidates, with uint32 global indices.
_ROW_LIMIT = 1 << 27


def _two_stage_topk(absx: jnp.ndarray, k: int):
    j = absx.shape[0]
    cols = _ROW_LIMIT
    rows = -(-j // cols)
    pad = rows * cols - j
    xp = jnp.pad(absx, (0, pad), constant_values=-jnp.inf).reshape(rows, cols)
    # exactness requires k candidates per row (a row may hold all of top-k)
    kr = int(min(k, cols))
    vals, idx = jax.lax.top_k(xp, kr)                  # (rows, kr)
    gidx = (jnp.arange(rows, dtype=jnp.uint32)[:, None] * jnp.uint32(cols)
            + idx.astype(jnp.uint32))
    vals = vals.reshape(-1)
    gidx = gidx.reshape(-1)
    _, sel = jax.lax.top_k(vals, int(k))               # candidates < 2^31
    return gidx[sel]


def topk_indices(score: jnp.ndarray, k: int):
    """Top-k indices by |score| (uint32 when J needs it)."""
    j = score.shape[0]
    k = int(min(k, j))
    absx = jnp.abs(score.astype(jnp.float32))
    if j > jnp.iinfo(jnp.int32).max:
        return _two_stage_topk(absx, k)
    _, idx = jax.lax.top_k(absx, k)
    return idx.astype(jnp.uint32)


def topk_mask_exact(score: jnp.ndarray, k: int) -> jnp.ndarray:
    """0/1 mask of the k largest-|score| entries. score: (J,)."""
    from repro.core import bigvec
    j = score.shape[0]
    k = int(min(k, j))
    idx = topk_indices(score, k)
    return bigvec.mask_from_indices(j, idx, score.dtype)


def hist_tail_bin(hist: jnp.ndarray, target) -> jnp.ndarray:
    """Largest bin index b whose tail count (entries in bins >= b) is
    >= target; -1 if none. Shared by every histogram selector (linear
    and bit-pattern) so the count(>= tau) >= target guarantee has one
    implementation."""
    bins = hist.shape[0]
    tail = jnp.cumsum(hist[::-1])[::-1]
    ok = tail >= target
    return jnp.max(jnp.where(ok, jnp.arange(bins), -1))


def histogram_threshold(score: jnp.ndarray, k: int,
                        bins: int = HIST_BINS) -> jnp.ndarray:
    """k-th largest |score| estimated via a linear magnitude histogram.

    Returns tau such that count(|score| >= tau) >= k, with tau at a bin
    boundary (<= exact k-th value, over-selecting by at most one bin's
    population). Pure-jnp reference semantics — the Pallas kernel in
    kernels/topk_select computes the identical histogram.
    """
    amax = jnp.max(jnp.abs(score))
    amax = jnp.where(amax > 0, amax, 1.0)
    scaled = jnp.abs(score) / amax                       # in [0, 1]
    bidx = jnp.clip((scaled * bins).astype(jnp.int32), 0, bins - 1)
    hist = jnp.zeros((bins,), jnp.int32).at[bidx].add(1)
    # largest bin b with tail count >= k  -> threshold at that bin's lower edge
    b = hist_tail_bin(hist, k)
    tau = jnp.where(b >= 0, b.astype(score.dtype) / bins * amax, 0.0)
    return tau


def topk_mask_histogram(score: jnp.ndarray, k: int, bins: int = HIST_BINS,
                        use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        from repro.kernels.topk_select.ops import histogram_threshold_op
        tau = histogram_threshold_op(score, k, bins)
    else:
        tau = histogram_threshold(score, k, bins)
    return (jnp.abs(score) >= tau).astype(score.dtype)


def topk_mask(score: jnp.ndarray, k: int, method: str = "exact") -> jnp.ndarray:
    if method == "exact":
        return topk_mask_exact(score, k)
    if method == "histogram":
        return topk_mask_histogram(score, k)
    if method == "histogram_kernel":
        return topk_mask_histogram(score, k, use_kernel=True)
    raise ValueError(f"unknown selector {method!r}")
