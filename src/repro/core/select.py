"""Top-k mask selection over flat score vectors.

Two selectors:

- ``exact``: ``jax.lax.top_k`` on |score|. Exactly k entries; O(J log k).
  Used on CPU, for small J, and as the oracle for the histogram path.
- ``histogram``: magnitude-histogram threshold (the TPU-native adaptation,
  DESIGN.md §2.2) backed by the Pallas kernel in ``repro.kernels.topk_select``
  with a pure-jnp fallback of identical semantics. Selects all entries with
  |score| >= tau where tau is the histogram-estimated k-th magnitude; the
  selected count is in [k, k*(1+binwidth_slack)].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

HIST_BINS = 2048


# lax.top_k returns int32 indices -> overflows for J > 2^31-1 (qwen-32b's
# per-rank flat gradient is 2.28e9 entries). Above this row size we run a
# TWO-STAGE exact top-k: top-k per row of a (rows, cols) reshape, then top-k
# over the row candidates, with uint32 global indices.
_ROW_LIMIT = 1 << 27


def _two_stage_topk(keys: jnp.ndarray, k: int):
    j = keys.shape[0]
    cols = _ROW_LIMIT
    rows = -(-j // cols)
    pad = rows * cols - j
    if jnp.issubdtype(keys.dtype, jnp.integer):
        padv = jnp.iinfo(keys.dtype).min
    else:
        padv = -jnp.inf
    # pad slots can tie with real minima but sit at the END of their row,
    # and lax.top_k breaks ties by position — real entries always win
    xp = jnp.pad(keys, (0, pad), constant_values=padv).reshape(rows, cols)
    # exactness requires k candidates per row (a row may hold all of top-k)
    kr = int(min(k, cols))
    vals, idx = jax.lax.top_k(xp, kr)                  # (rows, kr)
    gidx = (jnp.arange(rows, dtype=jnp.uint32)[:, None] * jnp.uint32(cols)
            + idx.astype(jnp.uint32))
    vals = vals.reshape(-1)
    gidx = gidx.reshape(-1)
    _, sel = jax.lax.top_k(vals, int(k))               # candidates < 2^31
    return gidx[sel]


def topk_indices_by_key(keys: jnp.ndarray, k: int):
    """Top-k indices of a raw key vector (no abs/cast; any ordered dtype),
    uint32 and two-stage above the int32 row limit."""
    j = keys.shape[0]
    k = int(min(k, j))
    if j > jnp.iinfo(jnp.int32).max:
        return _two_stage_topk(keys, k)
    _, idx = jax.lax.top_k(keys, k)
    return idx.astype(jnp.uint32)


def topk_indices(score: jnp.ndarray, k: int):
    """Top-k indices by |score| (uint32 when J needs it)."""
    return topk_indices_by_key(jnp.abs(score.astype(jnp.float32)), k)


def randk_indices(key, j: int, k: int):
    """Uniform random k-subset of [0, j) without replacement: the top-k
    POSITIONS of j iid uint32 draws (any k-subset is equally likely by
    exchangeability). One O(J log k) top_k over one generated stream —
    no full random permutation (jax.random.choice(replace=False) sorts
    the whole vector) — and uint32-safe for J > 2^31 via the two-stage
    path, which choice's int32 argsort is not. Bit collisions (~2^-32)
    resolve by index order: a bias far below the sampler's own quality.
    Shared by the reference and fused randk paths so their index
    streams are identical."""
    bits = jax.random.bits(key, (j,), jnp.uint32)
    return topk_indices_by_key(bits, int(min(k, j)))


def topk_mask_exact(score: jnp.ndarray, k: int) -> jnp.ndarray:
    """0/1 mask of the k largest-|score| entries. score: (J,)."""
    from repro.core import bigvec
    j = score.shape[0]
    k = int(min(k, j))
    idx = topk_indices(score, k)
    return bigvec.mask_from_indices(j, idx, score.dtype)


def hist_tail_bin(hist: jnp.ndarray, target) -> jnp.ndarray:
    """Largest bin index b whose tail count (entries in bins >= b) is
    >= target; -1 if none. Shared by every histogram selector (linear
    and bit-pattern) so the count(>= tau) >= target guarantee has one
    implementation."""
    bins = hist.shape[0]
    tail = jnp.cumsum(hist[::-1])[::-1]
    ok = tail >= target
    return jnp.max(jnp.where(ok, jnp.arange(bins), -1))


def histogram_threshold(score: jnp.ndarray, k: int,
                        bins: int = HIST_BINS) -> jnp.ndarray:
    """k-th largest |score| estimated via a linear magnitude histogram.

    Returns tau such that count(|score| >= tau) >= k, with tau at a bin
    boundary (<= exact k-th value, over-selecting by at most one bin's
    population). Pure-jnp reference semantics — the Pallas kernel in
    kernels/topk_select computes the identical histogram.
    """
    amax = jnp.max(jnp.abs(score))
    amax = jnp.where(amax > 0, amax, 1.0)
    scaled = jnp.abs(score) / amax                       # in [0, 1]
    bidx = jnp.clip((scaled * bins).astype(jnp.int32), 0, bins - 1)
    hist = jnp.zeros((bins,), jnp.int32).at[bidx].add(1)
    # largest bin b with tail count >= k  -> threshold at that bin's lower edge
    b = hist_tail_bin(hist, k)
    tau = jnp.where(b >= 0, b.astype(score.dtype) / bins * amax, 0.0)
    return tau


def topk_mask_histogram(score: jnp.ndarray, k: int, bins: int = HIST_BINS,
                        use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        from repro.kernels.topk_select.ops import histogram_threshold_op
        tau = histogram_threshold_op(score, k, bins)
    else:
        tau = histogram_threshold(score, k, bins)
    return (jnp.abs(score) >= tau).astype(score.dtype)


def topk_mask(score: jnp.ndarray, k: int, method: str = "exact") -> jnp.ndarray:
    if method == "exact":
        return topk_mask_exact(score, k)
    if method == "histogram":
        return topk_mask_histogram(score, k)
    if method == "histogram_kernel":
        return topk_mask_histogram(score, k, use_kernel=True)
    raise ValueError(f"unknown selector {method!r}")
