"""CountSketch-coordinated global TOP-k (beyond-paper extension).

The paper's Bayesian framework identifies GLOBAL TOP-k (the genie that
selects on the aggregated accumulated gradient) as the ideal sparsifier
(§3.1). REGTOP-k approximates it with one-round-stale evidence; our linreg
study (EXPERIMENTS.md) shows stale evidence plateaus where the genie
converges. This module closes that gap with one cheap extra collective:

1. every worker encodes its accumulated gradient a_n into a CountSketch
   S(a_n) (rows x width, width ~ O(k));
2. one all-reduce of the sketches yields S(sum_n w_n a_n) — sketches are
   LINEAR, so this is a sketch of the true aggregated accumulated gradient;
3. every worker decodes magnitude estimates for all J entries (median of
   rows) and selects the SAME top-k mask -> coordinated selection;
4. workers exchange only the k selected values (mask is shared, so the
   index list is implied).

Extra communication per step: rows*width floats (e.g. 3 x 4k), sub-linear in
J — for a 3B-parameter model at S=1e-3 this is ~0.1% of the dense gradient.

Hashing is stateless (multiplicative universal hashing on the index), so no
O(J) hash tables are stored.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

# fixed odd multipliers (Knuth multiplicative hashing), one pair per row.
# Plain numpy, never device arrays: the fused sweep-1 encode
# (kernels/compress/kernel.py) bakes these into its kernel body as
# python ints — kernels must not capture arrays — and plain hosts
# constants can never leak tracers into a traced caller.
_MULTS = np.array([2654435761, 2246822519, 3266489917, 668265263,
                   374761393, 2654435789, 1597334677, 2869860233],
                  dtype=np.uint32)
_ADDS = np.array([374761393, 3266489917, 1181783497, 2549297995,
                  4279918613, 1609587929, 2246822519, 2654435761],
                 dtype=np.uint32)

_WIDTH_CAP = 1 << 22

# k values already warned about — the width cap is surfaced once per
# process per k, same pattern as aggregate's sparse->simulate degrade
_CAP_WARNED: set = set()


def resolve_width(k: int, width: int = 0) -> int:
    """Effective sketch width: the explicit ``width`` verbatim, else
    4*k clamped to [256, 2^22]. Hitting the upper cap degrades estimate
    quality (more colliding coordinates per bucket than the 4x
    provisioning assumes) — warned once, never silent."""
    if width:
        return int(width)
    w = max(4 * k, 256)
    if w > _WIDTH_CAP:
        if k not in _CAP_WARNED:
            _CAP_WARNED.add(k)
            warnings.warn(
                f"sketch width 4*k = {w} exceeds the {_WIDTH_CAP} "
                f"auto-width cap at k={k}; the capped sketch packs "
                f"~{4 * k / _WIDTH_CAP:.1f}x more coordinates per bucket "
                "than the 4x provisioning assumes, degrading the "
                "magnitude estimates. Set SparsifierConfig.sketch_width "
                "explicitly to override the cap.",
                RuntimeWarning, stacklevel=2)
        return _WIDTH_CAP
    return int(w)


def _hashes(j: int, rows: int, width: int):
    """(h (rows, J) bucket indices, s (rows, J) ±1 signs), stateless."""
    idx = jnp.arange(j, dtype=jnp.uint32)
    m = _MULTS[:rows, None]
    a = _ADDS[:rows, None]
    x = idx[None, :] * m + a
    h = (x >> 8).astype(jnp.uint32) % jnp.uint32(width)
    s = ((x >> 31) & 1).astype(jnp.float32) * 2.0 - 1.0
    return h.astype(jnp.int32), s


def encode(a: jnp.ndarray, rows: int, width: int) -> jnp.ndarray:
    """a (J,) -> sketch (rows, width). Linear in a."""
    h, s = _hashes(a.shape[0], rows, width)
    af = a.astype(jnp.float32)

    def one_row(hr, sr):
        return jnp.zeros((width,), jnp.float32).at[hr].add(sr * af)

    return jax.vmap(one_row)(h, s)


def estimate(sketch: jnp.ndarray, j: int) -> jnp.ndarray:
    """Magnitude estimates for all J entries (median over rows)."""
    rows, width = sketch.shape
    h, s = _hashes(j, rows, width)
    vals = jax.vmap(lambda skr, hr, sr: sr * skr[hr])(sketch, h, s)
    return jnp.median(vals, axis=0)
