"""Deterministic fault-injection schedules for elastic aggregation.

A :class:`FaultSchedule` decides, per (step, worker), whether that
worker participates in the sparsified gradient sync (DESIGN.md §2.7).
The decision function is a pure, seeded function of ``(schedule, step,
worker)`` — traced-safe, so it runs INSIDE the shard_map'd train step
from the per-rank step counter and data-parallel axis index, and the
same schedule replays bit-identically across processes, restarts, and
the host-side analysis helpers below.

Three schedule kinds (the spec strings the ``--fault-schedule`` flag
parses):

- ``iid:<p>[,seed=<s>]``             — every worker independently drops
  each step with probability p (seeded PRNG, deterministic per
  (seed, step, worker)).
- ``bursty:period=<P>,outage=<O>[,workers=<i+j+...>]`` — the listed
  workers (default: worker 0) sit out the first O steps of every
  P-step window: a correlated, recurring outage (rack reboot, shared
  network partition).
- ``permanent:step=<t>[,workers=<i+j+...>]`` — the listed workers
  (default: worker 0) drop at step t and never return: permanent loss.

"Participation" composes downstream: ``train/step.py`` evaluates the
schedule per rank per step, ``core/aggregate.sync_gradient`` masks that
worker's packed payload inert and decays its error-feedback state
(``SparsifierConfig.err_decay``), and the non-finite payload guard can
force a scheduled-in worker out for one step (a dropped-for-health
worker is treated exactly like a scheduled absence).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

KINDS = ("iid", "bursty", "permanent")


@dataclass(frozen=True)
class FaultSchedule:
    kind: str                   # "iid" | "bursty" | "permanent"
    drop_prob: float = 0.0      # iid: per-(step, worker) drop probability
    period: int = 0             # bursty: window length in steps
    outage: int = 0             # bursty: down-steps per window
    fail_step: int = 0          # permanent: first dead step
    workers: tuple = (0,)       # bursty/permanent: affected worker indices
    seed: int = 0               # iid: PRNG stream seed


def parse_schedule(spec: str) -> Optional[FaultSchedule]:
    """Parse a ``--fault-schedule`` spec string; "" / "none" -> None.

    Grammar: ``<kind>:<args>`` with comma-separated ``key=value`` args
    (worker lists are ``+``-joined: ``workers=1+3``). The iid kind also
    accepts a bare leading probability: ``iid:0.3``.
    """
    spec = (spec or "").strip()
    if not spec or spec == "none":
        return None
    kind, _, rest = spec.partition(":")
    if kind not in KINDS:
        raise ValueError(
            f"unknown fault schedule kind {kind!r} in {spec!r}; "
            f"expected one of {KINDS}")
    kv = {}
    for i, part in enumerate(p for p in rest.split(",") if p):
        if "=" not in part:
            if kind == "iid" and i == 0:
                kv["p"] = part
                continue
            raise ValueError(f"malformed fault schedule arg {part!r} "
                             f"in {spec!r} (want key=value)")
        k, v = part.split("=", 1)
        kv[k.strip()] = v.strip()
    workers = tuple(int(w) for w in kv.get("workers", "0").split("+"))
    if kind == "iid":
        p = float(kv.get("p", kv.get("drop_prob", "0")))
        if not 0.0 <= p < 1.0:
            raise ValueError(f"iid drop probability must be in [0, 1): {p}")
        return FaultSchedule("iid", drop_prob=p, seed=int(kv.get("seed", 0)))
    if kind == "bursty":
        period = int(kv.get("period", 0))
        outage = int(kv.get("outage", 0))
        if period <= 0 or not 0 <= outage <= period:
            raise ValueError(
                f"bursty schedule needs period > 0 and 0 <= outage <= "
                f"period: {spec!r}")
        return FaultSchedule("bursty", period=period, outage=outage,
                             workers=workers)
    fail_step = int(kv.get("step", kv.get("fail_step", 0)))
    return FaultSchedule("permanent", fail_step=fail_step, workers=workers)


def format_schedule(sched: Optional[FaultSchedule]) -> str:
    """Inverse of :func:`parse_schedule` (round-trips through it)."""
    if sched is None:
        return ""
    w = "+".join(str(i) for i in sched.workers)
    if sched.kind == "iid":
        return f"iid:{sched.drop_prob},seed={sched.seed}"
    if sched.kind == "bursty":
        return f"bursty:period={sched.period},outage={sched.outage},workers={w}"
    return f"permanent:step={sched.fail_step},workers={w}"


def participates(sched: Optional[FaultSchedule], step, worker):
    """Does ``worker`` participate in the sync at ``step``? Traced-safe
    () bool — ``step``/``worker`` may be traced int32 scalars (the
    shard_map'd train step passes its state counter and data-parallel
    axis index), or concrete ints (the host-side helpers below).

    Deterministic in (schedule, step, worker): every rank evaluating its
    own bit agrees with every analysis replay of the same schedule.
    """
    if sched is None:
        return jnp.asarray(True)
    step = jnp.asarray(step, jnp.int32)
    worker = jnp.asarray(worker, jnp.int32)
    if sched.kind == "iid":
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(sched.seed), step), worker)
        return jax.random.uniform(key) >= sched.drop_prob
    affected = jnp.any(worker == jnp.asarray(sched.workers, jnp.int32))
    if sched.kind == "bursty":
        in_outage = (step % sched.period) < sched.outage
        return ~(affected & in_outage)
    return ~(affected & (step >= sched.fail_step))       # permanent


def participation_matrix(sched: Optional[FaultSchedule], steps: int,
                         n_workers: int):
    """Host-side replay: (steps, n_workers) bool numpy array of the
    schedule's participation bits (analysis / test oracles)."""
    import numpy as np
    out = np.ones((steps, n_workers), bool)
    for t in range(steps):
        for w in range(n_workers):
            out[t, w] = bool(participates(sched, t, w))
    return out


def expected_active(sched: Optional[FaultSchedule], n_workers: int) -> float:
    """Steady-state expected participating worker count — the
    ``n_active`` dimension of the analytic cost models
    (``core.aggregate.comm_bytes_per_step`` and the roofline's
    straggler-exposed collective term)."""
    n = float(n_workers)
    if sched is None:
        return n
    if sched.kind == "iid":
        return n * (1.0 - sched.drop_prob)
    naff = float(len([w for w in sched.workers if 0 <= w < n_workers]))
    if sched.kind == "bursty":
        return n - naff * (sched.outage / float(sched.period))
    return n - naff                                      # permanent


def describe(sched: Optional[FaultSchedule], n_workers: int) -> dict:
    """JSON-serializable record of the fault config (dryrun records)."""
    if sched is None:
        return {"schedule": "", "n_active_expected": float(n_workers)}
    return {"schedule": format_schedule(sched),
            "kind": sched.kind,
            "n_active_expected": expected_active(sched, n_workers)}
