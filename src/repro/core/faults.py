"""Deterministic fault-injection schedules for elastic aggregation.

A :class:`FaultSchedule` decides, per (step, worker), whether that
worker participates in the sparsified gradient sync (DESIGN.md §2.7).
The decision function is a pure, seeded function of ``(schedule, step,
worker)`` — traced-safe, so it runs INSIDE the shard_map'd train step
from the per-rank step counter and data-parallel axis index, and the
same schedule replays bit-identically across processes, restarts, and
the host-side analysis helpers below.

Three schedule kinds (the spec strings the ``--fault-schedule`` flag
parses):

- ``iid:<p>[,seed=<s>]``             — every worker independently drops
  each step with probability p (seeded PRNG, deterministic per
  (seed, step, worker)).
- ``bursty:period=<P>,outage=<O>[,workers=<i+j+...>]`` — the listed
  workers (default: worker 0) sit out the first O steps of every
  P-step window: a correlated, recurring outage (rack reboot, shared
  network partition).
- ``permanent:step=<t>[,workers=<i+j+...>]`` — the listed workers
  (default: worker 0) drop at step t and never return: permanent loss.

"Participation" composes downstream: ``train/step.py`` evaluates the
schedule per rank per step, ``core/aggregate.sync_gradient`` masks that
worker's packed payload inert and decays its error-feedback state
(``SparsifierConfig.err_decay``), and the non-finite payload guard can
force a scheduled-in worker out for one step (a dropped-for-health
worker is treated exactly like a scheduled absence).

Delta-channel faults (DESIGN.md §2.10) live in the second half of this
module: :class:`ChannelFaultSchedule` decides, per published
``param_version``, what the trainer→replica delta broadcast does to
that payload — dropped (``loss:p``), bit-corrupted in flight
(``corrupt:p``), delivered late/out-of-order (``reorder:window``), or
held back with the rest of a stall window and flushed afterwards
(``stall:steps``). Same discipline as the participation schedules:
pure, seeded functions of ``(schedule, version)``, traced-safe, with
the same parse/format/describe surface, so a fault trace replays
bit-identically in tests, launchers, and analysis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

KINDS = ("iid", "bursty", "permanent")


@dataclass(frozen=True)
class FaultSchedule:
    kind: str                   # "iid" | "bursty" | "permanent"
    drop_prob: float = 0.0      # iid: per-(step, worker) drop probability
    period: int = 0             # bursty: window length in steps
    outage: int = 0             # bursty: down-steps per window
    fail_step: int = 0          # permanent: first dead step
    workers: tuple = (0,)       # bursty/permanent: affected worker indices
    seed: int = 0               # iid: PRNG stream seed


def parse_schedule(spec: str) -> Optional[FaultSchedule]:
    """Parse a ``--fault-schedule`` spec string; "" / "none" -> None.

    Grammar: ``<kind>:<args>`` with comma-separated ``key=value`` args
    (worker lists are ``+``-joined: ``workers=1+3``). The iid kind also
    accepts a bare leading probability: ``iid:0.3``.
    """
    spec = (spec or "").strip()
    if not spec or spec == "none":
        return None
    kind, _, rest = spec.partition(":")
    if kind not in KINDS:
        raise ValueError(
            f"unknown fault schedule kind {kind!r} in {spec!r}; "
            f"expected one of {KINDS}")
    kv = {}
    for i, part in enumerate(p for p in rest.split(",") if p):
        if "=" not in part:
            if kind == "iid" and i == 0:
                kv["p"] = part
                continue
            raise ValueError(f"malformed fault schedule arg {part!r} "
                             f"in {spec!r} (want key=value)")
        k, v = part.split("=", 1)
        kv[k.strip()] = v.strip()
    workers = tuple(int(w) for w in kv.get("workers", "0").split("+"))
    if kind == "iid":
        p = float(kv.get("p", kv.get("drop_prob", "0")))
        if not 0.0 <= p < 1.0:
            raise ValueError(f"iid drop probability must be in [0, 1): {p}")
        return FaultSchedule("iid", drop_prob=p, seed=int(kv.get("seed", 0)))
    if kind == "bursty":
        period = int(kv.get("period", 0))
        outage = int(kv.get("outage", 0))
        if period <= 0 or not 0 <= outage <= period:
            raise ValueError(
                f"bursty schedule needs period > 0 and 0 <= outage <= "
                f"period: {spec!r}")
        return FaultSchedule("bursty", period=period, outage=outage,
                             workers=workers)
    fail_step = int(kv.get("step", kv.get("fail_step", 0)))
    return FaultSchedule("permanent", fail_step=fail_step, workers=workers)


def format_schedule(sched: Optional[FaultSchedule]) -> str:
    """Inverse of :func:`parse_schedule` (round-trips through it)."""
    if sched is None:
        return ""
    w = "+".join(str(i) for i in sched.workers)
    if sched.kind == "iid":
        return f"iid:{sched.drop_prob},seed={sched.seed}"
    if sched.kind == "bursty":
        return f"bursty:period={sched.period},outage={sched.outage},workers={w}"
    return f"permanent:step={sched.fail_step},workers={w}"


def participates(sched: Optional[FaultSchedule], step, worker):
    """Does ``worker`` participate in the sync at ``step``? Traced-safe
    () bool — ``step``/``worker`` may be traced int32 scalars (the
    shard_map'd train step passes its state counter and data-parallel
    axis index), or concrete ints (the host-side helpers below).

    Deterministic in (schedule, step, worker): every rank evaluating its
    own bit agrees with every analysis replay of the same schedule.
    """
    if sched is None:
        return jnp.asarray(True)
    step = jnp.asarray(step, jnp.int32)
    worker = jnp.asarray(worker, jnp.int32)
    if sched.kind == "iid":
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(sched.seed), step), worker)
        return jax.random.uniform(key) >= sched.drop_prob
    affected = jnp.any(worker == jnp.asarray(sched.workers, jnp.int32))
    if sched.kind == "bursty":
        in_outage = (step % sched.period) < sched.outage
        return ~(affected & in_outage)
    return ~(affected & (step >= sched.fail_step))       # permanent


def participation_matrix(sched: Optional[FaultSchedule], steps: int,
                         n_workers: int):
    """Host-side replay: (steps, n_workers) bool numpy array of the
    schedule's participation bits (analysis / test oracles)."""
    import numpy as np
    out = np.ones((steps, n_workers), bool)
    for t in range(steps):
        for w in range(n_workers):
            out[t, w] = bool(participates(sched, t, w))
    return out


def expected_active(sched: Optional[FaultSchedule], n_workers: int) -> float:
    """Steady-state expected participating worker count — the
    ``n_active`` dimension of the analytic cost models
    (``core.aggregate.comm_bytes_per_step`` and the roofline's
    straggler-exposed collective term)."""
    n = float(n_workers)
    if sched is None:
        return n
    if sched.kind == "iid":
        return n * (1.0 - sched.drop_prob)
    naff = float(len([w for w in sched.workers if 0 <= w < n_workers]))
    if sched.kind == "bursty":
        return n - naff * (sched.outage / float(sched.period))
    return n - naff                                      # permanent


def describe(sched: Optional[FaultSchedule], n_workers: int) -> dict:
    """JSON-serializable record of the fault config (dryrun records)."""
    if sched is None:
        return {"schedule": "", "n_active_expected": float(n_workers)}
    return {"schedule": format_schedule(sched),
            "kind": sched.kind,
            "n_active_expected": expected_active(sched, n_workers)}


# ---------------------------------------------------------------------------
# Delta-channel fault schedules (DESIGN.md §2.10)
#
# The trainer→replica delta broadcast is a lossy channel by contract:
# a ChannelFaultSchedule decides, per published param_version, what the
# channel does to that payload. Four kinds (the ``--delta-fault-schedule``
# spec strings):
#
# - ``loss:<p>[,seed=<s>]``    — each version independently dropped with
#   probability p (never delivered; the replica sees a version gap).
# - ``corrupt:<p>[,seed=<s>]`` — each version independently bit-flipped
#   in flight with probability p AFTER the checksum was stamped, so the
#   applier's guard detects and drops it (→ a gap, like loss, but the
#   ``dropped_corrupt`` counter fires instead of silent absence).
# - ``reorder:<window>[,seed=<s>]`` — each version delayed by a seeded
#   integer in [0, window] versions; deliveries interleave out of order.
#   The applier's monotonic gate drops stale arrivals and gap-detects
#   early ones.
# - ``stall:<steps>[,every=<P>][,at=<v>]`` — the channel buffers every
#   version inside the stall window and flushes them IN ORDER when the
#   window ends (a paused link, not a lossy one: the replica catches up
#   by applying the backlog, no resync needed). One-shot at version
#   ``at`` (default 1) unless ``every>0`` makes it periodic.
#
# Same discipline as the participation schedules above: pure seeded
# functions of (schedule, version), traced-safe, bit-identical across
# the channel implementation, test oracles, and analysis replays.
# ---------------------------------------------------------------------------

CHANNEL_KINDS = ("loss", "corrupt", "reorder", "stall")


@dataclass(frozen=True)
class ChannelFaultSchedule:
    kind: str            # "loss" | "corrupt" | "reorder" | "stall"
    prob: float = 0.0    # loss/corrupt: per-version event probability
    window: int = 0      # reorder: max delivery delay, in versions
    steps: int = 0       # stall: buffered versions per stall window
    every: int = 0       # stall: window period (0 = one-shot)
    at: int = 1          # stall: first stalled version
    seed: int = 0        # loss/corrupt/reorder: PRNG stream seed


def parse_channel_schedule(spec: str) -> Optional[ChannelFaultSchedule]:
    """Parse a ``--delta-fault-schedule`` spec; "" / "none" -> None.

    Grammar mirrors :func:`parse_schedule`: ``<kind>:<args>`` with
    comma-separated ``key=value`` args; the leading arg may be bare
    (``loss:0.3`` == ``loss:p=0.3``, ``reorder:4`` == ``reorder:window=4``,
    ``stall:10`` == ``stall:steps=10``).
    """
    spec = (spec or "").strip()
    if not spec or spec == "none":
        return None
    kind, _, rest = spec.partition(":")
    if kind not in CHANNEL_KINDS:
        raise ValueError(
            f"unknown delta-channel fault kind {kind!r} in {spec!r}; "
            f"expected one of {CHANNEL_KINDS}")
    bare_key = {"loss": "p", "corrupt": "p",
                "reorder": "window", "stall": "steps"}[kind]
    kv = {}
    for i, part in enumerate(p for p in rest.split(",") if p):
        if "=" not in part:
            if i == 0:
                kv[bare_key] = part
                continue
            raise ValueError(f"malformed delta-channel fault arg {part!r} "
                             f"in {spec!r} (want key=value)")
        k, v = part.split("=", 1)
        kv[k.strip()] = v.strip()
    seed = int(kv.get("seed", 0))
    if kind in ("loss", "corrupt"):
        p = float(kv.get("p", kv.get("prob", "0")))
        if not 0.0 <= p < 1.0:
            raise ValueError(
                f"{kind} probability must be in [0, 1): {p}")
        return ChannelFaultSchedule(kind, prob=p, seed=seed)
    if kind == "reorder":
        window = int(kv.get("window", 0))
        if window < 1:
            raise ValueError(f"reorder window must be >= 1: {spec!r}")
        return ChannelFaultSchedule("reorder", window=window, seed=seed)
    steps = int(kv.get("steps", 0))
    every = int(kv.get("every", 0))
    at = int(kv.get("at", 1))
    if steps < 1 or (every and every < steps):
        raise ValueError(
            f"stall schedule needs steps >= 1 and every in {{0}} ∪ "
            f"[steps, inf): {spec!r}")
    return ChannelFaultSchedule("stall", steps=steps, every=every, at=at)


def format_channel_schedule(sched: Optional[ChannelFaultSchedule]) -> str:
    """Inverse of :func:`parse_channel_schedule` (round-trips)."""
    if sched is None:
        return ""
    if sched.kind in ("loss", "corrupt"):
        return f"{sched.kind}:{sched.prob},seed={sched.seed}"
    if sched.kind == "reorder":
        return f"reorder:{sched.window},seed={sched.seed}"
    return f"stall:{sched.steps},every={sched.every},at={sched.at}"


def _channel_key(sched: ChannelFaultSchedule, version):
    salt = CHANNEL_KINDS.index(sched.kind)
    key = jax.random.fold_in(jax.random.PRNGKey(sched.seed), salt)
    return jax.random.fold_in(key, jnp.asarray(version, jnp.int32))


def channel_drops(sched: Optional[ChannelFaultSchedule], version):
    """Does the channel drop (never deliver) this version? Traced-safe
    () bool, deterministic in (schedule, version)."""
    if sched is None or sched.kind != "loss":
        return jnp.asarray(False)
    return jax.random.uniform(_channel_key(sched, version)) < sched.prob


def channel_corrupts(sched: Optional[ChannelFaultSchedule], version):
    """Does the channel bit-flip this version's payload in flight
    (after checksum stamping, so the applier detects it)?"""
    if sched is None or sched.kind != "corrupt":
        return jnp.asarray(False)
    return jax.random.uniform(_channel_key(sched, version)) < sched.prob


def channel_delay(sched: Optional[ChannelFaultSchedule], version):
    """Delivery delay, in versions, the channel imposes on this version
    (0 for non-reorder schedules). Versions are delivered in
    ``(version + delay, version)`` order."""
    if sched is None or sched.kind != "reorder":
        return jnp.asarray(0, jnp.int32)
    return jax.random.randint(_channel_key(sched, version), (),
                              0, sched.window + 1)


def channel_stalled(sched: Optional[ChannelFaultSchedule], version):
    """Is this version inside a stall window (buffered, flushed in
    order when the window ends)?"""
    if sched is None or sched.kind != "stall":
        return jnp.asarray(False)
    v = jnp.asarray(version, jnp.int32)
    if sched.every > 0:
        return (v >= sched.at) & ((v - sched.at) % sched.every < sched.steps)
    return (v >= sched.at) & (v < sched.at + sched.steps)


def expected_delivery_rate(sched: Optional[ChannelFaultSchedule]) -> float:
    """Steady-state fraction of published versions the applier ACCEPTS
    first-try (no gap, no drop) — the staleness dimension of the §2.10
    cost model. loss/corrupt remove mass outright; reorder and stall
    deliver everything eventually (rate 1.0 — they cost staleness, not
    versions)."""
    if sched is None:
        return 1.0
    if sched.kind in ("loss", "corrupt"):
        return 1.0 - sched.prob
    return 1.0


def describe_channel(sched: Optional[ChannelFaultSchedule]) -> dict:
    """JSON-serializable record of the channel fault config."""
    if sched is None:
        return {"schedule": "", "delivery_rate_expected": 1.0}
    return {"schedule": format_channel_schedule(sched),
            "kind": sched.kind,
            "delivery_rate_expected": expected_delivery_rate(sched)}
