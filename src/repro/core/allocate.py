"""Layer-adaptive density allocation: split the global budget k across
segments BEFORE selection (DESIGN.md §2.6).

The paper's REGTOP-k statistics are computed over the whole flattened
gradient, but the sparsity budget itself need not be uniform: *Adaptive
Top-K in SGD* (Ruan et al., 2022) derives per-layer k from gradient
statistics, and *rTop-k* (Barnes et al., 2020) shows a statistical split
of the budget beats pure magnitude selection. This module owns that
split. A **segment** is a contiguous slice of the flat gradient — a
near-equal partition (``segment_bounds``) by default, or leaf-aligned
"layer" bounds from the model's ``TreeFlattener`` metadata
(``layer_segments``; the train step passes these, so segments track real
parameter groups).

``SparsifierConfig.allocation`` selects the mode:

- ``"global"``       : one global top-k over the flat vector — today's
  behavior, bit-identical (the allocation machinery is never entered).
- ``"proportional"`` : k_l proportional to J_l (largest-remainder
  apportionment, static Python ints). With near-equal segments this is
  global-budget-per-slice; with layer segments it is per-layer top-k at
  uniform density.
- ``"adaptive"``     : k_l from per-segment second-moment (top-mass)
  statistics of the selection score, computed O(segments) from the
  sweep products the fused pipeline already makes (candidate covers /
  dense slices) — no extra O(J) traversal (audit-gated at 2.0 sweeps,
  ``tests/test_allocate.py::TestAllocatedSweepCount``). The per-element
  intensity ratio is clipped to [1/ADAPTIVE_CLIP, ADAPTIVE_CLIP] of the
  global mean, so adaptive quotas deviate at most ADAPTIVE_CLIP**2 x
  from the proportional share — which bounds candidate provisioning
  (``segment_caps``) statically and prevents degenerate all-in-one-
  segment allocations.

**Budget conservation** is exact in every mode: sum(k_l) == k
(including remainder distribution, per-segment caps k_l <= J_l with
overflow redistribution, and the >=1-per-segment floor when k >=
num_segments), pinned by ``tests/test_allocate.py::TestApportionment``.
The packed wire format is unchanged — compress still emits exactly k
(values, indices) pairs, so ``aggregate.sync_gradient`` moves the same
bytes for every allocation mode.

Supported configs (``check_allocation``): kind in {topk, dgc, regtopk,
thresholdk, randk} with selector="exact" (exact-count selection is what
makes sum(k_l) == k conservable; the histogram selector over-selects per
threshold). randk is score-free: allocation="adaptive" degrades to the
proportional split for it (documented, not silent — there is no score
statistic to adapt to). Everything is O(segments + k) beyond the sweeps
the pipelines already run.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

ALLOCATION_MODES = ("global", "proportional", "adaptive")
# kinds with a per-worker compress step whose selection can honor
# per-segment counts (aggregate-level / sketch-coordinated kinds cannot)
ALLOCATED_KINDS = ("topk", "dgc", "regtopk", "thresholdk", "randk")
# near-equal segment count when num_segments=0 and buckets don't decide
DEFAULT_SEGMENTS = 8
# adaptive per-element intensity ratio clip: quotas deviate at most
# ADAPTIVE_CLIP**2 x from the proportional share (the bounded-deviation
# rule that keeps candidate provisioning static and O(k))
ADAPTIVE_CLIP = 2.0
# additive per-segment provisioning headroom on top of the clipped quota
ADAPTIVE_SLACK = 64


def check_allocation(cfg) -> None:
    """Raise ValueError for configs the allocation subsystem cannot
    serve (explicit, never silent — mirroring the §2.5 dispatch rule).
    allocation="global" is universally valid (it is the no-op mode)."""
    if cfg.allocation not in ALLOCATION_MODES:
        raise ValueError(f"unknown allocation {cfg.allocation!r}; "
                         f"known: {ALLOCATION_MODES}")
    if cfg.allocation == "global":
        return
    if cfg.kind not in ALLOCATED_KINDS:
        raise ValueError(
            f"allocation={cfg.allocation!r} needs a per-worker compress "
            f"step that can honor per-segment counts; kind={cfg.kind!r} "
            "selects at the aggregate/sketch level (supported kinds: "
            f"{ALLOCATED_KINDS})")
    if cfg.kind != "randk" and cfg.selector != "exact":
        raise ValueError(
            f"allocation={cfg.allocation!r} requires selector='exact': "
            "per-segment budget conservation (sum k_l == k) needs "
            "exact-count selection, and the histogram selector "
            f"over-selects per threshold (got selector={cfg.selector!r})")
    if (cfg.kind == "regtopk" and cfg.pipeline != "fused"
            and cfg.state_format == "sparse"):
        raise ValueError(
            "allocation != 'global' is not implemented for the reference "
            "pipeline's regtopk state_format='sparse' layout; use "
            "state_format='dense' or pipeline='fused'")


def resolve_num_segments(cfg, j: int) -> int:
    """Concrete segment count for a config: cfg.num_segments, with 0
    resolved to the bucket partition (segments follow buckets when
    num_buckets > 1, so per-segment sweeps and the chunked collective
    share one cut) or DEFAULT_SEGMENTS for the flat schedule. Clamped to
    [1, j] — a segment is never empty."""
    ns = int(cfg.num_segments)
    if ns <= 0:
        ns = cfg.num_buckets if cfg.num_buckets > 1 else DEFAULT_SEGMENTS
    return max(1, min(ns, max(1, int(j))))


def segment_bounds(j: int, num_segments: int) -> list:
    """Near-equal contiguous segmentation of [0, j): [(offset, size),
    ...] — the same deterministic partition rule the bucketed pipeline
    uses (core.flatten.bucket_bounds)."""
    from repro.core.flatten import bucket_bounds
    return bucket_bounds(j, num_segments)


def layer_segments(leaves, max_segments: int) -> list:
    """Leaf-aligned "layer" segmentation: group consecutive flat-vector
    leaves into at most ``max_segments`` contiguous segments of
    near-equal total size, never cutting inside a leaf. ``leaves`` is
    either a list of leaf sizes (TreeFlattener.sizes order) or of
    (offset, size) pairs (TreeFlattener.layer_bounds()). Returns
    [(offset, size), ...] with sum(sizes) == sum(leaf sizes) and every
    segment non-empty. Deterministic in its inputs (a pure function of
    the static leaf layout)."""
    sizes = [int(x[1]) if isinstance(x, (tuple, list)) else int(x)
             for x in leaves]
    j = sum(sizes)
    if j <= 0:
        return [(0, 0)]
    n = len(sizes)
    # positive-leaf suffix counts: a segment boundary must leave at least
    # one positive leaf per remaining segment
    pos_after = [0] * (n + 1)
    for i in range(n - 1, -1, -1):
        pos_after[i] = pos_after[i + 1] + (1 if sizes[i] > 0 else 0)
    s = max(1, min(int(max_segments), pos_after[0]))
    bounds, off, i, rem_j = [], 0, 0, j
    for seg in range(s):
        rem_segs = s - seg
        if rem_segs == 1:
            bounds.append((off, j - off))
            break
        target = rem_j / rem_segs
        acc = 0
        while i < n:
            take = sizes[i]
            if acc > 0 and pos_after[i] <= rem_segs - 1:
                break                       # leaves reserved for the rest
            if acc > 0 and abs(acc + take - target) > abs(acc - target):
                break                       # next leaf overshoots the target
            acc += take
            i += 1
        bounds.append((off, acc))
        off += acc
        rem_j -= acc
    return bounds


def segment_caps(k: int, sizes) -> list:
    """Static per-segment selection/provisioning cap: the most entries
    any allocation mode may assign to segment l —
    min(J_l, k, ceil(ADAPTIVE_CLIP**2 * k * J_l / J) + ADAPTIVE_SLACK).
    Every mode's k_l satisfies k_l <= cap_l (proportional by
    construction; adaptive by the intensity clip + the integerizer's
    cap-overflow redistribution), so candidate provisioning sized for
    cap_l always covers the realized count. sum(caps) >= k always
    (each cap >= the proportional quota)."""
    sizes = [int(x) for x in sizes]
    j = sum(sizes)
    k = int(min(k, j))
    caps = [int(min(sz, k,
                    math.ceil(ADAPTIVE_CLIP ** 2 * k * sz / j)
                    + ADAPTIVE_SLACK))
            for sz in sizes]
    assert sum(caps) >= k, (k, sizes, caps)
    return caps


def proportional_counts(k: int, sizes) -> list:
    """Static largest-remainder apportionment of k over segment sizes:
    k_l ~ k * J_l / J, sum(k_l) == k exactly, 0 <= k_l <= J_l, with the
    >=1-per-segment floor applied when k >= num_segments (taken from
    the largest counts, deterministically). Pure Python ints — safe to
    bake into traced code as constants."""
    sizes = [int(x) for x in sizes]
    s, j = len(sizes), sum(sizes)
    k = int(min(k, j))
    base = [(k * sz) // j for sz in sizes]
    rems = [(k * sz) % j for sz in sizes]
    extra = k - sum(base)
    for i in sorted(range(s), key=lambda t: (-rems[t], t))[:extra]:
        base[i] += 1                        # base+1 <= ceil(k*J_l/J) <= J_l
    if k >= s:                              # floor: every segment sends >= 1
        for i in range(s):
            while base[i] < 1:
                d = max(range(s), key=lambda t: (base[t], -t))
                base[d] -= 1
                base[i] += 1
    return base


def _excl_cumsum(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([jnp.zeros((1,), x.dtype), jnp.cumsum(x)[:-1]])


def _integerize_counts(quota: jnp.ndarray, caps: jnp.ndarray, k: int,
                       lo: int) -> jnp.ndarray:
    """Exact traced integerization of real quotas (sum == k): cumulative
    rounding (conserves the sum and keeps |k_l - quota_l| < 1), then cap
    overflow redistributed to headroom in index order, then the floor
    raised with the shortfall taken from surplus in index order. All
    O(segments); deterministic."""
    cum = jnp.round(jnp.cumsum(quota))
    cum = jnp.minimum(cum, jnp.float32(k))      # float-sum slack guard
    cum = cum.at[-1].set(jnp.float32(k))        # conserve exactly
    kl = jnp.diff(jnp.concatenate([jnp.zeros((1,), cum.dtype), cum]))
    kl = kl.astype(jnp.int32)                   # cum monotone -> kl >= 0
    over = jnp.maximum(kl - caps, 0)
    kl = kl - over
    head = caps - kl
    give = jnp.clip(jnp.sum(over) - _excl_cumsum(head), 0, head)
    kl = kl + give                              # sum(caps) >= k absorbs all
    if lo:
        need = jnp.maximum(lo - kl, 0)
        short = jnp.sum(need)
        kl = jnp.maximum(kl, lo)
        sur = kl - lo
        take = jnp.clip(short - _excl_cumsum(sur), 0, sur)
        kl = kl - take                          # k >= S*lo guarantees cover
    return kl


def adaptive_counts(k: int, sizes, moments, caps=None) -> jnp.ndarray:
    """Traced adaptive split of k from per-segment second-moment
    statistics (``moments``: (S,) sum of squared selection-score
    magnitudes per segment, any non-negative scale). Per-element
    intensity m_l / J_l is compared to the global mean and clipped to
    [1/ADAPTIVE_CLIP, ADAPTIVE_CLIP]; quotas are k-proportional to
    J_l * ratio_l, integerized exactly (``_integerize_counts``).
    Returns (S,) int32 with sum == k, k_l <= caps_l (default
    ``segment_caps``), and k_l >= 1 when k >= S. All-zero moments
    degrade to the proportional split. O(segments) compute; fully
    deterministic under jit (tests/test_allocate.py::TestAdaptive)."""
    sizes = [int(x) for x in sizes]
    s, j = len(sizes), sum(sizes)
    k = int(min(k, j))
    caps = caps if caps is not None else segment_caps(k, sizes)
    sz = jnp.asarray(sizes, jnp.float32)
    m = jnp.maximum(jnp.asarray(moments, jnp.float32), 0.0)
    total = jnp.sum(m)
    mean = jnp.maximum(total / float(j), jnp.float32(1e-30))
    ratio = jnp.clip((m / sz) / mean, 1.0 / ADAPTIVE_CLIP, ADAPTIVE_CLIP)
    w = sz * jnp.where(total > 0, ratio, 1.0)
    quota = float(k) * w / jnp.sum(w)
    return _integerize_counts(quota, jnp.asarray(caps, jnp.int32), k,
                              lo=1 if k >= s else 0)


# ---------------------------------------------------------------------------
# Shared selection helpers (reference pipeline + fused fallback branch)
# ---------------------------------------------------------------------------

def allocated_select_dense(keys: jnp.ndarray, bounds, caps,
                           counts: jnp.ndarray, k: int):
    """Per-segment top-``counts[l]`` selection over a DENSE key vector,
    packed to exactly k pairs.

    keys: (J,) non-negative fp32 (|score|). For each segment, the top
    ``caps[l]`` keys are ranked (``lax.top_k`` tie-break: value desc,
    index asc within the segment) and the leading ``counts[l]`` are
    live; one final O(sum(caps)) top-k over the live-masked union packs
    them by key desc (ties resolve segment-major, index asc — the same
    order the fused per-segment trim produces, which is what makes
    fused-vs-reference proportional parity exact). Returns (idx (k,)
    uint32, keys_sel (k,)). Requires sum(counts) == k with counts[l] <=
    caps[l] (the apportionment functions guarantee both)."""
    parts_v, parts_i = [], []
    for pos, ((off, size), cap) in enumerate(zip(bounds, caps)):
        kv, ki = jax.lax.top_k(
            jax.lax.dynamic_slice_in_dim(keys, off, size), int(cap))
        live = jnp.arange(int(cap), dtype=jnp.int32) < counts[pos]
        parts_v.append(jnp.where(live, kv, -jnp.inf))
        parts_i.append(jnp.uint32(off) + ki.astype(jnp.uint32))
    allv = jnp.concatenate(parts_v)
    alli = jnp.concatenate(parts_i)
    tv, sel = jax.lax.top_k(allv, int(k))
    return alli[sel], tv


def dense_segment_moments(keys: jnp.ndarray, bounds, caps) -> jnp.ndarray:
    """(S,) adaptive statistics from a dense key vector: per-segment
    top-``caps[l]`` mass (sum of squared keys) — the oracle form of the
    fused pipeline's candidate-cover statistic (identical whenever the
    candidate cover holds, which the exactness witnesses enforce for
    the selection itself)."""
    out = []
    for (off, size), cap in zip(bounds, caps):
        kv = jax.lax.top_k(
            jax.lax.dynamic_slice_in_dim(keys, off, size), int(cap))[0]
        out.append(jnp.sum(jnp.where(kv > -jnp.inf, kv * kv, 0.0)))
    return jnp.stack(out)


def resolve_counts(allocation: str, k: int, bounds, caps,
                   moments=None) -> jnp.ndarray:
    """(S,) int32 per-segment budget for a non-global allocation mode.
    ``moments`` is required for "adaptive" (per-segment second-moment
    stats); "proportional" ignores it."""
    sizes = [sz for _, sz in bounds]
    if allocation == "adaptive":
        if moments is None:
            raise ValueError("allocation='adaptive' needs per-segment "
                             "moment statistics")
        return adaptive_counts(k, sizes, moments, caps=caps)
    if allocation == "proportional":
        return jnp.asarray(proportional_counts(k, sizes), jnp.int32)
    raise ValueError(f"not an allocated mode: {allocation!r}")


def reference_allocated_select(cfg, a: jnp.ndarray, score: jnp.ndarray,
                               k: int, seg_bounds=None):
    """Reference-pipeline allocated selection: (mask (J,), vals (k,),
    idx (k,) uint32) for allocation != "global". ``score`` is the dense
    selection score (already REGTOP-k-corrected for that kind); ``a``
    the error-compensated gradient the packed values are read from.
    Dense math — the oracle the fused per-segment trim is tested
    against (tests/test_allocate.py::TestAllocatedParity)."""
    from repro.core import bigvec
    j = int(score.shape[0])
    bounds = seg_bounds or segment_bounds(j, resolve_num_segments(cfg, j))
    caps = segment_caps(k, [sz for _, sz in bounds])
    keys = jnp.abs(score.astype(jnp.float32))
    moments = (dense_segment_moments(keys, bounds, caps)
               if cfg.allocation == "adaptive" else None)
    counts = resolve_counts(cfg.allocation, k, bounds, caps, moments)
    idx, _ = allocated_select_dense(keys, bounds, caps, counts, k)
    mask = bigvec.mask_from_indices(j, idx, a.dtype)
    return mask, bigvec.gather(a, idx), idx


def randk_allocated_indices(key, bounds, counts) -> jnp.ndarray:
    """Per-segment uniform k_l-subsets for allocated RANDOM-k
    (``counts``: static Python ints — randk allocation is the
    proportional split; there is no score statistic to adapt to). Each
    segment draws from ``fold_in(key, segment_index)``, so the stream
    is identical across pipelines and independent of other segments.
    Returns (k,) uint32 global indices, segment-major."""
    from repro.core import select
    parts = []
    for pos, ((off, size), kl) in enumerate(zip(bounds, counts)):
        if int(kl) <= 0:
            continue
        parts.append(jnp.uint32(off) + select.randk_indices(
            jax.random.fold_in(key, pos), size, int(kl)))
    return jnp.concatenate(parts)
