"""Shared numeric conventions.

The fused pipeline's parity with the reference path depends on both
sides using bit-identical formulas; anything used by more than one of
{core/sparsify, kernels/compress} lives here so the convention can only
be changed in one place.
"""
from __future__ import annotations

import jax.numpy as jnp

TINY = 1e-12


def safe_denom(denom, tiny: float = TINY):
    """Zero-safe divisor: |denom| <= tiny is replaced by
    sign(denom)*tiny + tiny (positive for denom >= 0, the REGTOP-k
    Algorithm 1 line 5 convention)."""
    return jnp.where(jnp.abs(denom) > tiny, denom,
                     jnp.sign(denom) * tiny + tiny)
