from repro.checkpoint.io import (save_checkpoint, restore_checkpoint,
                                 latest_step, read_manifest)
