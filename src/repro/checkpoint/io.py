"""Checkpointing: flat .npz per state tree + JSON manifest.

Arrays are pulled to host (global views) and stored by tree path; restore
rebuilds the pytree and (optionally) re-shards onto a mesh by device_put
with the given sharding tree. Deterministic, dependency-free, adequate for
the CPU-scale runs in this container; a real deployment would swap in
tensorstore/orbax behind the same two functions.

Legacy EF-state migration: checkpoints written before the two-traversal
state layout carried the fused sparsifier state as the pair
``(a_prev, s_prev)``; the current layout stores the single vector
``err_prev = a_prev * (1 - s_prev)``. ``restore_checkpoint`` performs
that one-shot dense multiply at restore when the saved EF tree has the
legacy keys and the template asks for ``err_prev`` — after which the
running state is maintained O(k) by the pipeline itself.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): np.asarray(l) for p, l in flat}


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state, ef_state,
                    param_version=None):
    """``param_version`` (DESIGN.md §2.10) stamps the delta-broadcast
    version these params correspond to into the manifest: a restore
    re-arms the replica's version floor there, and any delta at or below
    it is rejected as a hard error (it predates the restored state)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    np.savez(path + ".params.npz", **_flatten_with_paths(params))
    np.savez(path + ".opt.npz", **_flatten_with_paths(opt_state))
    np.savez(path + ".ef.npz", **_flatten_with_paths(ef_state))
    manifest = {"step": step}
    if param_version is not None:
        manifest["param_version"] = int(param_version)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.json", f))]
    return max(steps) if steps else None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The checkpoint's JSON manifest. Pre-§2.10 checkpoints carry only
    ``step``; ``manifest.get("param_version")`` is then None and the
    caller must treat the checkpoint as version-unstamped (a
    delta-applying restore cannot establish a version floor from it)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.json")
    with open(path) as f:
        return json.load(f)


def _migrate_ef_leaf(data, pstr: str):
    """Resolve one EF leaf from a saved npz, migrating the legacy
    ``(a_prev, s_prev)`` pair to ``err_prev`` when needed (one-shot
    dense multiply — the EF invariant err = a * (1 - s))."""
    if pstr in data:
        return data[pstr]
    if "err_prev" in pstr:
        pa = pstr.replace("err_prev", "a_prev")
        ps = pstr.replace("err_prev", "s_prev")
        if pa in data.files and ps in data.files:
            a = data[pa]
            s = data[ps]
            return (a.astype(np.float32)
                    * (1.0 - s.astype(np.float32))).astype(a.dtype)
    raise KeyError(
        f"checkpoint is missing EF leaf {pstr!r} and no legacy "
        "(a_prev, s_prev) pair to migrate it from")


def _fit_ef_worker_dims(leaf, want_shape, pstr: str):
    """Fit a saved EF leaf to the CURRENT worker layout (DESIGN.md §2.7).

    EF vectors are stored globally as (DP, TP, J_local). An elastic
    restart may resume with a different data-parallel extent (workers
    lost permanently, or replacements joined): when only the leading
    worker dims disagree and the trailing per-rank dims match, surviving
    workers keep their rows and REJOINED workers start with zero
    error-feedback memory — the same semantics as a fresh worker (it
    observed nothing while absent; its residual belongs to a dead
    incarnation). A trailing-dim mismatch means the model itself changed
    and stays a hard error.
    """
    if tuple(leaf.shape) == tuple(want_shape):
        return leaf
    if (leaf.ndim == len(want_shape) and leaf.ndim >= 3
            and tuple(leaf.shape[2:]) == tuple(want_shape[2:])):
        out = np.zeros(want_shape, leaf.dtype)
        d = min(leaf.shape[0], want_shape[0])
        t = min(leaf.shape[1], want_shape[1])
        out[:d, :t] = leaf[:d, :t]
        return out
    raise ValueError(
        f"checkpoint EF leaf {pstr!r} has shape {tuple(leaf.shape)} but the "
        f"run wants {tuple(want_shape)}; only the leading (DP, TP) worker "
        "dims may differ (elastic resume) — trailing per-rank dims must "
        "match")


def _fit_dtype(leaf, tmpl):
    """npz stores non-native dtypes (bfloat16 & friends from ml_dtypes)
    as raw void bytes; reinterpret them as the template leaf's dtype on
    the way back (same itemsize — this is a view, not a cast)."""
    want = np.dtype(getattr(tmpl, "dtype", leaf.dtype))
    if leaf.dtype.kind == "V" and leaf.dtype.itemsize == want.itemsize:
        return leaf.view(want)
    return leaf


def restore_checkpoint(ckpt_dir: str, step: int, params, opt_state, ef_state,
                       shardings=None):
    """Restore into the STRUCTURE of the given trees (values replaced).

    The EF tree additionally tolerates a changed data-parallel worker
    count (``_fit_ef_worker_dims``): rows of vanished workers are
    dropped, rows of new workers are zero-initialized.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")

    def load(tree, fname, migrate_ef=False):
        data = np.load(fname)
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        if migrate_ef:
            leaves = [_migrate_ef_leaf(data, jax.tree_util.keystr(p))
                      for p, _ in flat]
            leaves = [l if getattr(w, "ndim", 0) < 3 else
                      _fit_ef_worker_dims(l, np.shape(w),
                                          jax.tree_util.keystr(p))
                      for l, (p, w) in zip(leaves, flat)]
        else:
            leaves = [data[jax.tree_util.keystr(p)] for p, _ in flat]
        leaves = [_fit_dtype(l, w) for l, (p, w) in zip(leaves, flat)]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree), leaves)

    params = load(params, path + ".params.npz")
    opt_state = load(opt_state, path + ".opt.npz")
    ef_state = load(ef_state, path + ".ef.npz", migrate_ef=True)
    if shardings is not None:
        pshard, oshard, eshard = shardings
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
        ef_state = jax.device_put(ef_state, eshard)
    return params, opt_state, ef_state
