from repro.train.step import (
    build_parallel, build_train_step, init_train_state, train_state_specs,
)
