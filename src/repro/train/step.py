"""Distributed training step: shard_map over (pod?, data, model).

Composition per step (DESIGN.md §2.1):

1. local microbatch loss + grad (TP collectives inside the model);
2. psum over model for gradients of REPLICATED leaves (Megatron-SP rule);
3. flatten to the per-rank J_local fp32 vector;
4. THE PAPER: sparsified gradient sync over the data axes via the
   per-run core.aggregate.GradientSync object (TOP-k / REGTOP-k /
   baselines); sparsifier.overlap="backward" feeds stage 4 per
   layer-aligned segment as stage 1's VJP emits it (DESIGN.md §2.8),
   leaving the global trim/pack + collective as the only tail barrier. With
   sparsifier.num_buckets > 1 this stage uses the bucketed schedule of
   DESIGN.md §2.4: the fused sweeps run per bucket (histogram-merge
   global threshold), and the sparse all-gather is issued in
   num_buckets chunks so each chunk's collective overlaps the previous
   chunk's local scatter-add combine;
5. ZeRO-1 optimizer: each data rank updates its 1/DP slice of the fp32
   master + moments, params all-gathered back over data.

State layout (global arrays over the mesh):
- params: pytree, model-sharded per models/specs.py, replicated over data;
- opt:   {master,m,v}: (DP, TP, shard) sharded (dpaxes, model, -);
- ef:    sparsifier vectors (DP, TP, J_local) sharded likewise.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core import aggregate as agg
from repro.core import sparsify
from repro.core.flatten import TreeFlattener
from repro.models import init_params, loss_fn
from repro.models.parallel import Parallel
from repro.models.specs import param_specs, replicated_mask
from repro.optim import apply_updates, init_opt_state, opt_shard_len


def resolve_model_cfg(run: RunConfig):
    cfg = run.model
    if run.attn_override == "sliding" and cfg.attn_kind == "full":
        cfg = dataclasses.replace(cfg, attn_kind="sliding")
    return cfg


def build_parallel(mesh, *, seq_parallel=True, cache_seq_axis=None,
                   attn_dist="sp") -> Parallel:
    axes = mesh.axis_names
    tp = mesh.shape["model"]
    dpaxes = tuple(a for a in axes if a != "model")
    return Parallel(model_axis="model" if tp > 1 else None,
                    data_axes=dpaxes, tp=tp,
                    seq_parallel=seq_parallel and tp > 1,
                    cache_seq_axis=cache_seq_axis, attn_dist=attn_dist)


def _dp_size(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        if a != "model":
            n *= mesh.shape[a]
    return n


def _dp_index(dpaxes):
    idx = jnp.zeros((), jnp.int32)
    for a in dpaxes:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _gather_dp(x, dpaxes):
    for a in reversed(dpaxes):
        x = jax.lax.all_gather(x, a, axis=0, tiled=True)
    return x


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def abstract_params(run: RunConfig, pal: Parallel):
    cfg = resolve_model_cfg(run)
    return jax.eval_shape(partial(init_params, cfg, pal),
                          jax.random.PRNGKey(0))


def auto_num_buckets_for_run(run: RunConfig, mesh, pal: Parallel = None):
    """Trace-accurate mirror of GradientSync's ``num_buckets=0``
    resolution: the SAME flattened per-rank gradient length (TreeFlattener
    total over the abstract per-rank params — what step_fn's
    ``g.shape[0]`` is) and the same data-parallel extent. The single
    helper every out-of-band consumer (launch log line, dryrun record)
    must use, so logs and records can never disagree with the chunk
    count the compiled program executes. Returns (num_buckets, j_local,
    dp)."""
    from repro.core.flatten import tree_size
    from repro.core.sparsify import resolve_num_buckets
    pal = pal or build_parallel(mesh)
    dp = 1
    for a in pal.data_axes:
        dp *= int(mesh.shape[a])
    j_local = tree_size(abstract_params(run, pal))
    return resolve_num_buckets(run.sparsifier, j_local, dp), j_local, dp


def stream_bounds_for_run(run: RunConfig, mesh, pal: Parallel = None):
    """Trace-accurate mirror of build_train_step's streaming partition
    (DESIGN.md §2.8): the layer-aligned (offset, size) bounds the step
    feeds per segment under ``sparsifier.overlap="backward"``, or None
    when streaming is off. Out-of-band consumers (launch log line,
    dryrun record's ``num_stream_segments``) must use this helper so
    they can never disagree with the compiled program's cut."""
    sp = run.sparsifier
    if getattr(sp, "overlap", "none") != "backward":
        return None
    from repro.core import allocate
    pal = pal or build_parallel(mesh)
    flat = TreeFlattener(abstract_params(run, pal))
    return allocate.layer_segments(
        flat.layer_bounds(), allocate.resolve_num_segments(sp, flat.total))


def delta_publisher_for_run(run: RunConfig, params, delta_k: int = 0, *,
                            record_history: bool = False):
    """Trainer-side delta-broadcast publisher (DESIGN.md §2.10), budget
    resolved the same way the sparsifier resolves k: ``delta_k <= 0``
    falls back to ``resolve_k(run.sparsifier, J)`` over the whole flat
    model, so by default the serving channel ships the same per-step
    volume the gradient sync does. The caller publishes AFTER each
    optimizer step (``publish(params)``) and ships the version-0 base
    via ``write_snapshot`` before any replica subscribes."""
    from repro.core.flatten import tree_size
    from repro.core.sparsify import resolve_k
    from repro.serve.delta import DeltaPublisher
    k = int(delta_k)
    if k <= 0:
        k = resolve_k(run.sparsifier, tree_size(params))
    return DeltaPublisher(params, k, record_history=record_history)


def train_state_specs(run: RunConfig, mesh, pal: Parallel):
    """(param_specs, opt_specs, ef_specs) PartitionSpec trees."""
    tmpl = abstract_params(run, pal)
    pspecs = param_specs(tmpl) if pal.tp_on else jax.tree_util.tree_map(
        lambda _: P(), tmpl)
    dpaxes = pal.data_axes
    vec = P(dpaxes, "model", None) if pal.tp_on else P(dpaxes, None, None)

    def st_spec(tree):
        return jax.tree_util.tree_map(
            lambda l: vec if getattr(l, "ndim", 0) >= 1 else P(), tree)

    flat = TreeFlattener(tmpl)
    dp = _dp_size(mesh)
    shard = opt_shard_len(flat.total, dp)
    opt_tmpl = init_opt_state(run.optimizer,
                              jax.ShapeDtypeStruct((shard,), jnp.float32))
    ef_tmpl = sparsify.init_state(run.sparsifier, flat.total)
    return tmpl, pspecs, st_spec(opt_tmpl), st_spec(ef_tmpl)


def init_train_state(run: RunConfig, mesh, pal: Parallel, key):
    """shard_map'd initializer: returns (params, opt_state, ef_state)."""
    cfg = resolve_model_cfg(run)
    tmpl, pspecs, ospecs, especs = train_state_specs(run, mesh, pal)
    flat = TreeFlattener(tmpl)
    dp = _dp_size(mesh)
    shard = opt_shard_len(flat.total, dp)
    dpaxes = pal.data_axes

    def init_fn(k):
        params = init_params(cfg, pal, k)
        if pal.tp_on:
            # sharded leaves draw per-rank streams; REPLICATED leaves must be
            # bit-identical across model ranks -> init twice and select.
            kf = jax.random.fold_in(k, jax.lax.axis_index("model"))
            params_f = init_params(cfg, pal, kf)
            repl = replicated_mask(params)
            params = jax.tree_util.tree_map(
                lambda u, f, r: u if r else f, params, params_f, repl)
        vec = flat.flatten(params)
        r = _dp_index(dpaxes)
        vpad = jnp.pad(vec, (0, dp * shard - flat.total))
        mslice = jax.lax.dynamic_slice_in_dim(vpad, r * shard, shard)
        opt = init_opt_state(run.optimizer, mslice)
        ef = sparsify.init_state(run.sparsifier, flat.total)
        exp = lambda t: jax.tree_util.tree_map(
            lambda l: l.reshape((1, 1) + l.shape) if l.ndim >= 1 else l, t)
        return params, exp(opt), exp(ef)

    fn = jax.jit(jax.shard_map(
        init_fn, mesh=mesh, in_specs=(P(),),
        out_specs=(pspecs, ospecs, especs), check_vma=False))
    return fn(key)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(run: RunConfig, mesh, pal: Parallel):
    """Returns (step_fn, in_specs, out_specs) — step_fn is the UNJITTED
    shard_map'd function; caller jits (and .lower()s for the dry-run)."""
    cfg = resolve_model_cfg(run)
    sp = run.sparsifier
    opt = run.optimizer
    # fault injection (DESIGN.md §2.7): parsed ONCE at build time — the
    # schedule is static config; only the per-(step, worker) liveness
    # bit is traced. None (no/empty spec) keeps the sync call and the
    # metrics tree byte-identical to the fault-free build.
    from repro.core import faults
    sched = faults.parse_schedule(run.fault_schedule)
    tmpl, pspecs, ospecs, especs = train_state_specs(run, mesh, pal)
    repl = replicated_mask(tmpl)
    flat = TreeFlattener(tmpl)
    dp = _dp_size(mesh)
    shard = opt_shard_len(flat.total, dp)
    dpaxes = pal.data_axes
    window = cfg.window if run.attn_override == "sliding" else 0

    # density allocation (DESIGN.md §2.6): the train step owns the leaf
    # layout, so it pins LAYER-ALIGNED segment bounds (grouped leaves,
    # never cutting inside a parameter) instead of the near-equal
    # default cut GradientSync would fall back to. Static python ints
    # — safe to close over under shard_map/jit.
    seg_bounds = None
    if sp.allocation != "global":
        from repro.core import allocate
        allocate.check_allocation(sp)      # fail at build, not at trace
        seg_bounds = allocate.layer_segments(
            flat.layer_bounds(), allocate.resolve_num_segments(sp, flat.total))

    # streaming compression (DESIGN.md §2.8): with overlap="backward" the
    # gradient is fed into the fused pipeline per layer-aligned segment
    # as the VJP emits it, instead of as one flat concatenate. The
    # partition is pinned at build time (static ints); when allocation
    # also segments, the SAME bounds drive both, so the per-segment
    # sweeps and the density budget share one cut.
    stream_bounds = None
    if sp.overlap == "backward":
        from repro.core import allocate
        stream_bounds = seg_bounds if seg_bounds is not None else \
            allocate.layer_segments(
                flat.layer_bounds(),
                allocate.resolve_num_segments(sp, flat.total))

    # per-run sync object (static fields bound once; validates the
    # allocation/overlap combos and resolves num_buckets=0 at build time
    # — same resolution auto_num_buckets_for_run mirrors for logs)
    gsync = agg.GradientSync(sp, dpaxes, j=flat.total, n_workers=dp,
                             seg_bounds=seg_bounds)

    # duplicate-weights: replicated leaves appear in every model-rank's flat
    # vector; weight 1/tp in global-norm computations.
    dup = jnp.concatenate([
        jnp.full((s,), (1.0 / max(pal.tp, 1)) if r else 1.0, jnp.float32)
        for s, r in zip(flat.sizes, jax.tree_util.tree_leaves(repl))]) \
        if pal.tp_on else None

    def sq(t):
        return jax.tree_util.tree_map(
            lambda l: l.reshape(l.shape[2:]) if getattr(l, "ndim", 0) >= 3 else l, t)

    def exp(t):
        return jax.tree_util.tree_map(
            lambda l: (l.reshape((1, 1) + l.shape)
                       if getattr(l, "ndim", 0) >= 1 else l), t)

    def step_fn(params, opt_state, ef_state, batch, key):
        opt_state = sq(opt_state)
        ef_state = sq(ef_state)

        def loss_f(p):
            return loss_fn(p, batch, cfg, pal, window=window)

        (loss, aux), grads = jax.value_and_grad(loss_f, has_aux=True)(params)
        if pal.tp_on:
            grads = jax.tree_util.tree_map(
                lambda g, r: jax.lax.psum(g, "model") if r else g, grads, repl)
        if stream_bounds is not None:
            # streaming: one flat per segment, each depending only on its
            # own leaves' gradients — compression runs behind the
            # remaining backward work (DESIGN.md §2.8)
            g_segments = flat.flatten_segments(grads, stream_bounds)
            gnorm_local = jnp.sqrt(sum(
                jnp.sum(jnp.square(s.astype(jnp.float32)))
                for s in g_segments))
        else:
            g_segments = None
            g = flat.flatten(grads)
            gnorm_local = jnp.linalg.norm(g)

        key = jax.random.fold_in(key, _dp_index(dpaxes))
        fstats = None
        part = None
        if sched is not None:
            part = faults.participates(sched, ef_state["step"],
                                       _dp_index(dpaxes))
        if g_segments is not None:
            stream = gsync.begin(ef_state, key=key, participate=part)
            for gseg in g_segments:
                stream.feed_segment(gseg)
            if sched is None:
                g_agg, ef_new = stream.finish()
            else:
                g_agg, ef_new, fstats = stream.finish(with_stats=True)
        elif sched is None:
            g_agg, ef_new = gsync(ef_state, g, key=key)
        else:
            g_agg, ef_new, fstats = gsync(ef_state, g, key=key,
                                          participate=part, with_stats=True)

        # ZeRO-1 slice update
        r = _dp_index(dpaxes)
        gpad = jnp.pad(g_agg.astype(jnp.float32), (0, dp * shard - flat.total))
        gs = jax.lax.dynamic_slice_in_dim(gpad, r * shard, shard)
        if opt.grad_clip:
            w = dup if dup is not None else 1.0
            gn2 = jnp.sum(g_agg.astype(jnp.float32) ** 2 * w)
            gn2 = jax.lax.psum(gn2, "model") if pal.tp_on else gn2
            opt_state = dict(opt_state, gnorm=jnp.sqrt(gn2))
        master, opt_new = apply_updates(opt, opt_state, gs)
        mall = _gather_dp(master, dpaxes)[:flat.total]
        params_new = flat.unflatten(mall)

        from repro.models.transformer import global_loss
        metrics = {
            "loss": global_loss(loss, pal),          # psum over model first
            "gnorm_local": gnorm_local,
            "agg_nonzero": jnp.mean((g_agg != 0).astype(jnp.float32)),
        }
        metrics.update(aux)
        all_axes = dpaxes + (("model",) if pal.tp_on else ())
        metrics = {k_: jax.lax.pmean(v, dpaxes if k_ == "loss" else all_axes)
                   for k_, v in metrics.items()}
        if fstats is not None:
            # already rank-identical psums from GradientSync — no pmean
            metrics["n_active"] = fstats["n_active"]
            metrics["dropped_nonfinite"] = fstats["dropped_nonfinite"]
        return params_new, exp(opt_new), exp(ef_new), metrics

    batch_specs = {k: P(dpaxes, None) for k in ("tokens", "targets")}
    if cfg.frontend == "vision_stub":
        batch_specs["patches"] = P(dpaxes, None, None)
    elif cfg.frontend == "audio_stub":
        batch_specs["frames"] = P(dpaxes, None, None)
    mkeys = ["loss", "gnorm_local", "agg_nonzero",
             "lb_loss", "z_loss", "drop_frac"]
    if sched is not None:
        mkeys += ["n_active", "dropped_nonfinite"]
    mspecs = {k: P() for k in mkeys}
    in_specs = (pspecs, ospecs, especs, batch_specs, P())
    out_specs = (pspecs, ospecs, especs, mspecs)
    wrapped = jax.shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)
    return wrapped, in_specs, out_specs
