from repro.optim.optimizer import (
    init_opt_state, apply_updates, lr_at_step, opt_shard_len,
)
