"""Optimizers over FLAT fp32 vectors with ZeRO-1 sharding over data ranks.

The training step keeps parameters as a pytree (model-sharded), but the
optimizer operates on the flat per-rank vector (the same J_local layout the
sparsifier uses). With ZeRO-1 (optimizer.zero1), each of the DP data ranks
owns a 1/DP slice of (master, m, v); after gradient aggregation every rank
updates its slice and the updated master is all-gathered over the data axes.

States are fp32 regardless of the model dtype (master copy included).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def opt_shard_len(j_local: int, dp: int) -> int:
    """Padded per-data-rank slice length."""
    return -(-j_local // dp)


def lr_at_step(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps:
        warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    else:
        warm = 1.0
    if cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps) /
                     max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    else:
        decay = 1.0
    return lr * warm * decay


def init_opt_state(cfg: OptimizerConfig, master_slice: jnp.ndarray) -> dict:
    """State for one rank's slice. master_slice: (shard,) fp32 params."""
    z = jnp.zeros_like(master_slice)
    st = {"master": master_slice, "step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "momentum":
        st["m"] = z
    elif cfg.kind in ("adam", "adamw"):
        st["m"] = z
        st["v"] = z
    return st


def apply_updates(cfg: OptimizerConfig, state: dict, g_slice: jnp.ndarray):
    """One optimizer step on this rank's slice. Returns (new_master, state)."""
    m0 = state["master"]
    step = state["step"]
    lr = lr_at_step(cfg, step)
    g = g_slice.astype(jnp.float32)
    if cfg.grad_clip:
        # caller passes the GLOBAL grad norm via state["gnorm"] if clipping
        gn = state.get("gnorm", jnp.linalg.norm(g))
        g = g * jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    new = dict(state)
    if cfg.kind == "sgd":
        upd = g
    elif cfg.kind == "momentum":
        m = cfg.momentum * state["m"] + g
        new["m"] = m
        upd = m
    elif cfg.kind in ("adam", "adamw"):
        t = (step + 1).astype(jnp.float32)
        m = cfg.b1 * state["m"] + (1 - cfg.b1) * g
        v = cfg.b2 * state["v"] + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** t)
        vhat = v / (1 - cfg.b2 ** t)
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new["m"], new["v"] = m, v
    else:
        raise ValueError(cfg.kind)
    if cfg.weight_decay and cfg.kind == "adamw":
        upd = upd + cfg.weight_decay * m0
    master = m0 - lr * upd
    new["master"] = master
    new["step"] = step + 1
    new.pop("gnorm", None)
    return master, new
