import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, WITHOUT allocating any real arrays (ShapeDtypeStruct
inputs only). Proves the sharding config is coherent and yields the
memory/cost/collective numbers for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b \
      --shape train_4k [--multi-pod] [--out results/dryrun.json] \
      [--sparsifier regtopk --sparsity 0.001 --comm sparse] [--mesh 4x4]

The XLA_FLAGS lines below MUST run before any other jax import — jax locks
the device count at first init. Smoke tests and benches do NOT import this
module (they see 1 device).
"""
import argparse
import dataclasses
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    SHAPES, OptimizerConfig, RunConfig, SparsifierConfig,
    get_config, list_archs,
)
from repro.launch.mesh import make_production_mesh, make_mesh
from repro.models.params import count_active_params, count_params_analytic


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(run: RunConfig, mesh, pal, kind: str):
    """Abstract inputs for the given step kind: train | prefill | decode."""
    from repro.data.synthetic import lm_batch_specs
    from repro.serve.step import decode_cache_specs
    from repro.train.step import resolve_model_cfg
    cfg = resolve_model_cfg(run)
    gb, seq = run.shape.global_batch, run.shape.seq_len
    dpaxes = pal.data_axes

    def shd(spec):
        return NamedSharding(mesh, spec)

    if kind in ("train", "prefill"):
        b = lm_batch_specs(cfg, gb, seq)
        specs = {"tokens": P(dpaxes, None), "targets": P(dpaxes, None),
                 "patches": P(dpaxes, None, None), "frames": P(dpaxes, None, None)}
        return {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=shd(specs[k]))
                for k, v in b.items() if not (kind == "prefill" and k == "targets")}
    # decode: one token per sequence + cache
    tok_spec = P(dpaxes, None) if pal.cache_seq_axis is None else P(None, None)
    token = jax.ShapeDtypeStruct((gb, 1), jnp.int32, sharding=shd(tok_spec))
    cache_abs, cspecs, b_local, seq_local = decode_cache_specs(run, mesh, pal)
    cache = jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(
            _globalize_shape(l.shape, s, mesh), l.dtype, sharding=shd(s)),
        cache_abs, cspecs)
    return {"token": token, "cache": cache}


def _axsize(mesh, ax):
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _globalize_shape(shape, spec, mesh):
    out = list(shape)
    for d, ax in enumerate(spec):
        if ax is not None:
            out[d] = out[d] * _axsize(mesh, ax)
    return tuple(out)


def _globalize_tree(tmpl, specs, mesh):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(
            _globalize_shape(l.shape, s, mesh), l.dtype,
            sharding=NamedSharding(mesh, s)),
        tmpl, specs)


# ---------------------------------------------------------------------------
# Lower + compile one (arch, shape, mesh)
# ---------------------------------------------------------------------------

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in (optimized) HLO text."""
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2}
    out = {c: 0 for c in COLLECTIVES}
    # lines like: %x = bf16[2,16,128]{...} all-gather(...)
    pat = re.compile(
        r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\b("
        + "|".join(COLLECTIVES) + r")\b")
    for m in pat.finditer(hlo_text):
        dt, dims, op = m.groups()
        if dt not in dt_bytes:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] += n * dt_bytes[dt]
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def build_step(run: RunConfig, mesh, kind: str):
    from repro.serve.step import (build_decode_step, build_prefill,
                                  serve_parallel)
    from repro.train.step import (build_parallel, build_train_step,
                                  train_state_specs)
    if kind == "train":
        pal = build_parallel(mesh)
        step, in_specs, _ = build_train_step(run, mesh, pal)
        tmpl, pspecs, ospecs, especs = train_state_specs(run, mesh, pal)
        params_abs = _globalize_tree(tmpl, pspecs, mesh)
        from repro.core import sparsify
        from repro.optim import init_opt_state, opt_shard_len
        flat_total = sum(int(l.size) for l in jax.tree_util.tree_leaves(tmpl))
        dp = 1
        for a in pal.data_axes:
            dp *= mesh.shape[a]
        shard = opt_shard_len(flat_total, dp)
        opt_tmpl = jax.eval_shape(partial(init_opt_state, run.optimizer),
                                  jax.ShapeDtypeStruct((shard,), jnp.float32))
        ef_tmpl = jax.eval_shape(
            lambda: sparsify.init_state(run.sparsifier, flat_total))
        exp = lambda t: jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct((1, 1) + l.shape, l.dtype)
            if l.ndim >= 1 else l, t)
        opt_abs = _globalize_tree(exp(opt_tmpl), ospecs, mesh)
        ef_abs = _globalize_tree(exp(ef_tmpl), especs, mesh)
        batch_abs = input_specs(run, mesh, pal, "train")
        key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                       sharding=NamedSharding(mesh, P()))
        return step, (params_abs, opt_abs, ef_abs, batch_abs, key_abs), pal
    if kind == "prefill":
        pal = serve_parallel(mesh, run, decode=False)
        step, (pspecs, bspecs) = build_prefill(run, mesh, pal)
        from repro.train.step import abstract_params
        tmpl = abstract_params(run, pal)
        params_abs = _globalize_tree(
            tmpl, pspecs, mesh)
        batch_abs = input_specs(run, mesh, pal, "prefill")
        return step, (params_abs, batch_abs), pal
    # decode
    pal = serve_parallel(mesh, run, decode=True)
    step, (pspecs, cspecs, tok_spec) = build_decode_step(run, mesh, pal)
    from repro.train.step import abstract_params
    tmpl = abstract_params(run, pal)
    params_abs = _globalize_tree(tmpl, pspecs, mesh)
    ins = input_specs(run, mesh, pal, "decode")
    return step, (params_abs, ins["cache"], ins["token"]), pal


def dryrun_one(arch: str, shape_name: str, mesh, *, sparsifier="regtopk",
               sparsity=0.001, comm="sparse", verbose=True,
               variant="", state_format="dense", ef_dtype="float32",
               pipeline="reference", num_buckets=1, selector="exact",
               wire_dtype="float32", allocation="global", num_segments=0,
               fault_schedule="", err_decay=1.0, combine="mean",
               overlap="none", sketch_rows=3, sketch_width=0,
               delta_k=0, delta_fault_schedule="",
               **cfg_overrides) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    moe_over = {k[4:]: v for k, v in cfg_overrides.items()
                if k.startswith("moe_") and k != "moe_every"}
    cfg_overrides = {k: v for k, v in cfg_overrides.items()
                     if not (k.startswith("moe_") and k != "moe_every")}
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    if moe_over and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    attn_override = ""
    if shape_name == "long_500k" and cfg.attn_kind == "full" and \
            cfg.family not in ("ssm",) and cfg.attn_every == 1:
        attn_override = "sliding"   # dense archs: sliding-window variant
    run = RunConfig(
        model=cfg, shape=shape,
        sparsifier=SparsifierConfig(kind=sparsifier, sparsity=sparsity,
                                    comm_mode=comm, selector=selector,
                                    mu=0.5, state_format=state_format,
                                    ef_dtype=ef_dtype, pipeline=pipeline,
                                    num_buckets=num_buckets,
                                    allocation=allocation,
                                    num_segments=num_segments,
                                    wire_dtype=wire_dtype,
                                    err_decay=err_decay, combine=combine,
                                    overlap=overlap,
                                    sketch_rows=sketch_rows,
                                    sketch_width=sketch_width),
        optimizer=OptimizerConfig(kind="adam", lr=1e-4),
        attn_override=attn_override,
        fault_schedule=fault_schedule,
    )
    kind = shape.kind
    num_buckets_resolved = num_buckets
    gather_wire = None
    fault_rec = None
    num_stream_segments = None
    sketch_rec = None
    if kind == "train":
        # the trace resolves num_buckets inside GradientSync; the shared
        # helper mirrors it exactly (same flattened per-rank J, same dp
        # extent) so the record — which the roofline's
        # collective_exposed_s consumes — carries the chunk count the
        # compiled program actually executes. The same (j_local, dp)
        # yields the dtype-aware sparse-gather payload
        # (aggregate.sparse_gather_wire_bytes, None off the sparse path).
        from repro.core.aggregate import sparse_gather_wire_bytes
        from repro.train.step import auto_num_buckets_for_run
        nb_auto, j_local, dp = auto_num_buckets_for_run(run, mesh)
        if num_buckets == 0:
            num_buckets_resolved = nb_auto
        gather_wire = sparse_gather_wire_bytes(run.sparsifier, j_local, dp)
        from repro.core.aggregate import sketch_allreduce_bytes
        skb = sketch_allreduce_bytes(run.sparsifier, j_local, dp)
        if skb is not None:
            # sketch-coordinated selection: the record carries the
            # EFFECTIVE width (resolve_width may cap the 4k auto-size,
            # warned once) and the analytic all-reduce payload the
            # roofline's sketch_allreduce_s term consumes
            from repro.core import sketch as core_sketch
            from repro.core.sparsify import resolve_k
            sketch_rec = {
                "sketch_rows": run.sparsifier.sketch_rows,
                "sketch_width_effective": core_sketch.resolve_width(
                    resolve_k(run.sparsifier, j_local),
                    run.sparsifier.sketch_width),
                "sketch_allreduce_bytes": float(skb),
            }
        if overlap == "backward":
            # the streaming partition the compiled step executes — the
            # roofline's backward-overlap model consumes the count
            from repro.train.step import stream_bounds_for_run
            num_stream_segments = len(stream_bounds_for_run(run, mesh))
        if fault_schedule:
            # fault config rides in the record (DESIGN.md §2.7) so the
            # roofline can expose the straggler-scaled collective share;
            # the _active volume is the idealized elastic wire (absent
            # workers transmit nothing), NOT what the fixed-shape
            # compiled collectives move
            from repro.core import faults
            sched = faults.parse_schedule(fault_schedule)
            fault_rec = faults.describe(sched, dp)
            gw_act = sparse_gather_wire_bytes(
                run.sparsifier, j_local, dp,
                n_active=fault_rec["n_active_expected"])
            if gw_act is not None:
                fault_rec["sparse_gather_wire_bytes_active"] = float(gw_act)
    t0 = time.time()
    step, abs_args, pal = build_step(run, mesh, kind)
    with mesh:
        lowered = jax.jit(step).lower(*abs_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):          # jaxlib < 0.5 returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from repro.roofline.hlo_parser import analyze_hlo
    parsed = analyze_hlo(hlo, mesh.shape["model"])
    n_params = count_params_analytic(cfg)
    n_active = count_active_params(cfg)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": dict(zip(mesh.axis_names,
                         [int(mesh.shape[a]) for a in mesh.axis_names])),
        "kind": kind, "attn_override": attn_override,
        "num_buckets": num_buckets_resolved,
        "num_buckets_requested": num_buckets,
        "allocation": allocation,
        "params": int(n_params), "active_params": int(n_active),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        # loop-aware HLO parse (scan bodies x trip count) — the numbers the
        # roofline uses; cost_analysis counts while bodies once (see
        # roofline/hlo_parser.py docstring)
        "hlo_flops": parsed["flops"],
        "hlo_bytes": parsed["hbm_bytes"],
        "hlo_collectives": parsed["collectives"],
        "hlo_collective_wire_bytes": parsed["collective_wire_bytes"],
        "unknown_trip_loops": parsed["unknown_trip_loops"],
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "wire_dtype": wire_dtype,
        "overlap": overlap,
        "memory": {
            k: int(getattr(mem, k, -1)) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes", "peak_memory_in_bytes")
        },
    }
    if gather_wire is not None:
        rec["sparse_gather_wire_bytes"] = int(gather_wire)
    if num_stream_segments is not None:
        rec["num_stream_segments"] = int(num_stream_segments)
    if sketch_rec is not None:
        rec.update(sketch_rec)
    if fault_rec is not None:
        rec["fault"] = fault_rec
    if delta_k:
        # learning-while-serving channel (DESIGN.md §2.10): the record
        # carries the analytic per-delta wire size, the full-snapshot
        # resync size, and the staleness-vs-bandwidth breakeven so the
        # roofline's delta_apply_s / delta_bcast_s / resync_s terms are
        # modeled, not guessed. k counts against the GLOBAL param vector
        # (the published flat-J space), independent of the mesh.
        from repro.core import faults
        from repro.serve.delta import (delta_wire_bytes, resync_bytes,
                                       resync_equiv_deltas)
        k_eff = int(min(delta_k, n_params))
        rec["delta"] = {
            "k": k_eff,
            "wire_bytes": int(delta_wire_bytes(k_eff)),
            "resync_bytes": int(resync_bytes(n_params)),
            "resync_equiv_deltas": float(
                resync_equiv_deltas(n_params, k_eff)),
        }
        if delta_fault_schedule:
            rec["delta"]["fault"] = faults.describe_channel(
                faults.parse_channel_schedule(delta_fault_schedule))
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={rec['mesh']}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s", flush=True)
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops={:.3e} bytes={:.3e}".format(
            rec["flops"], rec["bytes_accessed"]))
        print("  hlo(loop-aware): flops={:.3e} bytes={:.3e} wire={:.3e}".format(
            parsed["flops"], parsed["hbm_bytes"],
            parsed["collective_wire_bytes"]))
        print("  collectives(wire):",
              {k: f"{v:.3e}" for k, v in parsed["collectives"].items() if v},
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default="", help="e.g. 4x4 or 2x4x4 (override)")
    ap.add_argument("--sparsifier", default="regtopk")
    ap.add_argument("--sparsity", type=float, default=0.001)
    ap.add_argument("--comm", default="sparse")
    ap.add_argument("--pipeline", default="reference",
                    choices=["reference", "fused"])
    ap.add_argument("--num-buckets", type=int, default=1,
                    help="bucketed compression + chunked sparse collectives "
                         "(DESIGN.md §2.4); the record carries num_buckets "
                         "so the roofline reports collective_exposed_s. "
                         "0 auto-tunes the count (the record then carries "
                         "the resolved value)")
    ap.add_argument("--selector", default="exact",
                    choices=["exact", "histogram"])
    ap.add_argument("--allocation", default="global",
                    choices=["global", "proportional", "adaptive"],
                    help="density allocation (DESIGN.md §2.6): split of "
                         "the budget k across segments before selection; "
                         "sum(k_l) == k so sparse wire bytes (and the "
                         "record's sparse_gather_wire_bytes) are "
                         "allocation-invariant")
    ap.add_argument("--num-segments", type=int, default=0,
                    help="segment count for --allocation != global "
                         "(0: follow --num-buckets, else 8)")
    ap.add_argument("--wire-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="wire dtype of the packed VALUES in "
                         "comm_mode='sparse' (indices stay uint32); "
                         "bfloat16 cuts sparse wire bytes 25%% and the "
                         "record's sparse_gather_wire_bytes reflects it")
    ap.add_argument("--fault-schedule", default="",
                    help="fault-injection spec (DESIGN.md §2.7, e.g. "
                         "'iid:0.3'); the record then carries the parsed "
                         "schedule + expected active-worker count and "
                         "sparse_gather_wire_bytes scales to E[n_active]")
    ap.add_argument("--overlap", default="none",
                    choices=["none", "backward"],
                    help="streaming compression (DESIGN.md §2.8): feed "
                         "the gradient into the fused pipeline per "
                         "layer-aligned segment behind the backward "
                         "pass; the record carries num_stream_segments "
                         "so the roofline reports the "
                         "comm-behind-backward exposed term")
    ap.add_argument("--err-decay", type=float, default=1.0,
                    help="EF memory decay on sat-out steps (DESIGN.md §2.7)")
    ap.add_argument("--sketch-rows", type=int, default=3,
                    help="CountSketch rows for --sparsifier sketchtopk "
                         "(DESIGN.md §2.9); the record carries "
                         "sketch_allreduce_bytes so the roofline reports "
                         "the pre-selection barrier term")
    ap.add_argument("--sketch-width", type=int, default=0,
                    help="CountSketch width for --sparsifier sketchtopk; "
                         "0 auto-sizes to min(max(4k, 256), 2^22) and the "
                         "record carries sketch_width_effective")
    ap.add_argument("--combine", default="mean",
                    choices=["mean", "support"],
                    help="elastic combine rule (DESIGN.md §2.7)")
    ap.add_argument("--delta-k", type=int, default=0,
                    help="learning-while-serving delta budget (DESIGN.md "
                         "§2.10): when > 0 the record carries the per-delta "
                         "wire bytes, the full-snapshot resync bytes, and "
                         "the resync breakeven, and the roofline reports "
                         "delta_bcast_s / delta_apply_s / resync_s")
    ap.add_argument("--delta-fault-schedule", default="",
                    help="delta-channel fault spec (loss:P | corrupt:P | "
                         "reorder:W | stall:N); the record's delta section "
                         "then carries the parsed schedule + expected "
                         "first-try delivery rate")
    ap.add_argument("--out", default="")
    ap.add_argument("--variant", default="", help="perf-variant tag for the record")
    ap.add_argument("--state-format", default="dense")
    ap.add_argument("--ef-dtype", default="float32")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. mla_absorb=true)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v

    if args.mesh:
        dims = [int(x) for x in args.mesh.split("x")]
        mesh = make_mesh(*dims[-2:], pods=dims[0] if len(dims) == 3 else 1)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    results, failures = [], []
    for a in archs:
        for s in shapes:
            try:
                results.append(dryrun_one(
                    a, s, mesh, sparsifier=args.sparsifier,
                    sparsity=args.sparsity, comm=args.comm,
                    variant=args.variant, state_format=args.state_format,
                    ef_dtype=args.ef_dtype, pipeline=args.pipeline,
                    num_buckets=args.num_buckets, selector=args.selector,
                    wire_dtype=args.wire_dtype, allocation=args.allocation,
                    num_segments=args.num_segments,
                    fault_schedule=args.fault_schedule,
                    err_decay=args.err_decay, combine=args.combine,
                    overlap=args.overlap,
                    sketch_rows=args.sketch_rows,
                    sketch_width=args.sketch_width,
                    delta_k=args.delta_k,
                    delta_fault_schedule=args.delta_fault_schedule,
                    **overrides))
            except Exception as e:  # noqa: BLE001 — report every combo
                import traceback
                traceback.print_exc()
                failures.append({"arch": a, "shape": s, "error": repr(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        payload = {"results": results, "failures": failures}
        if os.path.exists(args.out):
            try:
                old = json.load(open(args.out))
                keyf = lambda r: (r["arch"], r["shape"], r.get("variant", ""),
                                  tuple(sorted(r["mesh"].items())))
                seen = {keyf(r) for r in results}
                payload["results"] += [
                    r for r in old.get("results", []) if keyf(r) not in seen]
                ok = {(r["arch"], r["shape"]) for r in payload["results"]}
                fseen = set()
                merged = []
                for f in payload["failures"] + old.get("failures", []):
                    kk = (f["arch"], f["shape"])
                    if kk in ok or kk in fseen:
                        continue
                    fseen.add(kk)
                    merged.append(f)
                payload["failures"] = merged
            except Exception:
                pass
        json.dump(payload, open(args.out, "w"), indent=1)
    print(f"\n{len(results)} ok, {len(failures)} failed")
    for f in failures:
        print("FAIL:", f["arch"], f["shape"], f["error"][:200])
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
