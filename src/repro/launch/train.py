"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
      --smoke --steps 50 --sparsifier regtopk --sparsity 0.01 \
      --data 4 --model 2 --devices 8

--devices N forces N host devices (set BEFORE jax import); --smoke uses the
reduced config of the arch family so the run fits on CPU.
"""
import argparse
import os
import sys


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sparsifier", default="regtopk")
    ap.add_argument("--sparsity", type=float, default=0.01)
    ap.add_argument("--mu", type=float, default=0.5)
    ap.add_argument("--comm", default="simulate",
                    choices=["simulate", "sparse", "dense"])
    ap.add_argument("--pipeline", default="reference",
                    choices=["reference", "fused"],
                    help="compression execution pipeline (DESIGN.md §2.2): "
                         "dense reference math, or the two-sweep fused "
                         "kernels/compress path")
    ap.add_argument("--num-buckets", type=int, default=1,
                    help="bucketed compression (DESIGN.md §2.4): partition "
                         "the flat gradient into this many contiguous "
                         "buckets; the fused sweeps and the sparse "
                         "all-gather run per bucket so collectives overlap "
                         "compaction. Selection is bucketing-invariant; "
                         "1 disables bucketing; 0 auto-tunes the count from "
                         "the sparse-collective payload vs the interconnect "
                         "latency floor (roofline.analysis.auto_num_buckets)")
    ap.add_argument("--allocation", default="global",
                    choices=["global", "proportional", "adaptive"],
                    help="density allocation (DESIGN.md §2.6): how the "
                         "global budget k splits across layer-aligned "
                         "segments of the flat gradient before selection. "
                         "global = one flat top-k (the paper, default); "
                         "proportional = k_l ~ segment size; adaptive = "
                         "k_l from per-segment second-moment statistics "
                         "(Adaptive Top-K style). Every mode conserves "
                         "sum(k_l) == k, so sparse-comm bytes are "
                         "unchanged. Requires --selector exact")
    ap.add_argument("--num-segments", type=int, default=0,
                    help="segment count for --allocation != global: 0 "
                         "follows --num-buckets (or 8 for the flat "
                         "schedule); the train step aligns the cut to "
                         "parameter-leaf boundaries")
    ap.add_argument("--overlap", default="none",
                    choices=["none", "backward"],
                    help="streaming compression (DESIGN.md §2.8): "
                         "backward feeds the gradient into the fused "
                         "pipeline per layer-aligned segment as the "
                         "backward pass emits it, so sweep-1 + EF fold "
                         "run behind the remaining backward work; the "
                         "global trim/pack + sparse collective are the "
                         "only tail barrier. Bit-identical selection/EF "
                         "state to none; requires --pipeline fused")
    ap.add_argument("--selector", default="exact",
                    choices=["exact", "histogram"],
                    help="top-k selection rule: exact lax.top_k semantics, "
                         "or histogram threshold selection (over-selects "
                         "within [k, k*(1+slack)]; served by the fused "
                         "pipeline's sweep-1 bit-pattern histogram, "
                         "DESIGN.md §2.5)")
    ap.add_argument("--wire-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="wire dtype of the packed VALUES the sparse "
                         "all-gather moves (indices stay uint32): "
                         "bfloat16 cuts sparse comm bytes by 25%% with "
                         "bf16 rounding of the combined gradient "
                         "(upcast in the scatter-add combine)")
    ap.add_argument("--err-decay", type=float, default=1.0,
                    help="per-step decay of a sitting-out worker's "
                         "error-feedback memory (DESIGN.md §2.7): "
                         "err' = err_decay * err on non-participating "
                         "steps; 1.0 holds the memory, <1 forgets stale "
                         "residuals a straggler accumulated while absent")
    ap.add_argument("--combine", default="mean",
                    choices=["mean", "support"],
                    help="elastic combine rule (DESIGN.md §2.7): mean = "
                         "sum over active workers / n_active; support = "
                         "each coordinate divided by the number of active "
                         "workers that SELECTED it")
    ap.add_argument("--fault-schedule", default="",
                    help="fault-injection spec (DESIGN.md §2.7): "
                         "'iid:P[,seed=S]' drops each worker each step "
                         "with prob P; 'bursty:period=P,outage=O"
                         "[,workers=i+j]' sits listed workers out for the "
                         "first O of every P steps; 'permanent:step=T"
                         "[,workers=i]' kills them from step T on. Empty "
                         "= full participation (byte-identical program "
                         "to the fault-free build)")
    ap.add_argument("--drop-prob", type=float, default=0.0,
                    help="shorthand for --fault-schedule iid:<p>")
    ap.add_argument("--sketch-rows", type=int, default=3,
                    help="CountSketch rows for kind='sketchtopk' "
                         "(DESIGN.md §2.9); the sketch all-reduce moves "
                         "rows*width floats per step")
    ap.add_argument("--sketch-width", type=int, default=0,
                    help="CountSketch width for kind='sketchtopk'; 0 "
                         "auto-sizes to min(max(4k, 256), 2^22) "
                         "(sketch.resolve_width — warns once when 4k "
                         "exceeds the cap)")
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fixed-batch", action="store_true",
                    help="reuse step 0's batch every step (deterministic "
                         "overfit mode for convergence smoke tests; the "
                         "synthetic stream is uniform-random tokens, which "
                         "carry no learnable signal across fresh batches)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--publish-deltas", default="",
                    help="spool directory for the learning-while-serving "
                         "delta broadcast (DESIGN.md §2.10): after each "
                         "optimizer step the trainer publishes a "
                         "version-stamped, checksummed top-k delta of its "
                         "params there (plus full resync snapshots under "
                         "<dir>/snapshots), which a replica started with "
                         "launch/serve.py --apply-deltas consumes")
    ap.add_argument("--delta-k", type=int, default=0,
                    help="entries per published delta; 0 resolves from "
                         "--sparsity over the whole flat model (the same "
                         "rule as the gradient sync's k)")
    ap.add_argument("--delta-every", type=int, default=1,
                    help="publish every N optimizer steps (>=1)")
    ap.add_argument("--delta-snapshot-every", type=int, default=0,
                    help="write a full resync snapshot every N published "
                         "versions (0 = only the version-0 base and the "
                         "final snapshot); replicas that hit a version gap "
                         "wait for the next snapshot, so lossy channels "
                         "want this small enough to bound the wait")
    ap.add_argument("--delta-fault-schedule", default="",
                    help="delta-channel fault spec (DESIGN.md §2.10): "
                         "'loss:P' drops each published version with prob "
                         "P; 'corrupt:P' bit-flips it in flight (the "
                         "replica's checksum guard detects it); "
                         "'reorder:W' delays each version by a seeded "
                         "amount <= W; 'stall:N[,at=V]' pauses the link "
                         "for N versions and flushes the backlog in order")
    return ap.parse_args(argv)


def resolve_fault_spec(args) -> str:
    """--drop-prob is sugar for --fault-schedule iid:<p>. Validates the
    spec at launch time (argparse surface) instead of deep in trace."""
    spec = args.fault_schedule.strip()
    drop = getattr(args, "drop_prob", 0.0)
    if drop:
        if spec:
            raise SystemExit("--drop-prob is shorthand for --fault-schedule "
                             f"iid:<p>; it conflicts with --fault-schedule "
                             f"{spec!r} — pass one of them")
        spec = f"iid:{drop}"
    if spec:
        from repro.core import faults
        faults.parse_schedule(spec)
    return spec


def resolve_delta_fault_spec(args) -> str:
    """Validate --delta-fault-schedule at the argparse surface."""
    spec = getattr(args, "delta_fault_schedule", "").strip()
    if spec:
        from repro.core import faults
        faults.parse_channel_schedule(spec)
    return spec


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    from repro.configs.base import (OptimizerConfig, RunConfig, SHAPES,
                                    SparsifierConfig, get_config,
                                    reduced_config)
    from repro.data import lm_batch
    from repro.launch.mesh import make_mesh
    from repro.train.step import (build_parallel, build_train_step,
                                  init_train_state, resolve_model_cfg)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    fault_spec = resolve_fault_spec(args)
    run = RunConfig(
        model=cfg, shape=SHAPES["train_4k"],
        sparsifier=SparsifierConfig(kind=args.sparsifier,
                                    sparsity=args.sparsity, mu=args.mu,
                                    comm_mode=args.comm,
                                    pipeline=args.pipeline,
                                    selector=args.selector,
                                    num_buckets=args.num_buckets,
                                    allocation=args.allocation,
                                    num_segments=args.num_segments,
                                    wire_dtype=args.wire_dtype,
                                    err_decay=args.err_decay,
                                    combine=args.combine,
                                    overlap=args.overlap,
                                    sketch_rows=args.sketch_rows,
                                    sketch_width=args.sketch_width),
        optimizer=OptimizerConfig(kind=args.optimizer, lr=args.lr),
        seed=args.seed, steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        fault_schedule=fault_spec,
    )
    mesh = make_mesh(args.data, args.model, args.pods)
    pal = build_parallel(mesh)
    mcfg = resolve_model_cfg(run)
    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params, opt_state, ef_state = init_train_state(run, mesh, pal, key)
        step, _, _ = build_train_step(run, mesh, pal)
        jstep = jax.jit(step, donate_argnums=(0, 1, 2))
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        print(f"[train] {cfg.name}: {n:,} params (global), mesh="
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))}, "
              f"sparsifier={args.sparsifier}@{args.sparsity}")
        from repro.core.aggregate import effective_comm_mode
        sp = run.sparsifier
        if sp.num_buckets == 0:
            # the shared trace-accurate mirror of GradientSync's
            # resolution (train/step.auto_num_buckets_for_run)
            from repro.train.step import auto_num_buckets_for_run
            nb, j_local, dp = auto_num_buckets_for_run(run, mesh, pal)
            print(f"[train] num_buckets=0 -> auto-tuned {nb} "
                  f"(J_local={j_local:,}, dp={dp})")
        print(f"[train] effective comm mode: {effective_comm_mode(sp)}")
        if sp.overlap == "backward":
            from repro.train.step import stream_bounds_for_run
            sb = stream_bounds_for_run(run, mesh, pal)
            print(f"[train] overlap=backward: {len(sb)} stream segments "
                  f"(layer-aligned; DESIGN.md §2.8)")
        if run.fault_schedule:
            from repro.core import faults as _faults
            sched = _faults.parse_schedule(run.fault_schedule)
            ndp = args.data * args.pods
            print(f"[train] fault schedule: {_faults.format_schedule(sched)}"
                  f" (E[n_active]={_faults.expected_active(sched, ndp):.2f}"
                  f"/{ndp}, err_decay={sp.err_decay}, combine={sp.combine})")
        publisher = chan = snap_dir = None
        if args.publish_deltas:
            # learning-while-serving broadcast (DESIGN.md §2.10): the
            # trainer is the publisher; replicas subscribe to the spool
            from repro.core import faults as _faults
            from repro.serve.delta import (FaultyChannel, SpoolChannel,
                                           delta_wire_bytes)
            from repro.train.step import delta_publisher_for_run
            delta_fault = resolve_delta_fault_spec(args)
            publisher = delta_publisher_for_run(run, params, args.delta_k)
            chan = SpoolChannel(args.publish_deltas)
            if delta_fault:
                csched = _faults.parse_channel_schedule(delta_fault)
                chan = FaultyChannel(chan, csched)
                print(f"[train] delta channel faults: "
                      f"{_faults.format_channel_schedule(csched)}")
            snap_dir = os.path.join(args.publish_deltas, "snapshots")
            publisher.write_snapshot(snap_dir)       # version-0 base
            print(f"[train] publishing deltas: k={publisher.k} "
                  f"({delta_wire_bytes(publisher.k):,} wire bytes/delta, "
                  f"J={publisher.j:,}) every {max(1, args.delta_every)} "
                  f"steps -> {args.publish_deltas}")
        import time
        t0 = time.time()
        for t in range(args.steps):
            batch = lm_batch(mcfg, args.batch, args.seq, args.seed,
                             0 if args.fixed_batch else t)
            params, opt_state, ef_state, metrics = jstep(
                params, opt_state, ef_state, batch, key)
            if t % args.log_every == 0 or t == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                health = (f"active {m['n_active']:.0f} "
                          if "n_active" in m else "")
                print(f"step {t:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['gnorm_local']:.3f} "
                      f"nz {m['agg_nonzero']:.4f} "
                      f"{health}({time.time()-t0:.1f}s)")
            if publisher is not None and (t + 1) % max(
                    1, args.delta_every) == 0:
                chan.send(publisher.publish(params))
                if (args.delta_snapshot_every and publisher.version
                        % args.delta_snapshot_every == 0):
                    publisher.write_snapshot(snap_dir)
            if (run.checkpoint_every and run.checkpoint_dir
                    and t and t % run.checkpoint_every == 0):
                from repro.checkpoint import save_checkpoint
                save_checkpoint(run.checkpoint_dir, t, params, opt_state,
                                ef_state, param_version=(
                                    publisher.version if publisher else None))
        if publisher is not None:
            if hasattr(chan, "flush"):
                chan.flush()
            publisher.write_snapshot(snap_dir)
            sent = getattr(chan, "counters", {}).get(
                "sent", publisher.version)
            print(f"[train] published {publisher.version} delta versions "
                  f"({sent} reached the spool); final snapshot at "
                  f"v{publisher.version}")
        if run.checkpoint_dir:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(run.checkpoint_dir, args.steps, params,
                            opt_state, ef_state, param_version=(
                                publisher.version if publisher else None))
    return 0


if __name__ == "__main__":
    sys.exit(main())
