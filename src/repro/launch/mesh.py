"""Production mesh construction. Must be a FUNCTION so importing this module
never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax init).
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary mesh for tests / small runs."""
    import jax
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
