"""Serving launcher: prefill a batch of prompts, then stream decode steps.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --devices 8 --data 4 --model 2 --prompt-len 48 --new-tokens 16
"""
import argparse
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--apply-deltas", default="",
                    help="subscribe to a trainer's delta broadcast "
                         "(DESIGN.md §2.10): serving params start from the "
                         "latest full snapshot under <dir>/snapshots and "
                         "versioned sparse deltas from the spool apply "
                         "between decode steps; in-flight decode stays "
                         "pinned to the version it started on, version "
                         "gaps trigger a snapshot resync, and corrupt or "
                         "non-finite payloads are dropped on health "
                         "counters. Point it at the same directory as "
                         "launch/train.py --publish-deltas")
    ap.add_argument("--delta-fault-schedule", default="",
                    help="inject receive-side delta-channel faults "
                         "(loss:P | corrupt:P | reorder:W | stall:N; "
                         "DESIGN.md §2.10) — same seeded schedules the "
                         "trainer can inject on the send side")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import dataclasses

    import jax
    import jax.numpy as jnp
    from repro.configs.base import (RunConfig, SHAPES, SparsifierConfig,
                                    get_config, reduced_config)
    from repro.launch.mesh import make_mesh
    from repro.models.specs import param_specs, replicated_mask
    from repro.models import init_params
    from repro.serve.step import (build_decode_step, build_prefill,
                                  delta_applier_from_snapshot,
                                  serve_parallel)
    from jax.sharding import PartitionSpec as P

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    if args.mla_absorb:
        cfg = dataclasses.replace(cfg, mla_absorb=True)
    max_seq = args.prompt_len + args.new_tokens
    run = RunConfig(
        model=cfg,
        shape=dataclasses.replace(SHAPES["decode_32k"], seq_len=max_seq,
                                  global_batch=args.batch),
        sparsifier=SparsifierConfig(kind="none"),
    )
    mesh = make_mesh(args.data, args.model)
    pal = serve_parallel(mesh, run, decode=True)
    key = jax.random.PRNGKey(args.seed)
    with mesh:
        tmpl_pal = pal
        pspecs = param_specs(
            jax.eval_shape(lambda k: init_params(cfg, tmpl_pal, k), key)) \
            if pal.tp_on else None

        def init_fn(k):
            pu = init_params(cfg, pal, k)
            if pal.tp_on:
                kf = jax.random.fold_in(k, jax.lax.axis_index("model"))
                pf = init_params(cfg, pal, kf)
                pu = jax.tree_util.tree_map(
                    lambda u, f, r: u if r else f, pu, pf,
                    replicated_mask(pu))
            return pu

        applier = chan = snap_dir = None
        if args.apply_deltas:
            # learning-while-serving (DESIGN.md §2.10): params come from
            # the trainer's latest snapshot, not a fresh init, so the
            # held version means something
            from repro.core import faults as _faults
            from repro.serve.delta import FaultyChannel, SpoolChannel
            snap_dir = os.path.join(args.apply_deltas, "snapshots")
            applier, params = delta_applier_from_snapshot(
                run, mesh, pal, snap_dir)
            chan = SpoolChannel(args.apply_deltas)
            if args.delta_fault_schedule.strip():
                csched = _faults.parse_channel_schedule(
                    args.delta_fault_schedule)
                chan = FaultyChannel(chan, csched)
                print(f"[serve] delta channel faults (recv side): "
                      f"{_faults.format_channel_schedule(csched)}")
            print(f"[serve] applying deltas from {args.apply_deltas} "
                  f"(snapshot v{applier.version})")
        elif pal.tp_on:
            params = jax.jit(jax.shard_map(
                init_fn, mesh=mesh, in_specs=(P(),), out_specs=pspecs,
                check_vma=False))(key)
        else:
            params = init_fn(key)
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        print(f"[serve] {cfg.name}: {n/1e6:.1f}M params, batch {args.batch}, "
              f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}"
              f"{', absorbed MLA' if args.mla_absorb else ''}")

        pre, _ = build_prefill(run, mesh, pal)
        dec, _ = build_decode_step(run, mesh, pal)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        if cfg.frontend == "vision_stub":
            batch["patches"] = jax.random.normal(
                key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        elif cfg.frontend == "audio_stub":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        t0 = time.time()
        logits, cache = jax.jit(pre)(params, batch)
        jax.block_until_ready(logits)
        t_pre = time.time() - t0
        jdec = jax.jit(dec)
        toks = []
        # in-flight consistency contract (DESIGN.md §2.10): this decode
        # stream pins the (params, version) it started on; deltas
        # arriving between its steps advance the applier's LIVE tree
        # without touching the pinned buffers
        pinned, pinned_v = (applier.acquire() if applier is not None
                            else (params, None))
        t0 = time.time()
        for _ in range(args.new_tokens):
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(nxt)
            logits, cache = jdec(pinned, cache, nxt)
            if applier is not None:
                for p in chan.recv():
                    applier.offer(p)
                if applier.needs_resync and applier.can_resync(snap_dir):
                    applier.resync_from(snap_dir)
        jax.block_until_ready(logits)
        t_dec = time.time() - t0
        out = jnp.concatenate(toks, 1)
        print(f"prefill {args.prompt_len} tokens x {args.batch}: {t_pre:.2f}s")
        print(f"decode {args.new_tokens} steps: {t_dec:.2f}s "
              f"({t_dec/args.new_tokens*1e3:.0f} ms/step incl. dispatch)")
        print("first sequences:", out[:2].tolist())
        if applier is not None:
            if hasattr(chan, "flush"):
                for p in chan.flush():
                    applier.offer(p)
            if applier.needs_resync and applier.can_resync(snap_dir):
                applier.resync_from(snap_dir)
            m = applier.metrics()
            print(f"[serve] stream pinned at v{pinned_v}; live params now "
                  f"v{m['param_version']}"
                  f"{' (resync pending)' if m['needs_resync'] else ''}")
            print("[serve] delta health:",
                  {k: m[k] for k in ("received", "applied", "dropped_corrupt",
                                     "dropped_nonfinite", "dropped_stale",
                                     "gaps_detected", "resyncs")})
    return 0


if __name__ == "__main__":
    sys.exit(main())
