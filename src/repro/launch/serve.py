"""Serving launcher: prefill a batch of prompts, then stream decode steps.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --devices 8 --data 4 --model 2 --prompt-len 48 --new-tokens 16
"""
import argparse
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import dataclasses

    import jax
    import jax.numpy as jnp
    from repro.configs.base import (RunConfig, SHAPES, SparsifierConfig,
                                    get_config, reduced_config)
    from repro.launch.mesh import make_mesh
    from repro.models.specs import param_specs, replicated_mask
    from repro.models import init_params
    from repro.serve.step import (build_decode_step, build_prefill,
                                  serve_parallel)
    from jax.sharding import PartitionSpec as P

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    if args.mla_absorb:
        cfg = dataclasses.replace(cfg, mla_absorb=True)
    max_seq = args.prompt_len + args.new_tokens
    run = RunConfig(
        model=cfg,
        shape=dataclasses.replace(SHAPES["decode_32k"], seq_len=max_seq,
                                  global_batch=args.batch),
        sparsifier=SparsifierConfig(kind="none"),
    )
    mesh = make_mesh(args.data, args.model)
    pal = serve_parallel(mesh, run, decode=True)
    key = jax.random.PRNGKey(args.seed)
    with mesh:
        tmpl_pal = pal
        pspecs = param_specs(
            jax.eval_shape(lambda k: init_params(cfg, tmpl_pal, k), key)) \
            if pal.tp_on else None

        def init_fn(k):
            pu = init_params(cfg, pal, k)
            if pal.tp_on:
                kf = jax.random.fold_in(k, jax.lax.axis_index("model"))
                pf = init_params(cfg, pal, kf)
                pu = jax.tree_util.tree_map(
                    lambda u, f, r: u if r else f, pu, pf,
                    replicated_mask(pu))
            return pu

        if pal.tp_on:
            params = jax.jit(jax.shard_map(
                init_fn, mesh=mesh, in_specs=(P(),), out_specs=pspecs,
                check_vma=False))(key)
        else:
            params = init_fn(key)
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        print(f"[serve] {cfg.name}: {n/1e6:.1f}M params, batch {args.batch}, "
              f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}"
              f"{', absorbed MLA' if args.mla_absorb else ''}")

        pre, _ = build_prefill(run, mesh, pal)
        dec, _ = build_decode_step(run, mesh, pal)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        if cfg.frontend == "vision_stub":
            batch["patches"] = jax.random.normal(
                key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        elif cfg.frontend == "audio_stub":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        t0 = time.time()
        logits, cache = jax.jit(pre)(params, batch)
        jax.block_until_ready(logits)
        t_pre = time.time() - t0
        jdec = jax.jit(dec)
        toks = []
        t0 = time.time()
        for _ in range(args.new_tokens):
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(nxt)
            logits, cache = jdec(params, cache, nxt)
        jax.block_until_ready(logits)
        t_dec = time.time() - t0
        out = jnp.concatenate(toks, 1)
        print(f"prefill {args.prompt_len} tokens x {args.batch}: {t_pre:.2f}s")
        print(f"decode {args.new_tokens} steps: {t_dec:.2f}s "
              f"({t_dec/args.new_tokens*1e3:.0f} ms/step incl. dispatch)")
        print("first sequences:", out[:2].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
