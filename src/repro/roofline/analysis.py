"""Three-term roofline analysis from dry-run compile artifacts.

Terms per (arch x shape x mesh), DESIGN.md §5 — all in seconds:

  compute    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

Conventions: ``compiled.cost_analysis()`` on a jit-of-shard_map returns the
PER-DEVICE program's flops/bytes (the SPMD module is per-device), so compute
and memory terms divide by 1 chip; collective bytes parsed from the HLO are
also per-device payloads. MODEL_FLOPS uses the 6*N*D training rule (2*N*D
per token forward for decode) with N = ACTIVE params.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops_bf16: float      # per chip
    hbm_bw: float               # bytes/s per chip
    ici_bw: float               # bytes/s per link


HW_V5E = Hardware("tpu-v5e", 197e12, 819e9, 50e9)


def model_flops(kind: str, active_params: int, global_batch: int,
                seq_len: int) -> float:
    """Useful model FLOPs for the whole step (all chips)."""
    if kind == "train":
        return 6.0 * active_params * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * active_params * global_batch * seq_len
    # decode: one token per sequence
    return 2.0 * active_params * global_batch


def roofline_terms(rec: dict, hw: Hardware = HW_V5E) -> dict:
    """rec: one dryrun.py record. Returns the three terms + diagnosis."""
    mesh = rec["mesh"]
    chips = 1
    for v in mesh.values():
        chips *= v
    # loop-aware HLO parse (per-device); falls back to cost_analysis fields
    flops = rec.get("hlo_flops", rec["flops"])
    hbm = rec.get("hlo_bytes", rec["bytes_accessed"])
    wire = rec.get("hlo_collective_wire_bytes",
                   rec["collective_bytes"]["total"])
    t_compute = flops / hw.peak_flops_bf16
    t_memory = hbm / hw.hbm_bw
    t_coll = wire / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["kind"], rec["active_params"],
                     rec_global_batch(rec), rec_seq_len(rec))
    hlo_total_flops = flops * chips
    terms.update({
        "dominant": dominant.replace("_s", ""),
        "chips": chips,
        "model_flops": mf,
        "hlo_flops_total": hlo_total_flops,
        "useful_ratio": mf / hlo_total_flops if hlo_total_flops > 0 else 0.0,
        "step_time_lb_s": max(terms.values()),
        "mfu_upper_bound": (mf / chips / hw.peak_flops_bf16) /
                           max(max(terms.values()), 1e-12),
    })
    return terms


def rec_global_batch(rec: dict) -> int:
    from repro.configs.base import SHAPES
    return SHAPES[rec["shape"]].global_batch


def rec_seq_len(rec: dict) -> int:
    from repro.configs.base import SHAPES
    return SHAPES[rec["shape"]].seq_len


def analyze_record(rec: dict, hw: Hardware = HW_V5E) -> dict:
    out = dict(rec)
    out["roofline"] = roofline_terms(rec, hw)
    return out


def format_table(records: list, hw: Hardware = HW_V5E) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    rows = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful FLOPs ratio | MFU ub |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        t = roofline_terms(rec, hw)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | "
            f"{t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} | "
            f"{t['collective_s']*1e3:.2f} | {t['dominant']} | "
            f"{t['useful_ratio']:.2f} | {t['mfu_upper_bound']*100:.0f}% |")
    return "\n".join(rows)
