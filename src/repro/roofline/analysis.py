"""Three-term roofline analysis from dry-run compile artifacts.

Terms per (arch x shape x mesh), DESIGN.md §5 — all in seconds:

  compute    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

Conventions: ``compiled.cost_analysis()`` on a jit-of-shard_map returns the
PER-DEVICE program's flops/bytes (the SPMD module is per-device), so compute
and memory terms divide by 1 chip; collective bytes parsed from the HLO are
also per-device payloads. MODEL_FLOPS uses the 6*N*D training rule (2*N*D
per token forward for decode) with N = ACTIVE params.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops_bf16: float      # per chip
    hbm_bw: float               # bytes/s per chip
    ici_bw: float               # bytes/s per link


HW_V5E = Hardware("tpu-v5e", 197e12, 819e9, 50e9)

# Per-collective dispatch + ICI setup latency floor: below this, splitting
# a collective into more chunks costs more in launch latency than the
# pipelined overlap recovers (the "bytes per chunk vs interconnect latency
# floor" term of the num_buckets auto-tune, ROADMAP / DESIGN.md §2.4).
COLLECTIVE_LATENCY_S = 5e-6
# A chunk below this wire size is latency-dominated — never split finer.
MIN_CHUNK_BYTES = 1 << 16
# Past ~16 chunks the overlap model's min/B term is already flat (and the
# sweep audit resolves bucketings only to ~16, DESIGN.md §2.3).
MAX_AUTO_BUCKETS = 16


def auto_num_buckets(packed_len: int, n_workers: int,
                     hw: Hardware = HW_V5E,
                     latency_s: float = COLLECTIVE_LATENCY_S,
                     max_buckets: int = MAX_AUTO_BUCKETS) -> int:
    """Auto-tuned bucket count for the chunked sparse-comm schedule.

    The bucketed all-gather (DESIGN.md §2.4) pipelines each chunk's
    collective against the previous chunk's local scatter-add combine;
    with B chunks the exposed time is

        exposed(B) ~= max(t_coll, t_combine) + min(t_coll, t_combine)/B
                      + (B - 1) * latency_s

    where t_coll = payload / ici_bw (wire) and t_combine =
    payload / hbm_bw (the combine's HBM landing traffic) over the
    gathered payload n_workers * packed_len * 8 bytes (fp32 values +
    uint32 indices per rank). Minimizing over B gives
    B* = sqrt(min(t_coll, t_combine) / latency_s), clamped so every
    chunk stays above MIN_CHUNK_BYTES and B <= max_buckets. Small
    payloads (smoke scale) resolve to 1 — chunking only pays once the
    combine itself outweighs a collective launch.

    Deterministic in its inputs: ``num_buckets=0`` and a manual
    ``num_buckets=auto_num_buckets(...)`` flag are bit-identical
    (bucketing never changes selection semantics regardless).
    """
    import math
    payload = max(0, int(n_workers)) * max(0, int(packed_len)) * 8
    if payload <= 0 or latency_s <= 0:
        return 1
    t_coll = payload / hw.ici_bw
    t_combine = payload / hw.hbm_bw
    short = min(t_coll, t_combine)
    if short <= latency_s:
        return 1
    b = int(math.sqrt(short / latency_s))
    b = min(b, max(1, payload // MIN_CHUNK_BYTES), int(max_buckets))
    return max(1, b)


def model_flops(kind: str, active_params: int, global_batch: int,
                seq_len: int) -> float:
    """Useful model FLOPs for the whole step (all chips)."""
    if kind == "train":
        return 6.0 * active_params * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * active_params * global_batch * seq_len
    # decode: one token per sequence
    return 2.0 * active_params * global_batch


def pipelined_overlap_s(t_coll: float, t_local: float,
                        num_buckets: int = 1) -> float:
    """Exposed wall time of a collective pipelined against local work.

    The bucketed sparse-comm schedule (DESIGN.md §2.4) splits one
    monolithic all-gather + scatter-add into num_buckets independent
    chunk chains, so chunk b's collective overlaps chunk b+1's local
    compaction. With B perfectly balanced chunks the exposed time is the
    classic pipeline bound

        max(t_coll, t_local) + min(t_coll, t_local) / B

    (the longer side streams continuously; one chunk of the shorter side
    sticks out at the pipeline head). B = 1 degenerates to the fully
    serialized t_coll + t_local.
    """
    b = max(1, int(num_buckets))
    return max(t_coll, t_local) + min(t_coll, t_local) / b


def comm_behind_backward_s(t_gather: float, t_backward: float,
                           num_segments: int = 1) -> float:
    """EXPOSED share of the sparse collective under streaming
    compression (overlap="backward", DESIGN.md §2.8).

    With the gradient fed per layer-aligned segment, segment s's sweep-1
    + chunked all-gather launch while the backward pass still produces
    segments s+1..S, so the collective hides behind the remaining
    backward work instead of starting after it:

        exposed(S) = max(0, t_gather - t_backward)
                     + min(t_gather, t_backward) / S

    — the same head-of-pipeline bound as :func:`pipelined_overlap_s`,
    but only the collective's overhang is exposed (the backward pass
    runs regardless and is already counted in the compute term, so its
    overhang costs the collective nothing). S = 1 degenerates to the
    fully serialized t_gather; S >= 2 is strictly smaller whenever both
    times are positive.
    """
    s = max(1, int(num_segments))
    return max(0.0, t_gather - t_backward) + min(t_gather, t_backward) / s


def roofline_terms(rec: dict, hw: Hardware = HW_V5E) -> dict:
    """rec: one dryrun.py record. Returns the three terms + diagnosis.

    When the record carries ``num_buckets`` (> 1), the collective model
    additionally reports ``collective_exposed_s`` — the per-bucket
    overlap term: the sparse all-gather wire time pipelined against the
    local scatter-add/compaction share of the memory term instead of
    serialized after it.

    When the record carries ``overlap == "backward"`` (+
    ``num_stream_segments``), it also reports the comm-behind-backward
    view (DESIGN.md §2.8): ``backward_overlap_s`` (collective time
    hidden behind the backward pass) and
    ``collective_exposed_backward_s`` (whole-step collective term with
    the sparse gather's exposed share reduced to
    :func:`comm_behind_backward_s`), with t_backward ~= (2/3) *
    compute_s per the 6ND train rule (forward 2ND, backward 4ND).
    """
    mesh = rec["mesh"]
    chips = 1
    for v in mesh.values():
        chips *= v
    # loop-aware HLO parse (per-device); falls back to cost_analysis fields
    flops = rec.get("hlo_flops", rec["flops"])
    hbm = rec.get("hlo_bytes", rec["bytes_accessed"])
    wire = rec.get("hlo_collective_wire_bytes",
                   rec["collective_bytes"]["total"])
    t_compute = flops / hw.peak_flops_bf16
    t_memory = hbm / hw.hbm_bw
    t_coll = wire / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["kind"], rec["active_params"],
                     rec_global_batch(rec), rec_seq_len(rec))
    hlo_total_flops = flops * chips
    terms.update({
        "dominant": dominant.replace("_s", ""),
        "chips": chips,
        "model_flops": mf,
        "hlo_flops_total": hlo_total_flops,
        "useful_ratio": mf / hlo_total_flops if hlo_total_flops > 0 else 0.0,
        "step_time_lb_s": max(t_compute, t_memory, t_coll),
        "mfu_upper_bound": (mf / chips / hw.peak_flops_bf16) /
                           max(t_compute, t_memory, t_coll, 1e-12),
    })
    num_buckets = int(rec.get("num_buckets", 1))
    if num_buckets > 1:
        # diagnostic (not part of the three-term lower bound): only the
        # sparse gradient all-gather is chunked, so prefer the record's
        # own breakdown (``sparse_gather_wire_bytes``) when present;
        # falling back to the whole-step wire bytes makes this an UPPER
        # BOUND on the overlappable share (ZeRO-1 param gathers and TP
        # psums in ``wire`` are not chunked by the schedule)
        gw = rec.get("sparse_gather_wire_bytes", wire)
        t_gather = gw / hw.ici_bw
        # the local work a chunk's collective hides behind is the
        # scatter-add combine of the previously gathered pairs —
        # bounded by their HBM landing traffic (written exactly once)
        t_combine = min(t_memory, gw / hw.hbm_bw)
        terms["collective_exposed_s"] = (t_coll - t_gather) + \
            pipelined_overlap_s(t_gather, t_combine, num_buckets)
        terms["num_buckets"] = num_buckets
    if rec.get("overlap") == "backward" and rec.get("kind") == "train":
        # streaming view (DESIGN.md §2.8): the sparse gather share of the
        # collective term launches per layer-aligned segment behind the
        # remaining backward work; only its overhang past the backward
        # pass (plus one segment's pipeline head) stays exposed.
        num_segments = int(rec.get("num_stream_segments", 1))
        gw = rec.get("sparse_gather_wire_bytes", wire)
        t_gather = gw / hw.ici_bw
        t_bwd = (2.0 / 3.0) * t_compute      # 6ND rule: backward = 4ND/6ND
        exposed = comm_behind_backward_s(t_gather, t_bwd, num_segments)
        terms["num_stream_segments"] = num_segments
        terms["backward_overlap_s"] = t_gather - exposed
        terms["collective_exposed_backward_s"] = (t_coll - t_gather) + exposed
    skb = rec.get("sketch_allreduce_bytes")
    if skb:
        # sketch-coordinated selection (DESIGN.md §2.9): one extra
        # all-reduce of the (rows, width) CountSketch BEFORE selection.
        # It is a pre-selection barrier — it cannot hide behind the
        # backward pass (check_overlap rejects overlap="backward") or
        # behind the value all-gather (the shared mask gates the
        # gather), so its wire time is exposed serially and is reported
        # as its own term next to the values-only gather share.
        t_sketch = skb / hw.ici_bw
        terms["sketch_allreduce_s"] = t_sketch
        gw = rec.get("sparse_gather_wire_bytes")
        if gw is not None:
            # shared-mask wire: values only, so the gather share the
            # sketch barrier buys back is the halved-payload gather
            terms["coordinated_collective_s"] = \
                t_sketch + gw / hw.ici_bw
    delta = rec.get("delta")
    if delta:
        # learning-while-serving channel (DESIGN.md §2.10): per
        # published version the replica pays the sparse broadcast on the
        # wire and an O(k) scatter in HBM; a version gap escalates to a
        # full-snapshot resync. delta_apply_s bills reading the k
        # (value, index) pairs plus the read-modify-write of the k
        # touched parameter slots (16 bytes/entry in fp32) — the
        # between-decode-steps stall the apply adds. resync_equiv_deltas
        # is the staleness-vs-bandwidth breakeven: a channel that gaps
        # more often than once per that many versions spends its sparse
        # savings on snapshots.
        k = int(delta.get("k", 0))
        wire = float(delta.get("wire_bytes", 0))
        terms["delta_wire_bytes"] = wire
        terms["delta_bcast_s"] = wire / hw.ici_bw
        terms["delta_apply_s"] = (16.0 * k) / hw.hbm_bw
        rs = float(delta.get("resync_bytes", 0))
        terms["resync_bytes"] = rs
        terms["resync_s"] = rs / hw.ici_bw
        if delta.get("resync_equiv_deltas") is not None:
            terms["resync_equiv_deltas"] = float(
                delta["resync_equiv_deltas"])
        dfault = delta.get("fault")
        if dfault and wire:
            # expected wire cost per PUBLISHED version when the channel
            # drops mass: every accepted version costs one delta; the
            # lost fraction is eventually bought back by snapshots
            rate = float(dfault.get("delivery_rate_expected", 1.0))
            terms["delta_delivery_rate"] = rate
            terms["delta_wire_bytes_effective"] = \
                wire + (1.0 - rate) * rs / max(
                    1.0, terms.get("resync_equiv_deltas", 1.0))
    fault = rec.get("fault")
    if fault:
        # straggler-exposed view (DESIGN.md §2.7): with an elastic
        # transport, absent workers transmit nothing, so the sparse
        # gradient all-gather share shrinks to the record's idealized
        # E[n_active] volume; everything else (param gathers, TP psums)
        # is participation-invariant. The compiled fixed-shape program
        # does NOT realize this gain — inert payloads still move — which
        # is exactly the gap this term quantifies.
        gw = rec.get("sparse_gather_wire_bytes")
        gw_act = fault.get("sparse_gather_wire_bytes_active")
        terms["n_active_expected"] = fault.get("n_active_expected")
        if gw is not None and gw_act is not None:
            t_gather = gw / hw.ici_bw
            t_gather_act = gw_act / hw.ici_bw
            terms["collective_elastic_s"] = t_coll - t_gather + t_gather_act
            terms["straggler_wire_gain_s"] = t_gather - t_gather_act
    return terms


def rec_global_batch(rec: dict) -> int:
    from repro.configs.base import SHAPES
    return SHAPES[rec["shape"]].global_batch


def rec_seq_len(rec: dict) -> int:
    from repro.configs.base import SHAPES
    return SHAPES[rec["shape"]].seq_len


def analyze_record(rec: dict, hw: Hardware = HW_V5E) -> dict:
    out = dict(rec)
    out["roofline"] = roofline_terms(rec, hw)
    return out


def format_table(records: list, hw: Hardware = HW_V5E) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    rows = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful FLOPs ratio | MFU ub |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        t = roofline_terms(rec, hw)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | "
            f"{t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} | "
            f"{t['collective_s']*1e3:.2f} | {t['dominant']} | "
            f"{t['useful_ratio']:.2f} | {t['mfu_upper_bound']*100:.0f}% |")
    return "\n".join(rows)
