"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (scan bodies are
not multiplied by trip count), which under-reports every scanned-layer model
by ~n_layers x. This parser rebuilds the numbers from ``compiled.as_text()``:

- computations are parsed with their instructions;
- the call graph is walked from ENTRY; while bodies multiply by
  ``backend_config known_trip_count`` (default 1 + flag if unknown);
- per instruction we accumulate:
    * FLOPs for dot/convolution (2 x out_elems x contracted size),
    * HBM bytes ~ operand + output bytes of surface instructions (fusion
      internals excluded — a fusion reads its operands and writes its output
      once),
    * collective WIRE bytes per device with ring factors:
        all-gather: out x (g-1)/g         all-reduce: out x 2(g-1)/g
        reduce-scatter: out x (g-1)       all-to-all: out x (g-1)/g
        collective-permute: out x 1
      (g = replica group size parsed from replica_groups).

Numbers are per-device (the SPMD module is per-device).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
             "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
             "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _split_instr(line: str):
    """Parse '%name = TYPE opcode(operands...), attrs' robustly (TYPE may be
    a tuple in parens). Returns (name, type_str, opcode, operand_span) or
    None."""
    line = _COMMENT_RE.sub("", line)
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":          # tuple type
        depth = 0
        j = i
        while j < n:
            depth += line[j] == "("
            depth -= line[j] == ")"
            j += 1
            if depth == 0:
                break
        type_str = line[i:j]
        i = j
    else:                                  # simple type token
        j = line.find(" ", i)
        if j < 0:
            return None
        type_str = line[i:j]
        i = j
    # opcode: next identifier followed by '('
    m2 = re.match(r"\s*([\w\-]+)\(", line[i:])
    if not m2:
        return None
    opcode = m2.group(1)
    start = i + m2.end()
    depth = 1
    j = start
    while j < n and depth:
        depth += line[j] == "("
        depth -= line[j] == ")"
        j += 1
    return name, type_str, opcode, line[start:j - 1], line
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota", "broadcast",
                   "partition-id", "replica-id"}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _type_bytes_elems(type_str: str):
    """bytes and element count of a (possibly tuple) HLO type string."""
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DT_BYTES[dt]
        total_e += n
    return total_b, total_e


@dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    line: str
    out_bytes: int = 0
    out_elems: int = 0
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)


def parse_hlo(text: str):
    comps = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line.strip()) if "{" in line else None
            if m and "->" in line:
                cur = Computation(m.group(2))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _split_instr(line)
        if parsed is None:
            continue
        name, type_str, opcode, operand_span, clean = parsed
        ins = Instr(name, opcode, type_str, clean)
        ins.out_bytes, ins.out_elems = _type_bytes_elems(type_str)
        ins.operands = re.findall(r"%([\w.\-]+)", operand_span)
        cur.instrs.append(ins)
    return comps


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_bytes(opcode: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if opcode == "all-gather":
        return out_bytes * (g - 1) / g
    if opcode == "all-reduce":
        return out_bytes * 2 * (g - 1) / g
    if opcode == "reduce-scatter":
        return out_bytes * (g - 1)
    if opcode == "all-to-all":
        return out_bytes * (g - 1) / g
    if opcode == "collective-permute":
        return float(out_bytes)
    return 0.0


def _dot_flops(ins: Instr, shapes: dict) -> float:
    """2 x out_elems x contracted-dim product."""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if not m or not ins.operands:
        return 2.0 * ins.out_elems   # fallback
    lhs = shapes.get(ins.operands[0])
    if lhs is None:
        return 2.0 * ins.out_elems
    contract = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs):
            contract *= lhs[int(d)]
    return 2.0 * ins.out_elems * contract


def analyze_hlo(text: str, n_devices_default: int = 1) -> dict:
    comps = parse_hlo(text)
    # instruction output shapes (dims only) for dot contraction lookup
    shapes = {}
    for c in comps.values():
        for ins in c.instrs:
            mm = _SHAPE_RE.findall(ins.type_str)
            if mm:
                dims = [int(d) for d in mm[0][1].split(",") if d]
                shapes[ins.name] = dims

    # ENTRY is emitted last by XLA (and usually named main*)
    names = list(comps)
    entry = next((n for n in names if n.startswith("main") or ".main" in n),
                 names[-1] if names else None)

    out = {
        "flops": 0.0, "hbm_bytes": 0.0,
        "collectives": {k: 0.0 for k in COLLECTIVE_OPS},
        "collective_wire_bytes": 0.0,
        "unknown_trip_loops": 0,
    }

    def visit(comp_name: str, mult: float, stack=()):
        c = comps.get(comp_name)
        if c is None or comp_name in stack:
            return
        for ins in c.instrs:
            if ins.opcode == "while":
                m = _TRIP_RE.search(ins.line)
                trip = int(m.group(1)) if m else 1
                if not m:
                    out["unknown_trip_loops"] += 1
                mb = re.search(r"body=%([\w.\-]+)", ins.line)
                if mb:
                    visit(mb.group(1), mult * trip, stack + (comp_name,))
                continue
            if ins.opcode == "conditional":
                for mb in re.finditer(r"%([\w.\-]+)", ins.line):
                    if mb.group(1) in comps and "region" in mb.group(1):
                        visit(mb.group(1), mult, stack + (comp_name,))
                continue
            if ins.opcode in ("dot", "convolution"):
                out["flops"] += mult * _dot_flops(ins, shapes)
            if ins.opcode in COLLECTIVE_OPS:
                g = _group_size(ins.line, n_devices_default)
                wb = _wire_bytes(ins.opcode, ins.out_bytes, g)
                out["collectives"][ins.opcode] += mult * wb
                out["collective_wire_bytes"] += mult * wb
            if ins.opcode not in _SKIP_BYTES_OPS:
                # HBM traffic model: every value is written once and charged
                # one read at its FIRST consumption (repeat reads of the same
                # buffer are assumed cached/fused on TPU — documented
                # approximation; see module docstring).
                if ("dynamic-update-slice" in ins.name
                        or ins.opcode == "dynamic-update-slice"):
                    # in-place aliased update: traffic = the UPDATE slice
                    # (read + write), not the whole stacked buffer
                    ops_b = sorted(_producer_bytes.get(o, 0)
                                   for o in ins.operands)
                    upd = sum(ops_b[:-1]) if len(ops_b) > 1 else 0
                    out["hbm_bytes"] += mult * 2 * upd
                    continue
                reads = 0
                for o in ins.operands:
                    if o not in _consumed:
                        _consumed.add(o)
                        reads += _producer_bytes.get(o, 0)
                out["hbm_bytes"] += mult * (ins.out_bytes + reads)

    # producer bytes map + first-consumption tracking
    _producer_bytes = {}
    _consumed = set()
    for c in comps.values():
        for ins in c.instrs:
            _producer_bytes[ins.name] = ins.out_bytes

    visit(entry, 1.0)
    return out


def analyze_compiled(compiled, n_devices_default: int = 1) -> dict:
    return analyze_hlo(compiled.as_text(), n_devices_default)
