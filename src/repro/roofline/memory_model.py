"""Analytic per-device memory model for the production mesh ("does it fit
16 GB of v5e HBM?"). Derived from our sharding rules — exact for parameter /
state / cache residency; activations use the remat working-set estimate.

Beyond the resident breakdown, the model surfaces the PEAK HBM per train
step: the compress stage's transient working buffers (the fused sweeps'
``a``/``score`` streams, or the reference path's longer dense chain) live
simultaneously with the resident state, and — when the EF state buffers
are NOT donated into the jitted step (``jax.jit(..., donate_argnums)``,
as launch/train.py does) — every step transiently double-buffers the
J-sized state vectors it rewrites. ``MemoryBreakdown.peak`` accounts for
both; ``fits_hbm`` gates on it.

XLA's CompiledMemoryStats on the CPU backend aggregates buffers in a
backend-dependent way (see EXPERIMENTS.md §4 note), so the fits-check uses
this model; the raw XLA numbers are recorded alongside in the dry-run JSON.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import RunConfig
from repro.core.sparsify import resolve_k
from repro.models.params import count_params_analytic


@dataclass
class MemoryBreakdown:
    params: float
    grads: float
    opt: float
    ef: float
    cache: float
    activations: float
    # transient compress working set (fused: the a/score fp32 streams;
    # reference: its longer dense score/mask/ghat chain). Zero outside
    # train steps.
    compress_transient: float = 0.0
    # extra J-sized state copies alive while the step rewrites err/mom
    # buffers that were NOT donated in place (0 when donated)
    state_double_buffer: float = 0.0

    @property
    def total(self):
        """Resident bytes (state + activation working set)."""
        return (self.params + self.grads + self.opt + self.ef + self.cache +
                self.activations)

    @property
    def peak(self):
        """Peak per-step bytes: resident + compress transients + any
        undonated state double-buffering."""
        return self.total + self.compress_transient + self.state_double_buffer


def _dtype_bytes(dt: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[dt]


def per_device_memory(run: RunConfig, *, tp=16, dp=16, kind="train",
                      state_format=None, ef_dtype=None,
                      donate_ef: bool = True) -> MemoryBreakdown:
    """``donate_ef=False`` models a caller that does NOT donate the EF
    state buffers into the jitted step: the J-sized vectors the step
    rewrites (err_prev, DGC's mom, the reference layouts' err/a_prev/
    s_prev) are then transiently double-buffered
    (MemoryBreakdown.state_double_buffer). launch/train.py donates
    (params, opt, ef), so the default matches production.

    Density allocation (DESIGN.md §2.6) is memory-invariant at this
    model's resolution: every mode keeps the same J-sized state and
    k-sized packed pairs (sum(k_l) == k), adding only O(num_segments)
    counts and O(sum(caps)) ~ O(k) trim transients — both below the
    J-scale terms modeled here, so no ``sp.allocation`` branch exists
    on purpose."""
    cfg = run.model
    sp = run.sparsifier
    state_format = state_format or sp.state_format
    ef_dtype = ef_dtype or sp.ef_dtype
    shape = run.shape
    n = count_params_analytic(cfg)
    j_local = n / tp                       # flat per-(data,model)-rank vector
    pb = _dtype_bytes(cfg.dtype)
    params = n / tp * pb
    if kind != "train":
        b_local = max(shape.global_batch // dp, 1)
        cache = 0.0
        if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            # KV cache (full or sliding window), seq sharded when batch < dp
            kv = cfg.n_kv_heads
            kvp = -(-kv // tp) * tp
            hd = cfg.resolved_head_dim
            seq = shape.seq_len
            if cfg.attn_kind == "sliding" or (shape.name == "long_500k"
                                              and cfg.attn_kind == "full"):
                seq = min(seq, cfg.window)
            seq_local = seq if shape.global_batch >= dp else seq // dp
            n_attn = cfg.n_layers if cfg.attn_every <= 1 else \
                cfg.n_layers // cfg.attn_every
            if cfg.attn_kind == "mla":
                per_tok = cfg.kv_lora_rank + cfg.rope_head_dim
            else:
                per_tok = 2 * (kvp // tp) * hd
            cache = b_local * seq_local * per_tok * pb * n_attn
        return MemoryBreakdown(params, 0, 0, 0, cache,
                               0.1e9)  # decode activations are tiny
    grads = j_local * 4                    # fp32 flat gradient (transient)
    opt = 3 * (j_local / dp) * 4           # ZeRO-1 master+m+v fp32
    efb = _dtype_bytes(ef_dtype)
    k = resolve_k(sp, int(j_local))
    # the capability table (kernels.compress.dispatch) decides which
    # layout a config actually runs — never re-derive it here
    from repro.kernels.compress.dispatch import dispatch as _dispatch
    fused = _dispatch(sp).path == "fused"
    if fused:
        # two-traversal layout (DESIGN.md §2.2): ONE J-sized vector
        # (err_prev; + mom for DGC) + REGTOP-k's O(k) posterior — no
        # dense mask, no a_prev copy
        ef = j_local * efb * (2 if sp.kind == "dgc" else 1)
        if sp.kind == "regtopk":
            ef += k * (4 + 2 * efb)        # idx u32 + a_sel/g_sel
    elif sp.kind == "regtopk" and state_format == "dense":
        ef = (1 * j_local + 3 * j_local) * efb     # err + a_prev+s_prev+g_prev
    elif sp.kind == "regtopk":
        ef = j_local * efb + 3 * k * 4
    elif sp.kind in ("topk", "thresholdk", "sketchtopk", "randk"):
        ef = j_local * efb
    elif sp.kind == "dgc":
        ef = 2 * j_local * efb
    else:
        ef = 0.0
    # compress transients: the fused sweeps stream two fp32 J-vectors
    # (a, score); the reference chain holds ~4 (a, score, mask, ghat)
    if sp.kind in ("none", "globaltopk"):
        compress_transient = 0.0
    elif fused:
        compress_transient = 2 * j_local * 4
    else:
        compress_transient = 4 * j_local * 4
    state_double_buffer = 0.0 if donate_ef else ef
    # activations: remat keeps one super-block working set + layer inputs
    b_local = shape.global_batch // dp
    seq_local = shape.seq_len // tp        # SP-sharded residual stream
    from repro.models.transformer import n_superblocks, superblock_period
    nsb = n_superblocks(cfg)
    resid = b_local * shape.seq_len * cfg.d_model * pb  # gathered, transient
    saved = nsb * b_local * seq_local * cfg.d_model * pb * superblock_period(cfg)
    activations = saved + 4 * resid
    return MemoryBreakdown(params, grads, opt, ef, 0.0, activations,
                           compress_transient, state_double_buffer)


def fits_hbm(run: RunConfig, hbm_bytes=16e9, **kw) -> tuple:
    """Gates on the PEAK per-step bytes (resident + compress transients
    + any undonated state double-buffer), not just residency."""
    mb = per_device_memory(run, **kw)
    return mb.peak <= hbm_bytes, mb
