"""Analytic per-device memory model for the production mesh ("does it fit
16 GB of v5e HBM?"). Derived from our sharding rules — exact for parameter /
state / cache residency; activations use the remat working-set estimate.

XLA's CompiledMemoryStats on the CPU backend aggregates buffers in a
backend-dependent way (see EXPERIMENTS.md §4 note), so the fits-check uses
this model; the raw XLA numbers are recorded alongside in the dry-run JSON.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import RunConfig
from repro.core.sparsify import resolve_k
from repro.models.params import count_params_analytic


@dataclass
class MemoryBreakdown:
    params: float
    grads: float
    opt: float
    ef: float
    cache: float
    activations: float

    @property
    def total(self):
        return (self.params + self.grads + self.opt + self.ef + self.cache +
                self.activations)


def _dtype_bytes(dt: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[dt]


def per_device_memory(run: RunConfig, *, tp=16, dp=16, kind="train",
                      state_format=None, ef_dtype=None) -> MemoryBreakdown:
    cfg = run.model
    sp = run.sparsifier
    state_format = state_format or sp.state_format
    ef_dtype = ef_dtype or sp.ef_dtype
    shape = run.shape
    n = count_params_analytic(cfg)
    j_local = n / tp                       # flat per-(data,model)-rank vector
    pb = _dtype_bytes(cfg.dtype)
    params = n / tp * pb
    if kind != "train":
        b_local = max(shape.global_batch // dp, 1)
        cache = 0.0
        if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            # KV cache (full or sliding window), seq sharded when batch < dp
            kv = cfg.n_kv_heads
            kvp = -(-kv // tp) * tp
            hd = cfg.resolved_head_dim
            seq = shape.seq_len
            if cfg.attn_kind == "sliding" or (shape.name == "long_500k"
                                              and cfg.attn_kind == "full"):
                seq = min(seq, cfg.window)
            seq_local = seq if shape.global_batch >= dp else seq // dp
            n_attn = cfg.n_layers if cfg.attn_every <= 1 else \
                cfg.n_layers // cfg.attn_every
            if cfg.attn_kind == "mla":
                per_tok = cfg.kv_lora_rank + cfg.rope_head_dim
            else:
                per_tok = 2 * (kvp // tp) * hd
            cache = b_local * seq_local * per_tok * pb * n_attn
        return MemoryBreakdown(params, 0, 0, 0, cache,
                               0.1e9)  # decode activations are tiny
    grads = j_local * 4                    # fp32 flat gradient (transient)
    opt = 3 * (j_local / dp) * 4           # ZeRO-1 master+m+v fp32
    efb = _dtype_bytes(ef_dtype)
    k = resolve_k(sp, int(j_local))
    if sp.kind == "regtopk" and state_format == "dense":
        ef = (1 * j_local + 3 * j_local) * efb     # err + a_prev+s_prev+g_prev
    elif sp.kind == "regtopk":
        ef = j_local * efb + 3 * k * 4
    elif sp.kind in ("topk", "thresholdk", "sketchtopk"):
        ef = j_local * efb
    elif sp.kind == "dgc":
        ef = 2 * j_local * efb
    else:
        ef = 0.0
    # activations: remat keeps one super-block working set + layer inputs
    b_local = shape.global_batch // dp
    seq_local = shape.seq_len // tp        # SP-sharded residual stream
    from repro.models.transformer import n_superblocks, superblock_period
    nsb = n_superblocks(cfg)
    resid = b_local * shape.seq_len * cfg.d_model * pb  # gathered, transient
    saved = nsb * b_local * seq_local * cfg.d_model * pb * superblock_period(cfg)
    activations = saved + 4 * resid
    return MemoryBreakdown(params, grads, opt, ef, 0.0, activations)


def fits_hbm(run: RunConfig, hbm_bytes=16e9, **kw) -> tuple:
    mb = per_device_memory(run, **kw)
    return mb.total <= hbm_bytes, mb
