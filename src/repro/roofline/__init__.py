from repro.roofline.analysis import (
    HW_V5E, roofline_terms, model_flops, analyze_record, format_table,
)
