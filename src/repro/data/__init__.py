from repro.data.synthetic import (
    lm_batch, lm_batch_specs, linreg_dataset, image_dataset,
)
