"""Deterministic synthetic data pipelines.

Everything is generated from fold_in(seed, step, rank) PRNG streams — fully
deterministic, shardable, no host I/O. Three generators:

- ``lm_batch``: token batches (+ modality stubs) for the LM architectures;
- ``linreg_dataset``: the paper §4.1 Gaussian linear-model datasets
  (per-worker ground truth t_n ~ N(u_n, h^2), u_n ~ N(U, sigma^2));
- ``image_dataset``: synthetic 10-class image set standing in for CIFAR-10
  in the §4.2 analogue experiment (class-conditional Gaussian means over
  32x32x3, fixed across steps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM batches
# ---------------------------------------------------------------------------

def lm_batch(cfg, batch: int, seq: int, seed: int, step) -> dict:
    """One deterministic LM batch for model config cfg (local shapes)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kt, kp = jax.random.split(key)
    tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    targets = jnp.concatenate([tokens[:, 1:],
                               jnp.full((batch, 1), -1, jnp.int32)], 1)
    out = {"tokens": tokens, "targets": targets}
    if cfg.frontend == "vision_stub":
        out["patches"] = jax.random.normal(
            kp, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        # patch positions carry no LM loss
        mask = jnp.arange(seq)[None, :] < cfg.n_frontend_tokens
        out["targets"] = jnp.where(mask, -1, targets)
    elif cfg.frontend == "audio_stub":
        out["frames"] = jax.random.normal(
            kp, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return out


def lm_batch_specs(cfg, batch: int, seq: int, dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins matching lm_batch (dry-run input_specs)."""
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio_stub":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Paper §4.1 linear regression
# ---------------------------------------------------------------------------

def linreg_dataset(n_workers=20, n_points=500, dim=100, U=0.0, sigma2=5.0,
                   h2=1.0, noise=0.5, seed=0):
    """Per-worker (X, y) plus the global LS optimum w*."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for n in range(n_workers):
        u_n = rng.normal(U, np.sqrt(sigma2))
        t_n = rng.normal(u_n, np.sqrt(h2), size=(dim,))
        X = rng.normal(0.0, 1.0, size=(n_points, dim))
        eps = rng.normal(0.0, np.sqrt(noise), size=(n_points,))
        y = X @ t_n + eps
        xs.append(X)
        ys.append(y)
    # global LS optimum of (1/N) sum_n ||X_n w - y_n||^2 / (2 D_n)
    A = sum(x.T @ x for x in xs)
    b = sum(x.T @ y for x, y in zip(xs, ys))
    w_star = np.linalg.solve(A, b)
    return ([jnp.asarray(x) for x in xs], [jnp.asarray(y) for y in ys],
            jnp.asarray(w_star))


# ---------------------------------------------------------------------------
# §4.2 analogue: synthetic 10-class images
# ---------------------------------------------------------------------------

def image_dataset(n_train=2000, n_test=500, n_classes=10, hw=16, seed=0):
    """Class-conditional Gaussian images (B, hw, hw, 3) + labels."""
    rng = np.random.default_rng(seed)
    means = rng.normal(0.0, 1.0, size=(n_classes, hw, hw, 3)).astype(np.float32)

    def make(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, n_classes, size=(n,))
        x = means[y] + r.normal(0.0, 1.5, size=(n, hw, hw, 3)).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(y)

    xtr, ytr = make(n_train, seed + 1)
    xte, yte = make(n_test, seed + 2)
    return xtr, ytr, xte, yte
