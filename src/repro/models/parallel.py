"""Parallelism context threaded through model code.

All model code takes a :class:`Parallel` describing the mesh axes it runs
under (inside ``shard_map``). With ``model_axis=None`` the collectives are
no-ops and the code is single-device — tests and the paper-experiment
drivers use that path; the dry-run and launcher use named axes.

TP conventions (DESIGN.md §2.1):
- MLP/MoE: column-parallel up, row-parallel down (+psum or psum_scatter).
- Attention: head sharding with PADDING to the model-axis size (assigned
  archs have head counts not divisible by 16 — padded q/kv heads have
  zero-init projections, so semantics are unchanged; the waste shows up in
  the roofline MODEL_FLOPS ratio and is attacked in §Perf).
- SSM (mamba/xlstm): channel-parallel over d_inner / head_dim rows.
- Sequence parallel: residual stream sharded (batch/data, seq/model, d).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Parallel:
    model_axis: Optional[str] = None   # TP axis name (None = single device)
    data_axes: tuple = ()              # DP axis name(s), e.g. ("pod", "data")
    tp: int = 1                        # static size of model axis
    seq_parallel: bool = False         # residual stream seq-sharded over model
    cache_seq_axis: Optional[object] = None  # decode cache seq-shard axis (str|tuple)
    attn_dist: str = "sp"              # "sp" (Megatron-SP) | "ring" (context parallel)
    remat: bool = True

    @property
    def tp_on(self) -> bool:
        return self.model_axis is not None and self.tp > 1


def pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def heads_padded(n_heads: int, pal: Parallel) -> int:
    return pad_to(n_heads, pal.tp) if pal.tp_on else n_heads


def psum_model(x, pal: Parallel):
    return jax.lax.psum(x, pal.model_axis) if pal.tp_on else x


def psum_scatter_model(x, pal: Parallel, axis: int):
    """Row-parallel output reduction in sequence-parallel mode."""
    if not pal.tp_on:
        return x
    return jax.lax.psum_scatter(x, pal.model_axis, scatter_dimension=axis,
                                tiled=True)


def all_gather_model(x, pal: Parallel, axis: int):
    if not pal.tp_on:
        return x
    return jax.lax.all_gather(x, pal.model_axis, axis=axis, tiled=True)


def axis_index(pal: Parallel):
    return jax.lax.axis_index(pal.model_axis) if pal.tp_on else jnp.zeros((), jnp.int32)


def ppermute_model(x, pal: Parallel, shift: int = 1):
    n = pal.tp
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, pal.model_axis, perm)


def shard_slice(n: int, pal: Parallel) -> int:
    """Static per-rank length of a dimension of size n sharded over model."""
    if not pal.tp_on:
        return n
    assert n % pal.tp == 0, f"{n} not divisible by tp={pal.tp}"
    return n // pal.tp
