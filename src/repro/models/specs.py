"""PartitionSpec trees for the parameter pytree.

Specs are derived from the params structure by (parent-module, leaf-name)
rules that mirror the sharding conventions in each module's init. Used for:

- shard_map in_specs/out_specs of params in train/serve steps,
- identifying REPLICATED leaves whose gradients need a psum over the model
  axis (Megatron-SP layernorm-grad rule, DESIGN.md §2.1),
- dry-run in_shardings.

Scanned super-block stacks have a leading (n_superblocks,) dim -> specs get
a leading None.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# (parent module key, leaf key) -> dim index sharded over the model axis,
# or None for replicated. "*" matches any parent.
_COL = 1          # output-dim sharded (column parallel)
_ROW = 0          # input-dim sharded (row parallel)
_VEC = 0          # 1-D sharded vector
_REP = None

_RULES = {
    # attention (GQA + MLA)
    ("attn", "wq"): _COL, ("attn", "wk"): _COL, ("attn", "wv"): _COL,
    ("attn", "wo"): _ROW,
    ("attn", "bq"): _VEC, ("attn", "bk"): _VEC, ("attn", "bv"): _VEC,
    ("attn", "dkv"): _REP, ("attn", "kv_norm"): _REP,
    ("attn", "uk"): _COL, ("attn", "uv"): _COL,
    ("cross", "wq"): _COL, ("cross", "wk"): _COL, ("cross", "wv"): _COL,
    ("cross", "wo"): _ROW,
    ("cross", "bq"): _VEC, ("cross", "bk"): _VEC, ("cross", "bv"): _VEC,
    # dense MLP
    ("mlp", "gate"): _COL, ("mlp", "up"): _COL, ("mlp", "down"): _ROW,
    ("mlp", "up_b"): _VEC, ("mlp", "down_b"): _REP,
    ("shared", "gate"): _COL, ("shared", "up"): _COL, ("shared", "down"): _ROW,
    ("shared", "up_b"): _VEC, ("shared", "down_b"): _REP,
    # MoE (expert dim sharded)
    ("moe", "router"): _REP,
    ("moe", "gate"): 0, ("moe", "up"): 0, ("moe", "down"): 0,
    # mamba (channel parallel)
    ("mamba", "conv_w"): 1, ("mamba", "conv_b"): _VEC,
    ("mamba", "x_proj"): _ROW, ("mamba", "dt_proj"): _COL,
    ("mamba", "dt_bias"): _VEC, ("mamba", "A_log"): 0, ("mamba", "D"): _VEC,
    ("mamba", "out_proj"): _ROW,
    # mLSTM (value-dim sharded on its own axis; q/k/up replicated)
    ("mlstm", "up"): _REP, ("mlstm", "up_gate"): 2,
    ("mlstm", "wq"): _REP, ("mlstm", "wk"): _REP, ("mlstm", "wv"): 2,
    ("mlstm", "wif"): _REP, ("mlstm", "ln_h"): 1, ("mlstm", "down"): 1,
    # sLSTM (split gate projections, col-parallel)
    ("slstm", "wi"): _COL, ("slstm", "wf"): _COL, ("slstm", "wz"): _COL,
    ("slstm", "wo"): _COL,
    ("slstm", "ln_h"): _VEC, ("slstm", "down"): _ROW,
    ("mamba", "in_x"): _COL, ("mamba", "in_z"): _COL,
    # embedding / head (vocab sharded)
    ("embed", "tok"): 0, ("embed", "head"): _COL,
}

_NORM_KEYS = {"scale", "bias"}  # all norms replicated
_NORM_PARENTS = {"norm", "norm1", "norm2", "norm_x", "final_norm", "kv_norm"}


def _leaf_spec(path, leaf, model_axis: str):
    keys = [k.key for k in path if hasattr(k, "key")]
    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    # norms anywhere -> replicated
    if parent in _NORM_PARENTS or (name in _NORM_KEYS):
        dim = _REP
    elif (parent, name) in _RULES:
        dim = _RULES[(parent, name)]
    elif name == "kv_norm":
        dim = _REP
    else:
        raise KeyError(f"no sharding rule for param {'/'.join(keys)}")
    ndim = leaf.ndim
    # scanned stacks ('blocks' in path) have a leading stack dim
    stacked = "blocks" in keys
    if dim is None:
        return P()
    d = dim + (1 if stacked else 0)
    if d >= ndim:  # 1-D vec under stack
        d = ndim - 1
    spec = [None] * ndim
    spec[d] = model_axis
    return P(*spec)


def param_specs(params, model_axis: str = "model"):
    """PartitionSpec tree matching the params pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, model_axis), params)


def replicated_mask(params):
    """Boolean tree: True for leaves replicated over the model axis (their
    grads need a psum over model)."""
    specs = param_specs(params)
    return jax.tree_util.tree_map(lambda s: all(a is None for a in s), specs)
