"""Attention: GQA (+RoPE, bias), MLA (DeepSeek-V2), sliding-window; full-seq
(train/prefill) and cached decode paths.

Distribution modes (pal.attn_dist):

- ``sp`` (default, Megatron-SP): attention weights head-sharded over the
  model axis (head counts PADDED to multiples of tp — assigned archs are not
  divisible; padded heads have zero-init out-projections so semantics are
  unchanged). In sequence-parallel mode the residual stream arrives
  seq-sharded; we all-gather seq, run chunked (flash-style, online-softmax)
  attention on the rank's local heads over the full sequence, and
  psum_scatter the output back to seq shards.

- ``ring``: context-parallel ring attention. Attention weights are
  REPLICATED over the model axis (each rank computes all heads for its seq
  block); K/V blocks rotate via ppermute. For MLA the ring payload is the
  COMPRESSED (ckv, krope) stream — kv_lora+rope dims instead of 2*H*hd per
  token (beyond-paper optimization, cheap to replicate thanks to MLA's
  low-rank projections).

Decode: KV cache is head-sharded over model (sp) and optionally
sequence-sharded over ``pal.cache_seq_axis`` (context-parallel decode for
batch < data-axis size, e.g. long_500k) with flash LSE-merge psums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.parallel import (
    Parallel, all_gather_model, axis_index, heads_padded,
    ppermute_model, psum_model, psum_scatter_model, shard_slice,
)

NEG_INF = -1e30
Q_CHUNK = 1024
KV_CHUNK = 2048


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def apply_rope(x, pos, rot_dim: int, theta: float):
    """x: (B, S, H, hd); pos: (S,) int32. Rotates the first rot_dim dims."""
    if rot_dim == 0:
        return x
    freqs = theta ** (-jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    angle = pos[:, None].astype(jnp.float32) * freqs         # (S, rot/2)
    cos = jnp.cos(angle)[None, :, None, :]
    sin = jnp.sin(angle)[None, :, None, :]
    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    rot = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    rot = rot.reshape(xr.shape)
    return jnp.concatenate([rot.astype(x.dtype), x[..., rot_dim:]], -1)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _head_counts(cfg, pal: Parallel):
    """(local_q_heads, local_kv_heads) under the current distribution."""
    if getattr(pal, "attn_dist", "sp") == "ring":
        return cfg.n_heads, cfg.n_kv_heads       # replicated
    hp = heads_padded(cfg.n_heads, pal)
    kvp = heads_padded(cfg.n_kv_heads, pal)
    assert hp % kvp == 0, (hp, kvp)
    return shard_slice(hp, pal), shard_slice(kvp, pal)


def init_attention(key, cfg, pal: Parallel, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    hl, kvl = _head_counts(cfg, pal)
    if cfg.attn_kind == "mla" and not cross:
        vhd = cfg.v_head_dim or hd
        return {
            "dkv": dense_init(ks[0], d, cfg.kv_lora_rank + cfg.rope_head_dim),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
            "uk": dense_init(ks[1], cfg.kv_lora_rank, hl * hd),
            "uv": dense_init(ks[2], cfg.kv_lora_rank, hl * vhd),
            "wq": dense_init(ks[3], d, hl * (hd + cfg.rope_head_dim)),
            "wo": dense_init(ks[4], hl * vhd, d),
        }
    p = {
        "wq": dense_init(ks[0], d, hl * hd),
        "wk": dense_init(ks[1], d, kvl * hd),
        "wv": dense_init(ks[2], d, kvl * hd),
        "wo": dense_init(ks[3], hl * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hl * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kvl * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kvl * hd,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def _proj_qkv(p, x, cfg, pos):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, s, -1, hd)
    k = k.reshape(b, s, -1, hd)
    v = v.reshape(b, s, -1, hd)
    if cfg.rope:
        rot = int(hd * cfg.rotary_pct)
        q = apply_rope(q, pos, rot, cfg.rope_theta)
        k = apply_rope(k, pos, rot, cfg.rope_theta)
    return q, k, v


def _proj_mla(p, x, cfg, pos):
    from repro.models.layers import norm_fwd
    b, s, _ = x.shape
    hd, rhd = cfg.resolved_head_dim, cfg.rope_head_dim
    dkv = x @ p["dkv"].astype(x.dtype)
    ckv, krope = dkv[..., :cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    ckv = norm_fwd({"scale": p["kv_norm"]}, ckv, "rmsnorm")
    krope = apply_rope(krope[:, :, None, :], pos, rhd, cfg.rope_theta)[:, :, 0]
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, -1, hd + rhd)
    qn, qr = q[..., :hd], q[..., hd:]
    qr = apply_rope(qr, pos, rhd, cfg.rope_theta)
    return jnp.concatenate([qn, qr], -1), ckv, krope


def _mla_expand(p, ckv, krope, n_heads, cfg, dtype):
    b, s, _ = ckv.shape
    hd, rhd = cfg.resolved_head_dim, cfg.rope_head_dim
    vhd = cfg.v_head_dim or hd
    k_nope = (ckv @ p["uk"].astype(dtype)).reshape(b, s, n_heads, hd)
    v = (ckv @ p["uv"].astype(dtype)).reshape(b, s, n_heads, vhd)
    k_rope = jnp.broadcast_to(krope[:, :, None, :], (b, s, n_heads, rhd)).astype(dtype)
    return jnp.concatenate([k_nope, k_rope], -1), v


# ---------------------------------------------------------------------------
# SDPA primitives (fp32 softmax)
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,*); GQA broadcast; mask (Sq,Sk) bool."""
    b, sq = q.shape[0], q.shape[1]
    g = q.shape[2] // k.shape[2]
    qg = q.reshape(b, sq, k.shape[2], g, q.shape[3])
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return o.reshape(b, sq, -1, v.shape[3])


def _sdpa_partial(q, k, v, mask, scale):
    """Partial softmax block: returns (o_unnormalized, m, l); m,l (B,Sq,H)."""
    b, sq = q.shape[0], q.shape[1]
    g = q.shape[2] // k.shape[2]
    qg = q.reshape(b, sq, k.shape[2], g, q.shape[3])
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, -1)
    w = jnp.exp(s - m[..., None])
    l = jnp.sum(w, -1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w.astype(v.dtype), v)
    h = k.shape[2] * g
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, v.shape[3])
    m = m.transpose(0, 3, 1, 2).reshape(b, sq, h)
    l = l.transpose(0, 3, 1, 2).reshape(b, sq, h)
    return o, m, l


def mask_padded_heads(o, cfg, pal: Parallel):
    """Zero attention outputs of PADDED heads (head counts are rounded up to
    tp multiples — DESIGN.md §2.1) so padding is semantically neutral.
    o: (B, S, Hl, hd). Runs only under shard_map (uses axis_index)."""
    if getattr(pal, "attn_dist", "sp") == "ring" or not pal.tp_on:
        return o
    hl = o.shape[2]
    if hl * pal.tp <= cfg.n_heads:
        return o
    gh = axis_index(pal) * hl + jnp.arange(hl)
    return o * (gh < cfg.n_heads)[None, None, :, None].astype(o.dtype)


def _merge_two(o1, m1, l1, o2, m2, l2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    o = o1 * a1[..., None].astype(o1.dtype) + o2 * a2[..., None].astype(o2.dtype)
    return o, m, l1 * a1 + l2 * a2


def _finalize(o, l):
    return o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)


def _sdpa_chunked(q, k, v, q_pos, k_pos, scale, causal=True, window=0):
    """Flash-style chunked attention: scan over q chunks, inner scan over kv
    chunks with online softmax. Never materializes (Sq, Sk) scores.
    q_pos (Sq,), k_pos (Sk,) are global positions for masking."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    vhd = v.shape[3]
    qc = min(Q_CHUNK, sq)
    kc = min(KV_CHUNK, sk)
    nq, nk = sq // qc, sk // kc
    if sq % qc or sk % kc:                        # ragged: fall back
        mask = _mask_from_pos(q_pos, k_pos, causal, window)
        return _sdpa(q, k, v, mask, scale)

    qs = q.reshape(b, nq, qc, h, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(nq, qc)

    def per_q(qi, qpi):
        o0 = jnp.zeros((b, qc, h, vhd), v.dtype)
        m0 = jnp.full((b, qc, h), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qc, h), jnp.float32)

        def body(carry, inp):
            o, m, l = carry
            kb, vb, kpb = inp
            mask = _mask_from_pos(qpi, kpb, causal, window)
            ob, mb, lb = _sdpa_partial(qi, kb, vb, mask, scale)
            return _merge_two(o, m, l, ob, mb, lb), None

        ks_ = k.reshape(b, nk, kc, k.shape[2], hd).transpose(1, 0, 2, 3, 4)
        vs_ = v.reshape(b, nk, kc, v.shape[2], vhd).transpose(1, 0, 2, 3, 4)
        kps = k_pos.reshape(nk, kc)
        (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), (ks_, vs_, kps))
        return _finalize(o, l)

    outs = jax.lax.map(lambda t: per_q(t[0], t[1]), (qs, qp))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, vhd)


def _mask_from_pos(q_pos, k_pos, causal, window):
    if causal:
        m = q_pos[:, None] >= k_pos[None, :]
        if window:
            m &= q_pos[:, None] - k_pos[None, :] < window
        return m
    return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)



# ---------------------------------------------------------------------------
# MLA absorbed attention (cfg.mla_absorb): scores and context are computed in
# the COMPRESSED kv_lora space — q_nope is projected through W_uk once
# (per query), attention weights contract against c_kv directly, and the
# per-head value expansion W_uv is applied to the CONTEXT instead of every
# key. Never materializes (S, H, hd) K/V — the HBM win the §Perf iteration
# for deepseek-v2 targets. Exactly equivalent to the expanded path.
# ---------------------------------------------------------------------------

def _absorb_q(p, q, cfg):
    """q (B,S,H,hd+rhd) -> (q_lora (B,S,H,lora), q_rope (B,S,H,rhd))."""
    hd = cfg.resolved_head_dim
    qn, qr = q[..., :hd], q[..., hd:]
    b, s, h, _ = qn.shape
    uk = p["uk"].astype(qn.dtype).reshape(cfg.kv_lora_rank, h, hd)
    ql = jnp.einsum("bshd,lhd->bshl", qn, uk)
    return ql, qr


def _sdpa_absorbed_chunked(p, q, ckv, krope, cfg, scale, q_pos, k_pos,
                           causal=True, window=0):
    """Chunked absorbed MLA attention. Returns (B,Sq,H,vhd)."""
    ql, qr = _absorb_q(p, q, cfg)
    b, sq, h, lora = ql.shape
    sk = ckv.shape[1]
    vhd = cfg.v_head_dim or cfg.resolved_head_dim
    uv = p["uv"].astype(ckv.dtype).reshape(lora, h, vhd)
    kc = min(KV_CHUNK, sk)
    if sk % kc:
        kc = sk
    nk = sk // kc

    o0 = jnp.zeros((b, sq, h, lora), ckv.dtype)
    m0 = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)

    def body(carry, inp):
        o, m, l = carry
        cb, rb, kpb = inp                                 # (B,kc,lora)...
        s_ = (jnp.einsum("bqhl,bsl->bhqs", ql, cb) +
              jnp.einsum("bqhr,bsr->bhqs", qr, rb)).astype(jnp.float32) * scale
        mask = _mask_from_pos(q_pos, kpb, causal, window)
        s_ = jnp.where(mask[None, None], s_, NEG_INF)
        mb = jnp.max(s_, -1)                              # (B,H,Sq)
        w = jnp.exp(s_ - mb[..., None])
        lb = jnp.sum(w, -1)
        ob = jnp.einsum("bhqs,bsl->bqhl", w.astype(cb.dtype), cb)
        mb = mb.transpose(0, 2, 1)
        lb = lb.transpose(0, 2, 1)
        mn = jnp.maximum(m, mb)
        a1 = jnp.exp(m - mn)
        a2 = jnp.exp(mb - mn)
        o = o * a1[..., None].astype(o.dtype) + ob * a2[..., None].astype(o.dtype)
        return (o, mn, l * a1 + lb * a2), None

    cs = ckv.reshape(b, nk, kc, lora).transpose(1, 0, 2, 3)
    rs = krope.reshape(b, nk, kc, -1).transpose(1, 0, 2, 3)
    kps = k_pos.reshape(nk, kc)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), (cs, rs, kps))
    ctx = _finalize(o, l)                                 # (B,Sq,H,lora)
    return jnp.einsum("bqhl,lhv->bqhv", ctx, uv)


def _decode_attend_absorbed(p, q, cache, pos, cfg, pal: Parallel, scale):
    """Absorbed MLA decode over the compressed cache (LSE-merge aware)."""
    ckv, krope = cache["ckv"], cache["krope"]
    ql, qr = _absorb_q(p, q, cfg)                        # (B,1,H,lora)
    b, _, h, lora = ql.shape
    sl = ckv.shape[1]
    vhd = cfg.v_head_dim or cfg.resolved_head_dim
    uv = p["uv"].astype(ckv.dtype).reshape(lora, h, vhd)
    s_ = (jnp.einsum("bqhl,bsl->bhqs", ql, ckv) +
          jnp.einsum("bqhr,bsr->bhqs", qr, krope)).astype(jnp.float32) * scale
    if pal.cache_seq_axis is None:
        valid = jnp.arange(sl) <= pos
        s_ = jnp.where(valid[None, None, None], s_, NEG_INF)
        w = jax.nn.softmax(s_, -1)
        ctx = jnp.einsum("bhqs,bsl->bqhl", w.astype(ckv.dtype), ckv)
        return jnp.einsum("bqhl,lhv->bqhv", ctx, uv)
    r = jax.lax.axis_index(pal.cache_seq_axis)
    gpos = r * sl + jnp.arange(sl)
    s_ = jnp.where((gpos <= pos)[None, None, None], s_, NEG_INF)
    m = jnp.max(s_, -1)
    w = jnp.exp(s_ - m[..., None])
    l = jnp.sum(w, -1)
    ctx = jnp.einsum("bhqs,bsl->bqhl", w.astype(ckv.dtype), ckv)
    mg = jax.lax.pmax(m, pal.cache_seq_axis)
    a = jnp.exp(m - mg)
    ctx = jax.lax.psum(ctx * a.transpose(0, 2, 1)[..., None].astype(ctx.dtype),
                       pal.cache_seq_axis)
    l = jax.lax.psum(l * a, pal.cache_seq_axis).transpose(0, 2, 1)
    ctx = ctx / jnp.maximum(l, 1e-30)[..., None].astype(ctx.dtype)
    return jnp.einsum("bqhl,lhv->bqhv", ctx, uv)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill-as-part-of-train)
# ---------------------------------------------------------------------------

def attn_fwd_full(p, x, cfg, pal: Parallel, *, causal=True, pos0=0,
                  window=0, cross_kv=None):
    """x: (B, S/tp, d) if pal.seq_parallel else (B, S, d). Returns same
    sharding as input."""
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    if cfg.attn_kind == "sliding" and window == 0:
        window = cfg.window
    ring = getattr(pal, "attn_dist", "sp") == "ring" and pal.tp_on

    if cross_kv is not None:
        # cross-attention (whisper decoder): kv precomputed from encoder.
        if pal.seq_parallel:
            x = all_gather_model(x, pal, axis=1)
        b, s, _ = x.shape
        q = x @ p["wq"].astype(x.dtype)
        if cfg.qkv_bias:
            q = q + p["bq"].astype(q.dtype)
        q = q.reshape(b, s, -1, hd)
        k, v = cross_kv
        o = _sdpa_chunked(q, k, v, jnp.arange(s), jnp.arange(k.shape[1]),
                          scale, causal=False)
        o = mask_padded_heads(o, cfg, pal)
        y = o.reshape(b, s, -1) @ p["wo"].astype(o.dtype)
        if pal.seq_parallel:
            return psum_scatter_model(y, pal, axis=1)
        return psum_model(y, pal)

    if ring:
        return _ring_fwd(p, x, cfg, pal, scale, causal, window)

    if pal.seq_parallel:
        x = all_gather_model(x, pal, axis=1)
    b, s, _ = x.shape
    pos = pos0 + jnp.arange(s)
    if cfg.attn_kind == "mla":
        q, ckv, krope = _proj_mla(p, x, cfg, pos)
        if cfg.mla_absorb:
            o = _sdpa_absorbed_chunked(p, q, ckv, krope, cfg, scale, pos,
                                       pos, causal, window)
        else:
            k, v = _mla_expand(p, ckv, krope, q.shape[2], cfg, x.dtype)
            o = _sdpa_chunked(q, k, v, pos, pos, scale, causal, window)
    else:
        q, k, v = _proj_qkv(p, x, cfg, pos)
        o = _sdpa_chunked(q, k, v, pos, pos, scale, causal, window)
    o = mask_padded_heads(o, cfg, pal)
    y = o.reshape(b, s, -1) @ p["wo"].astype(o.dtype)
    if pal.seq_parallel:
        return psum_scatter_model(y, pal, axis=1)
    return psum_model(y, pal)


def _ring_fwd(p, x, cfg, pal: Parallel, scale, causal, window):
    """Context-parallel ring attention; x (B, Sl, d) seq-sharded; attention
    weights replicated (all heads computed per rank)."""
    b, sl, _ = x.shape
    tp = pal.tp
    r = axis_index(pal)
    pos = r * sl + jnp.arange(sl)
    mla = cfg.attn_kind == "mla"
    if mla:
        q, ckv, krope = _proj_mla(p, x, cfg, pos)
        kv_payload = (ckv, krope)
    else:
        q, k, v = _proj_qkv(p, x, cfg, pos)
        kv_payload = (k, v)
    h = q.shape[2]
    vhd = (cfg.v_head_dim or cfg.resolved_head_dim) if mla else kv_payload[1].shape[3]
    o0 = jnp.zeros((b, sl, h, vhd), x.dtype)
    m0 = jnp.full((b, sl, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sl, h), jnp.float32)

    def body(i, carry):
        o, m, l, payload = carry
        src = (r - i) % tp
        k_pos = src * sl + jnp.arange(sl)
        if mla:
            kb, vb = _mla_expand(p, payload[0], payload[1], h, cfg, x.dtype)
        else:
            kb, vb = payload
        mask = _mask_from_pos(pos, k_pos, causal, window)
        ob, mb, lb = _sdpa_partial(q, kb, vb, mask, scale)
        o, m, l = _merge_two(o, m, l, ob, mb, lb)
        payload = tuple(ppermute_model(t, pal, 1) for t in payload)
        return (o, m, l, payload)

    o, m, l, _ = jax.lax.fori_loop(0, tp, body, (o0, m0, l0, kv_payload))
    o = _finalize(o, l)
    o = mask_padded_heads(o, cfg, pal)
    y = o.reshape(b, sl, -1) @ p["wo"].astype(o.dtype)
    return y                                       # stays seq-sharded; no psum


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg, pal: Parallel, batch: int, max_seq: int, dtype):
    """Per-layer cache. If pal.cache_seq_axis is set the seq dim here is the
    PER-RANK slice (caller divides max_seq by the axis size)."""
    hd = cfg.resolved_head_dim
    if cfg.attn_kind == "mla":
        return {
            "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
        }
    _, kvl = _head_counts(cfg, pal)
    return {
        "k": jnp.zeros((batch, max_seq, kvl, hd), dtype),
        "v": jnp.zeros((batch, max_seq, kvl, hd), dtype),
    }


def cache_max_seq(cfg, seq_len: int) -> int:
    """Global cache length for a given context length."""
    if cfg.attn_kind == "sliding":
        return min(seq_len, cfg.window)
    return seq_len


def _cache_write(arr, new, slot, pal: Parallel):
    """Write new (B,1,...) at global slot index; seq dim possibly sharded
    over pal.cache_seq_axis."""
    if pal.cache_seq_axis is None:
        return jax.lax.dynamic_update_slice_in_dim(arr, new.astype(arr.dtype), slot, 1)
    sl = arr.shape[1]
    r = jax.lax.axis_index(pal.cache_seq_axis)
    local = slot - r * sl
    inb = (local >= 0) & (local < sl)
    upd = jax.lax.dynamic_update_slice_in_dim(
        arr, new.astype(arr.dtype), jnp.clip(local, 0, sl - 1), 1)
    return jnp.where(inb, upd, arr)


def attn_decode(p, x, cache, pos, cfg, pal: Parallel, cross_kv=None):
    """x (B,1,d), pos scalar int32 -> (y (B,1,d), cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    sliding = cfg.attn_kind == "sliding"

    if cross_kv is not None:
        q = x @ p["wq"].astype(x.dtype)
        if cfg.qkv_bias:
            q = q + p["bq"].astype(q.dtype)
        q = q.reshape(b, 1, -1, hd)
        k, v = cross_kv
        o, m, l = _sdpa_partial(q, k, v, jnp.ones((1, k.shape[1]), bool), scale)
        o = _finalize(o, l)
        o = mask_padded_heads(o, cfg, pal)
        y = o.reshape(b, 1, -1) @ p["wo"].astype(o.dtype)
        return psum_model(y, pal), cache

    posv = jnp.full((1,), pos, jnp.int32)
    if cfg.attn_kind == "mla":
        q, ckv, krope = _proj_mla(p, x, cfg, posv)
        cache = {"ckv": _cache_write(cache["ckv"], ckv, pos, pal),
                 "krope": _cache_write(cache["krope"], krope, pos, pal)}
        if cfg.mla_absorb:
            o = _decode_attend_absorbed(p, q, cache, pos, cfg, pal, scale)
        else:
            k, v = _mla_expand(p, cache["ckv"], cache["krope"], q.shape[2],
                               cfg, x.dtype)
            o = _decode_attend(q, k, v, pos, pal, scale, False, 0)
        o = mask_padded_heads(o, cfg, pal)
        y = o.reshape(b, 1, -1) @ p["wo"].astype(o.dtype)
        return psum_model(y, pal), cache

    q, k_new, v_new = _proj_qkv(p, x, cfg, posv)
    if sliding:
        w_total = cfg.window
        slot = pos % jnp.int32(min(w_total, _global_cache_len(cache, pal)))
    else:
        slot = pos
    cache = {"k": _cache_write(cache["k"], k_new, slot, pal),
             "v": _cache_write(cache["v"], v_new, slot, pal)}
    o = _decode_attend(q, cache["k"], cache["v"], pos, pal, scale,
                       sliding, cfg.window)
    o = mask_padded_heads(o, cfg, pal)
    y = o.reshape(b, 1, -1) @ p["wo"].astype(o.dtype)
    return psum_model(y, pal), cache


def _global_cache_len(cache, pal: Parallel) -> int:
    n = cache["k"].shape[1]
    if pal.cache_seq_axis is not None:
        # static per-rank slice; global = slice * axis size (set by caller via
        # pal metadata; we recover it statically from the mesh at trace time)
        import jax.core
        n = n * jax.lax.axis_size(pal.cache_seq_axis)
    return n


def _decode_attend(q, k, v, pos, pal: Parallel, scale, sliding, window):
    sl = k.shape[1]
    if pal.cache_seq_axis is None:
        gpos = jnp.arange(sl)
        if sliding:
            cap = sl                       # ring buffer of length min(window, S)
            slot_pos = pos - ((pos - gpos) % cap)
            valid = (slot_pos >= 0) & (slot_pos <= pos) & (pos - slot_pos < window)
        else:
            valid = gpos <= pos
        o, m, l = _sdpa_partial(q, k, v, valid[None, :], scale)
        return _finalize(o, l)
    r = jax.lax.axis_index(pal.cache_seq_axis)
    nax = jax.lax.axis_size(pal.cache_seq_axis)
    gpos = r * sl + jnp.arange(sl)
    if sliding:
        cap = sl * nax
        slot_pos = pos - ((pos - gpos) % cap)
        valid = (slot_pos >= 0) & (slot_pos <= pos) & (pos - slot_pos < window)
    else:
        valid = gpos <= pos
    o, m, l = _sdpa_partial(q, k, v, valid[None, :], scale)
    mg = jax.lax.pmax(m, pal.cache_seq_axis)
    a = jnp.exp(m - mg)
    o = jax.lax.psum(o * a[..., None].astype(o.dtype), pal.cache_seq_axis)
    l = jax.lax.psum(l * a, pal.cache_seq_axis)
    return _finalize(o, l)


# ---------------------------------------------------------------------------
# Prefill that returns a cache (serving)
# ---------------------------------------------------------------------------

def attn_prefill(p, x, cfg, pal: Parallel, *, max_seq=None):
    """Prompt forward + cache build. x (B, S, d) full (serving prefill is
    batch-sharded over data, seq unsharded). Cache seq dim is NOT sharded
    here (prefill shapes have batch >= data size)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    window = cfg.window if cfg.attn_kind == "sliding" else 0
    max_seq = max_seq or s
    pos = jnp.arange(s)
    if cfg.attn_kind == "mla":
        q, ckv, krope = _proj_mla(p, x, cfg, pos)
        if cfg.mla_absorb:
            o = _sdpa_absorbed_chunked(p, q, ckv, krope, cfg, scale, pos,
                                       pos, True, window)
        else:
            k, v = _mla_expand(p, ckv, krope, q.shape[2], cfg, x.dtype)
            o = _sdpa_chunked(q, k, v, pos, pos, scale, True, window)
        cache = init_cache(cfg, pal, b, max_seq, x.dtype)
        cache["ckv"] = _prefix_write(cache["ckv"], ckv)
        cache["krope"] = _prefix_write(cache["krope"], krope)
    else:
        q, k, v = _proj_qkv(p, x, cfg, pos)
        o = _sdpa_chunked(q, k, v, pos, pos, scale, True, window)
        cache = init_cache(cfg, pal, b,
                           min(max_seq, cfg.window) if window else max_seq,
                           x.dtype)
        cw = cache["k"].shape[1]
        if window and s > cw:
            # keep the last cw positions at slots (position % cw)
            sel = jnp.arange(s - cw, s)
            cache["k"] = cache["k"].at[:, sel % cw].set(
                k[:, sel].astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, sel % cw].set(
                v[:, sel].astype(cache["v"].dtype))
        else:
            cache["k"] = _prefix_write(cache["k"], k)
            cache["v"] = _prefix_write(cache["v"], v)
    o = mask_padded_heads(o, cfg, pal)
    y = o.reshape(b, s, -1) @ p["wo"].astype(o.dtype)
    return psum_model(y, pal), cache


def _prefix_write(arr, new):
    return jax.lax.dynamic_update_slice_in_dim(arr, new.astype(arr.dtype), 0, 1)


# cross-attention K/V for whisper (computed once from encoder output)
def init_cross_kv(p, enc_out, cfg, pal: Parallel):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = enc_out @ p["wk"].astype(enc_out.dtype)
    v = enc_out @ p["wv"].astype(enc_out.dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return k.reshape(b, s, -1, hd), v.reshape(b, s, -1, hd)
