"""Shared layers: norms, MLPs, embeddings — pure functional JAX.

Parameter convention: nested dicts of jnp arrays. Every ``init_*`` returns a
dict; the matching ``*_fwd`` applies it. TP sharding follows DESIGN.md §2.1:
MLP up-projections are column-parallel (output dim sharded), down-projections
row-parallel (input dim sharded, psum / psum_scatter after).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.parallel import (
    Parallel, all_gather_model, psum_model, psum_scatter_model, shard_slice,
)


def dense_init(key, d_in, d_out, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_fwd(p, x, kind="rmsnorm", eps=1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        y = y * p["scale"]
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU for act=silu, plain 2-layer for act=gelu)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, pal: Parallel, d_ff=None):
    d, dff = cfg.d_model, (d_ff or cfg.d_ff)
    dffl = shard_slice(dff, pal)                  # column-parallel shard
    ks = jax.random.split(key, 3)
    p = {"down": dense_init(ks[2], dffl, d)}
    if cfg.act == "silu":
        p["gate"] = dense_init(ks[0], d, dffl)
        p["up"] = dense_init(ks[1], d, dffl)
    else:
        p["up"] = dense_init(ks[1], d, dffl)
        p["up_b"] = jnp.zeros((dffl,), jnp.float32)
        p["down_b"] = jnp.zeros((d,), jnp.float32)
    return p


def mlp_fwd(p, x, cfg, pal: Parallel):
    """x: (..., S?, d). In seq-parallel mode x is seq-sharded; we all-gather
    seq before the column-parallel matmul and psum_scatter after the
    row-parallel one (Megatron-SP schedule)."""
    seq_ax = x.ndim - 2
    if pal.seq_parallel:
        x = all_gather_model(x, pal, axis=seq_ax)
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["gate"].astype(x.dtype)) * (x @ p["up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["up"].astype(x.dtype) + p["up_b"].astype(x.dtype))
    y = h @ p["down"].astype(x.dtype)
    if pal.seq_parallel:
        y = psum_scatter_model(y, pal, axis=seq_ax)
    else:
        y = psum_model(y, pal)
    if cfg.act != "silu":
        y = y + p["down_b"].astype(y.dtype)  # added once, after the reduction
    return y


# ---------------------------------------------------------------------------
# Embedding / LM head (vocab-sharded over model axis)
# ---------------------------------------------------------------------------

def init_embed(key, cfg, pal: Parallel):
    from repro.models.parallel import pad_to
    v = pad_to(cfg.vocab_size, max(pal.tp, 1))
    vl = shard_slice(v, pal)
    ks = jax.random.split(key, 2)
    p = {"tok": jax.random.normal(ks[0], (vl, cfg.d_model), jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], cfg.d_model, vl, scale=cfg.d_model ** -0.5)
    return p


def embed_fwd(p, tokens, cfg, pal: Parallel, reduce: str = "psum"):
    """tokens (B, S) -> (B, S, d). Vocab-sharded: local one-hot matmul, then
    reduce: "psum" (full output), "scatter" (psum_scatter on the seq dim —
    fuses the vocab reduction with the seq-parallel slice AND makes the
    embedding gradient exact under SP), or "none" (partial)."""
    vl = p["tok"].shape[0]
    if pal.tp_on:
        from repro.models.parallel import axis_index
        base = axis_index(pal) * vl
        local = tokens - base
        oh = jax.nn.one_hot(jnp.clip(local, 0, vl - 1), vl, dtype=p["tok"].dtype)
        oh = oh * ((local >= 0) & (local < vl))[..., None]
        x = oh @ p["tok"]
        if reduce == "psum":
            x = psum_model(x, pal)
        elif reduce == "scatter":
            x = psum_scatter_model(x, pal, axis=1)
    else:
        x = p["tok"][tokens]
    return x.astype(jnp.dtype(cfg.dtype))


def lm_head_fwd(p, x, cfg, pal: Parallel):
    """x (B, S, d) -> logits (B, S, V_local) — vocab stays sharded; the loss
    computes a sharded softmax (psum over model for the normalizer). Vocab
    ids >= cfg.vocab_size (padding to a tp multiple) are masked to -inf."""
    w = p["tok"] if cfg.tie_embeddings else p["head"]
    logits = x @ (w.T if cfg.tie_embeddings else w).astype(x.dtype)
    vl = logits.shape[-1]
    if vl * max(pal.tp, 1) > cfg.vocab_size:
        from repro.models.parallel import axis_index
        gids = axis_index(pal) * vl + jnp.arange(vl)
        logits = jnp.where(gids < cfg.vocab_size, logits, -1e30)
    return logits


def sharded_xent(logits, targets, cfg, pal: Parallel, vocab_offset=None):
    """Cross-entropy over vocab-sharded logits (B, S, V_local), fp32 math."""
    lf = logits.astype(jnp.float32)
    vl = lf.shape[-1]
    m = jnp.max(lf, -1, keepdims=True)
    if pal.tp_on:
        m = jax.lax.pmax(jax.lax.stop_gradient(m), pal.model_axis)
    else:
        m = jax.lax.stop_gradient(m)
    z = jnp.exp(lf - m)
    denom = psum_model(jnp.sum(z, -1, keepdims=True), pal)
    if pal.tp_on:
        from repro.models.parallel import axis_index
        base = axis_index(pal) * vl
        local = targets - base
        inb = (local >= 0) & (local < vl)
        tgt_logit = jnp.take_along_axis(
            lf, jnp.clip(local, 0, vl - 1)[..., None], axis=-1)[..., 0]
        tgt_logit = psum_model(jnp.where(inb, tgt_logit, 0.0), pal)
    else:
        tgt_logit = jnp.take_along_axis(lf, targets[..., None], -1)[..., 0]
    logp = tgt_logit - (m[..., 0] + jnp.log(denom[..., 0]))
    return -logp
