"""Analytic parameter counts (tp=1, unpadded) — used for MODEL_FLOPS in the
roofline analysis. Mirrors the init shapes in this package exactly.
"""
from __future__ import annotations


def _attn_params(cfg) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.attn_kind == "mla":
        vhd = cfg.v_head_dim or hd
        n = d * (cfg.kv_lora_rank + cfg.rope_head_dim)       # dkv
        n += cfg.kv_lora_rank                                 # kv_norm
        n += cfg.kv_lora_rank * cfg.n_heads * hd              # uk
        n += cfg.kv_lora_rank * cfg.n_heads * vhd             # uv
        n += d * cfg.n_heads * (hd + cfg.rope_head_dim)       # wq
        n += cfg.n_heads * vhd * d                            # wo
        return n
    n = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.qkv_bias:
        n += cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd
    return n


def _mlp_params(cfg, d_ff) -> int:
    d = cfg.d_model
    if cfg.act == "silu":
        return 3 * d * d_ff
    return 2 * d * d_ff + d_ff + d


def _moe_params(cfg) -> tuple[int, int]:
    """(total, active) MoE FFN params per MoE layer."""
    m = cfg.moe
    d = cfg.d_model
    per_expert = 3 * d * m.d_expert if cfg.act == "silu" else 2 * d * m.d_expert
    total = d * m.n_experts + m.n_experts * per_expert
    active = d * m.n_experts + m.top_k * per_expert
    if m.n_shared_experts:
        sh = _mlp_params(cfg, m.d_expert * m.n_shared_experts)
        total += sh
        active += sh
    return total, active


def _mamba_params(cfg) -> int:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    ds = cfg.ssm.d_state
    dc = cfg.ssm.d_conv
    dtr = cfg.ssm.dt_rank or max(1, -(-d // 16))
    n = d * 2 * di                       # in_proj
    n += dc * di + di                    # conv
    n += di * (dtr + 2 * ds)             # x_proj
    n += dtr * di + di                   # dt_proj + bias
    n += di * ds + di                    # A_log, D
    n += di * d                          # out_proj
    return n


def _mlstm_params(cfg) -> int:
    d = cfg.d_model
    di = int(cfg.ssm.mlstm_proj_factor * d)
    h = cfg.n_heads
    n = d + d                            # norm
    n += d * di + d * di                 # up, up_gate
    n += 3 * di * di                     # wq wk wv (v dim = di)
    n += di * 2 * h                      # gates
    n += di                              # ln_h
    n += di * d                          # down
    return n


def _slstm_params(cfg) -> int:
    d = cfg.d_model
    di = -(-int(cfg.ssm.slstm_proj_factor * d) // 16) * 16
    return 2 * d + d * 4 * di + di + di * d


def _norm_params(cfg) -> int:
    return cfg.d_model * (2 if cfg.norm == "layernorm" else 1)


def count_params_analytic(cfg) -> int:
    from repro.models.transformer import layer_pattern, n_superblocks
    pattern = layer_pattern(cfg)
    nsb = n_superblocks(cfg)
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size
    n += _norm_params(cfg)

    def layer_n(mixer, ffn):
        ln = 0
        if mixer == "attn":
            ln += _norm_params(cfg) + _attn_params(cfg)
        elif mixer == "mamba":
            ln += _norm_params(cfg) + _mamba_params(cfg)
        elif mixer == "mlstm":
            ln += _mlstm_params(cfg)
        elif mixer == "slstm":
            ln += _slstm_params(cfg)
        if cfg.is_encoder_decoder:
            ln += _norm_params(cfg) + _attn_params(cfg)      # cross
        if ffn == "dense":
            ln += _norm_params(cfg) + _mlp_params(cfg, cfg.d_ff)
        elif ffn == "moe":
            ln += _norm_params(cfg) + _moe_params(cfg)[0]
        return ln

    n += nsb * sum(layer_n(m, f) for m, f in pattern)
    n += cfg.n_dense_prefix * layer_n(pattern[0][0], "dense")
    if cfg.is_encoder_decoder:
        enc_layer = (_norm_params(cfg) + _attn_params(cfg) +
                     _norm_params(cfg) + _mlp_params(cfg, cfg.d_ff))
        n += cfg.n_enc_layers * enc_layer + _norm_params(cfg)
    return n


def count_active_params(cfg) -> int:
    """Active (per-token) params — MoE counts only routed top-k experts."""
    if cfg.moe is None:
        return count_params_analytic(cfg)
    from repro.models.transformer import layer_pattern, n_superblocks
    total = count_params_analytic(cfg)
    pattern = layer_pattern(cfg)
    nsb = n_superblocks(cfg)
    n_moe_layers = nsb * sum(1 for _, f in pattern if f == "moe")
    tot_moe, act_moe = _moe_params(cfg)
    return total - n_moe_layers * (tot_moe - act_moe)
