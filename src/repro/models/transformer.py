"""Composable decoder (and encoder-decoder) transformer over heterogeneous
layer stacks: attention / Mamba / xLSTM mixers, dense / MoE FFNs.

Layers are grouped into SUPER-BLOCKS of period P (jamba: 8, xlstm: 2, else
1); the stack is a ``jax.lax.scan`` over stacked super-block params — one
trace per distinct layer body regardless of depth (compile-time control for
the 27..64-layer assigned configs). Each super-block is rematerialized
(jax.checkpoint) in training when cfg.remat.

Residual stream in training is sequence-sharded over the model axis when
pal.seq_parallel (Megatron-SP); every mixer gathers/scatters internally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import (
    embed_fwd, init_embed, init_mlp, init_norm, lm_head_fwd, mlp_fwd,
    norm_fwd, sharded_xent,
)
from repro.models.parallel import (
    Parallel, all_gather_model, axis_index, shard_slice,
)

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

def superblock_period(cfg) -> int:
    p = 1
    if cfg.ssm is not None and cfg.ssm.kind == "xlstm":
        p = 2
    if cfg.attn_every > 1:
        p = max(p, cfg.attn_every)
    if cfg.moe is not None:
        p = max(p, cfg.moe.moe_every)
    return p


def layer_pattern(cfg):
    """[(mixer, ffn_kind)] for one super-block period. mixer: attn|mamba|
    mlstm|slstm; ffn: dense|moe|none."""
    p = superblock_period(cfg)
    out = []
    for j in range(p):
        if cfg.attn_every == 0:
            mixer = "mlstm" if (cfg.ssm.kind == "xlstm" and j % 2 == 0) else (
                "slstm" if cfg.ssm.kind == "xlstm" else "mamba")
        elif cfg.attn_every == 1 or j % cfg.attn_every == cfg.attn_offset:
            mixer = "attn"
        else:
            mixer = cfg.ssm.kind if cfg.ssm.kind != "xlstm" else "mlstm"
        if cfg.moe is not None and j % cfg.moe.moe_every == cfg.moe.moe_offset:
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        out.append((mixer, ffn))
    return out


def n_superblocks(cfg) -> int:
    p = superblock_period(cfg)
    body = cfg.n_layers - cfg.n_dense_prefix
    assert body % p == 0, (cfg.name, body, p)
    return body // p


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg, pal: Parallel, mixer: str, ffn: str,
                cross: bool = False, causal: bool = True):
    ks = jax.random.split(key, 6)
    p = {}
    if mixer == "attn":
        p["norm1"] = init_norm(cfg)
        p["attn"] = attn.init_attention(ks[0], cfg, pal)
    elif mixer == "mamba":
        p["norm1"] = init_norm(cfg)
        p["mamba"] = mam.init_mamba(ks[0], cfg, pal)
    elif mixer == "mlstm":
        p["mlstm"] = xl.init_mlstm(ks[0], cfg, pal)
    elif mixer == "slstm":
        p["slstm"] = xl.init_slstm(ks[0], cfg, pal)
    if cross:
        p["norm_x"] = init_norm(cfg)
        p["cross"] = attn.init_attention(ks[1], cfg, pal, cross=True)
    if ffn == "dense":
        p["norm2"] = init_norm(cfg)
        p["mlp"] = init_mlp(ks[2], cfg, pal)
    elif ffn == "moe":
        p["norm2"] = init_norm(cfg)
        p["moe"] = moe_mod.init_moe(ks[2], cfg, pal)
    return p


def init_params(cfg, pal: Parallel, key):
    ks = jax.random.split(key, 8)
    pattern = layer_pattern(cfg)
    nsb = n_superblocks(cfg)

    def init_sb(k):
        kk = jax.random.split(k, len(pattern))
        return {f"l{j}": _init_layer(kk[j], cfg, pal, m, f,
                                     cross=cfg.is_encoder_decoder)
                for j, (m, f) in enumerate(pattern)}

    params = {
        "embed": init_embed(ks[0], cfg, pal),
        "blocks": jax.vmap(init_sb)(jax.random.split(ks[1], nsb)),
        "final_norm": init_norm(cfg),
    }
    if cfg.n_dense_prefix:
        kk = jax.random.split(ks[2], cfg.n_dense_prefix)
        params["prefix"] = [
            _init_layer(kk[i], cfg, pal, pattern[0][0], "dense",
                        cross=cfg.is_encoder_decoder)
            for i in range(cfg.n_dense_prefix)]
    if cfg.is_encoder_decoder:
        def init_enc_layer(k):
            return _init_layer(k, cfg, pal, "attn", "dense", causal=False)
        params["encoder"] = {
            "blocks": jax.vmap(init_enc_layer)(
                jax.random.split(ks[3], cfg.n_enc_layers)),
            "final_norm": init_norm(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# Layer forward (training / full-seq)
# ---------------------------------------------------------------------------

def _layer_fwd(p, x, cfg, pal: Parallel, mixer: str, ffn: str, aux,
               causal=True, cross_kv=None, window=0):
    if mixer == "attn":
        h = norm_fwd(p["norm1"], x, cfg.norm)
        x = x + attn.attn_fwd_full(p["attn"], h, cfg, pal, causal=causal,
                                   window=window)
    elif mixer == "mamba":
        h = norm_fwd(p["norm1"], x, cfg.norm)
        x = x + mam.mamba_fwd(p["mamba"], h, cfg, pal)
    elif mixer == "mlstm":
        x = x + xl.mlstm_fwd(p["mlstm"], x, cfg, pal)
    elif mixer == "slstm":
        x = x + xl.slstm_fwd(p["slstm"], x, cfg, pal)
    if "cross" in p and cross_kv is not None and bool(cross_kv):
        h = norm_fwd(p["norm_x"], x, cfg.norm)
        kv = attn.init_cross_kv(p["cross"], cross_kv.enc_out, cfg, pal)
        x = x + attn.attn_fwd_full(p["cross"], h, cfg, pal, causal=False,
                                   cross_kv=kv)
    if ffn == "dense":
        h = norm_fwd(p["norm2"], x, cfg.norm)
        x = x + mlp_fwd(p["mlp"], h, cfg, pal)
    elif ffn == "moe":
        h = norm_fwd(p["norm2"], x, cfg.norm)
        y, a = moe_mod.moe_fwd(p["moe"], h, cfg, pal)
        x = x + y
        aux = {k: aux[k] + a[k] for k in a}
    return x, aux


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "drop_frac": jnp.zeros((), jnp.float32)}


def forward_hidden(params, x, cfg, pal: Parallel, cross_kv=None, window=0):
    """Run the full layer stack on embedded input x. Returns (x, aux)."""
    pattern = layer_pattern(cfg)
    aux = _zero_aux()
    for p in params.get("prefix", []):
        x, aux = _layer_fwd(p, x, cfg, pal, pattern[0][0], "dense", aux,
                            cross_kv=cross_kv, window=window)

    def sb_fwd(carry, sbp):
        x, aux = carry
        for j, (m, f) in enumerate(pattern):
            x, aux = _layer_fwd(sbp[f"l{j}"], x, cfg, pal, m, f, aux,
                                cross_kv=cross_kv, window=window)
        return (x, aux), None

    body = jax.checkpoint(sb_fwd) if cfg.remat else sb_fwd
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
    x = norm_fwd(params["final_norm"], x, cfg.norm)
    return x, aux


def encode(params, frames, cfg, pal: Parallel):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    s = frames.shape[1]
    pos = _sinusoidal(s, cfg.d_model, frames.dtype)
    x = frames + pos

    def enc_fwd(x, lp):
        x, _ = _layer_fwd(lp, x, cfg, pal, "attn", "dense", _zero_aux(),
                          causal=False)
        return x, None

    body = jax.checkpoint(enc_fwd) if cfg.remat else enc_fwd
    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return norm_fwd(params["encoder"]["final_norm"], x, cfg.norm)


def _sinusoidal(s, d, dtype):
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe[None].astype(dtype)


# ---------------------------------------------------------------------------
# Embedding of a (possibly multimodal) batch
# ---------------------------------------------------------------------------

def embed_batch(params, batch, cfg, pal: Parallel, seq_shard: bool):
    """tokens (B, S) [+ patches (B, P, d)] -> x, possibly seq-sharded.

    NB: the vocab-sharded embedding psum requires every model rank to query
    the SAME token ids (each contributes its vocab shard) — so we embed the
    full sequence first and slice the rank's seq shard afterwards.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    shard = seq_shard and pal.tp_on
    x = embed_fwd(params["embed"], tokens, cfg, pal,
                  reduce="scatter" if shard else "psum")
    if shard:
        sl = shard_slice(s, pal)
        pos0 = axis_index(pal) * sl
    else:
        sl, pos0 = s, 0
    if cfg.frontend == "vision_stub" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype)      # (B, P, d)
        npat = patches.shape[1]
        gpos = pos0 + jnp.arange(sl)
        idx = jnp.clip(gpos, 0, npat - 1)
        over = jnp.take(patches, idx, axis=1)
        x = jnp.where((gpos < npat)[None, :, None], over, x)
    if not cfg.rope and cfg.ssm is None:
        # absolute sinusoidal positions (whisper decoder, non-rope dense)
        pe = _sinusoidal(s, cfg.d_model, x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pe, pos0, sl, 1)
    return x


# ---------------------------------------------------------------------------
# Loss (training)
# ---------------------------------------------------------------------------

def loss_fn(params, batch, cfg, pal: Parallel, window=0):
    """Next-token xent (mean over non-masked targets) + MoE aux losses.

    batch: tokens (B, S) int32; targets (B, S) int32 with -1 = masked;
    vlm: patches (B, P, d); audio: frames (B, S_enc, d).
    """
    cross_kv = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frames"].astype(jnp.dtype(cfg.dtype)),
                         cfg, pal)
        # per-layer cross K/V computed lazily inside layers would recompute
        # the projection each scan step; instead pass enc_out and project
        # inside each layer (params differ per layer).
        cross_kv = enc_out
    x = embed_batch(params, batch, cfg, pal, seq_shard=pal.seq_parallel)
    x, aux = forward_hidden(params, x, cfg, pal,
                            cross_kv=_CrossFromEnc(cross_kv), window=window)
    if pal.seq_parallel:
        x = all_gather_model(x, pal, axis=1)
    targets = batch["targets"]
    loss = _chunked_xent(params, x, targets, cfg, pal)
    if cfg.moe is not None:
        m = cfg.moe
        nl = sum(1 for _, f in layer_pattern(cfg) if f == "moe") * n_superblocks(cfg)
        aux_term = (m.load_balance_loss * aux["lb_loss"] +
                    m.router_z_loss * aux["z_loss"]) / max(nl, 1)
        if pal.tp_on:
            # local loss is a per-rank DISJOINT contribution (see
            # _chunked_xent); each rank's aux covers its seq shard, and the
            # global aux average is sum_r aux_r / tp -> add aux_r / tp here.
            aux_term = aux_term / pal.tp
            aux = {k: jax.lax.pmean(jax.lax.stop_gradient(v),
                                    pal.model_axis) for k, v in aux.items()}
        loss = loss + aux_term
    return loss, aux


def global_loss(loss_local, pal: Parallel):
    """Combine per-rank disjoint loss contributions into the global loss
    value (metrics only — never differentiate through this)."""
    if pal.tp_on:
        return jax.lax.psum(loss_local, pal.model_axis)
    return loss_local


class _CrossFromEnc:
    """Sentinel wrapper: layers project enc_out with their own cross wk/wv."""
    def __init__(self, enc_out):
        self.enc_out = enc_out

    def __bool__(self):
        return self.enc_out is not None


def _chunked_xent(params, x, targets, cfg, pal: Parallel):
    """Cross-entropy over vocab-sharded logits, chunked over seq.

    SPMD-correct loss composition: every model rank computes the nll for all
    positions (x is seq-gathered, logits vocab-sharded with psums inside),
    but each rank SUMS ONLY ITS OWN seq-slice — contributions are disjoint,
    and one final psum yields the global loss. Summing redundant copies
    instead would inflate gradients by tp through the psum transposes.
    """
    b, s, _ = x.shape
    chunk = min(LOSS_CHUNK, s)
    if s % chunk:
        chunk = s
    n = s // chunk
    xs = x.reshape(b, n, chunk, -1).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, n, chunk).transpose(1, 0, 2)
    if pal.tp_on:
        sl = s // pal.tp
        r = axis_index(pal)
        own_lo, own_hi = r * sl, (r + 1) * sl
    offs = jnp.arange(n) * chunk

    def body(carry, inp):
        xc, tc, off = inp
        logits = lm_head_fwd(params["embed"], xc, cfg, pal)
        valid = (tc >= 0).astype(jnp.float32)
        if pal.tp_on:
            gpos = off + jnp.arange(chunk)
            own = (gpos >= own_lo) & (gpos < own_hi)
            valid = valid * own[None, :]
        nll = sharded_xent(logits, jnp.maximum(tc, 0), cfg, pal)
        loss = jnp.sum(nll * valid)
        return (carry[0] + loss, carry[1] + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (xs, ts, offs))
    if pal.tp_on:
        # Return the LOCAL contribution tot_r / CNT (global count). The
        # global loss is psum(local) — but that psum must happen OUTSIDE the
        # grad: differentiating a replicated post-psum loss inflates every
        # gradient by tp via the psum transpose. SPMD collective transposes
        # deliver the cross-rank terms automatically when each rank seeds
        # only its own disjoint contribution.
        cnt = jax.lax.psum(jax.lax.stop_gradient(cnt), pal.model_axis)
        return tot / jnp.maximum(cnt, 1.0)
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def _layer_cache_init(cfg, pal: Parallel, mixer: str, batch: int,
                      cache_seq: int, dtype):
    if mixer == "attn":
        return attn.init_cache(cfg, pal, batch, cache_seq, dtype)
    if mixer == "mamba":
        return mam.init_mamba_cache(cfg, pal, batch, dtype)
    if mixer == "mlstm":
        return xl.init_mlstm_cache(cfg, pal, batch)
    if mixer == "slstm":
        return xl.init_slstm_cache(cfg, pal, batch)
    raise ValueError(mixer)


def init_decode_cache(cfg, pal: Parallel, batch: int, max_seq: int, dtype,
                      enc_seq: int = 0):
    """Cache pytree for decode. max_seq here is the PER-RANK cache length
    when pal.cache_seq_axis is set (caller divides)."""
    pattern = layer_pattern(cfg)
    nsb = n_superblocks(cfg)
    hd = cfg.resolved_head_dim

    def sb_cache(_):
        return {f"l{j}": _layer_cache_init(cfg, pal, m, batch, max_seq, dtype)
                for j, (m, f) in enumerate(pattern)}

    cache = {
        "pos": jnp.zeros((), jnp.int32),
        "blocks": jax.vmap(sb_cache)(jnp.arange(nsb)),
    }
    if cfg.n_dense_prefix:
        cache["prefix"] = [
            _layer_cache_init(cfg, pal, pattern[0][0], batch, max_seq, dtype)
            for _ in range(cfg.n_dense_prefix)]
    if cfg.is_encoder_decoder:
        from repro.models.parallel import heads_padded
        kvl = shard_slice(heads_padded(cfg.n_kv_heads, pal), pal) \
            if getattr(pal, "attn_dist", "sp") != "ring" else cfg.n_kv_heads
        z = jnp.zeros((nsb, batch, enc_seq, kvl, hd), dtype)
        cache["cross"] = {"k": z, "v": jnp.array(z)}
    return cache


def _layer_decode(p, x, lc, pos, cfg, pal: Parallel, mixer, ffn, cross_kv=None):
    if mixer == "attn":
        h = norm_fwd(p["norm1"], x, cfg.norm)
        y, lc = attn.attn_decode(p["attn"], h, lc, pos, cfg, pal)
        x = x + y
    elif mixer == "mamba":
        h = norm_fwd(p["norm1"], x, cfg.norm)
        y, lc = mam.mamba_decode(p["mamba"], h, lc, cfg, pal)
        x = x + y
    elif mixer == "mlstm":
        y, lc = xl.mlstm_decode(p["mlstm"], x, lc, cfg, pal)
        x = x + y
    elif mixer == "slstm":
        y, lc = xl.slstm_decode(p["slstm"], x, lc, cfg, pal)
        x = x + y
    if "cross" in p and cross_kv is not None:
        h = norm_fwd(p["norm_x"], x, cfg.norm)
        y, _ = attn.attn_decode(p["cross"], h, None, pos, cfg, pal,
                                cross_kv=cross_kv)
        x = x + y
    if ffn == "dense":
        x = x + mlp_fwd(p["mlp"], norm_fwd(p["norm2"], x, cfg.norm), cfg, pal)
    elif ffn == "moe":
        y, _ = moe_mod.moe_fwd(p["moe"], norm_fwd(p["norm2"], x, cfg.norm), cfg, pal)
        x = x + y
    return x, lc


def decode_step(params, cache, token, cfg, pal: Parallel):
    """token (B, 1) int32 -> (logits (B, V_padded), new cache). One step."""
    pattern = layer_pattern(cfg)
    pos = cache["pos"]
    x = embed_fwd(params["embed"], token, cfg, pal)
    if not cfg.rope and cfg.ssm is None:
        pe = _sinusoidal_at(pos, cfg.d_model, x.dtype)
        x = x + pe
    new_cache = dict(cache)
    if cfg.n_dense_prefix:
        new_prefix = []
        for p, lc in zip(params["prefix"], cache["prefix"]):
            x, lc = _layer_decode(p, x, lc, pos, cfg, pal, pattern[0][0], "dense")
            new_prefix.append(lc)
        new_cache["prefix"] = new_prefix

    cross = cache.get("cross")

    def sb_dec(x, inp):
        if cross is not None:
            sbp, sbc, ckv = inp
        else:
            sbp, sbc = inp
            ckv = None
        new_sbc = {}
        for j, (m, f) in enumerate(pattern):
            xkv = (ckv["k"], ckv["v"]) if ckv is not None else None
            x, lc = _layer_decode(sbp[f"l{j}"], x, sbc[f"l{j}"], pos, cfg, pal,
                                  m, f, cross_kv=xkv)
            new_sbc[f"l{j}"] = lc
        return x, new_sbc

    xs = (params["blocks"], cache["blocks"], cross) if cross is not None \
        else (params["blocks"], cache["blocks"])
    x, new_blocks = jax.lax.scan(sb_dec, x, xs)
    new_cache["blocks"] = new_blocks
    new_cache["pos"] = pos + 1
    x = norm_fwd(params["final_norm"], x, cfg.norm)
    logits = lm_head_fwd(params["embed"], x, cfg, pal)      # (B,1,V_local)
    logits = all_gather_model(logits, pal, axis=2)[:, 0]
    return logits, new_cache


def _sinusoidal_at(pos, d, dtype):
    dim = jnp.arange(0, d, 2).astype(jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
    return pe[None, None].astype(dtype)


def _layer_prefill(p, x, cfg, pal: Parallel, mixer, ffn, max_seq, dtype,
                   cross_kv=None):
    """Full-prompt forward returning (x, layer_cache)."""
    if mixer == "attn":
        h = norm_fwd(p["norm1"], x, cfg.norm)
        y, lc = attn.attn_prefill(p["attn"], h, cfg, pal, max_seq=max_seq)
        x = x + y
    elif mixer == "mamba":
        h = norm_fwd(p["norm1"], x, cfg.norm)
        y, st = mam.mamba_fwd(p["mamba"], h, cfg, pal, return_state=True)
        x = x + y
        lc = st
    elif mixer == "mlstm":
        y, st = xl.mlstm_fwd(p["mlstm"], x, cfg, pal, return_state=True)
        x = x + y
        lc = st
    elif mixer == "slstm":
        y, st = xl.slstm_fwd(p["slstm"], x, cfg, pal, return_state=True)
        x = x + y
        lc = st
    if "cross" in p and cross_kv is not None:
        h = norm_fwd(p["norm_x"], x, cfg.norm)
        kv = attn.init_cross_kv(p["cross"], cross_kv, cfg, pal)
        x = x + attn.attn_fwd_full(p["cross"], h, cfg, pal, causal=False,
                                   cross_kv=kv)
    if ffn == "dense":
        x = x + mlp_fwd(p["mlp"], norm_fwd(p["norm2"], x, cfg.norm), cfg, pal)
    elif ffn == "moe":
        y, _ = moe_mod.moe_fwd(p["moe"], norm_fwd(p["norm2"], x, cfg.norm), cfg, pal)
        x = x + y
    return x, lc


def prefill(params, batch, cfg, pal: Parallel, max_seq: int):
    """Prompt forward building the decode cache. batch: tokens (B, S) [+
    patches/frames]. Returns (last_logits (B, V_padded), cache). Serving is
    batch-parallel over data; seq is NOT sharded here (pal.seq_parallel off
    in serve paths); cache seq dim is full length max_seq (sliding archs:
    min(window, max_seq))."""
    pattern = layer_pattern(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frames"].astype(dtype), cfg, pal)
    x = embed_batch(params, batch, cfg, pal, seq_shard=False)

    cache = init_decode_cache(cfg, pal, b, max_seq, dtype,
                              enc_seq=enc_out.shape[1] if enc_out is not None else 0)
    if cfg.n_dense_prefix:
        new_prefix = []
        for p in params["prefix"]:
            x, lc = _layer_prefill(p, x, cfg, pal, pattern[0][0], "dense",
                                   max_seq, dtype, cross_kv=enc_out)
            new_prefix.append(lc)
        cache["prefix"] = new_prefix

    def sb_pre(x, sbp):
        new_sbc = {}
        ck = None
        for j, (m, f) in enumerate(pattern):
            x, lc = _layer_prefill(sbp[f"l{j}"], x, cfg, pal, m, f, max_seq,
                                   dtype, cross_kv=enc_out)
            new_sbc[f"l{j}"] = lc
            if cfg.is_encoder_decoder:
                k, v = attn.init_cross_kv(sbp[f"l{j}"]["cross"], enc_out, cfg, pal)
                ck = {"k": k.astype(dtype), "v": v.astype(dtype)}
        out = (new_sbc, ck) if cfg.is_encoder_decoder else new_sbc
        return x, out

    x, collected = jax.lax.scan(sb_pre, x, params["blocks"])
    if cfg.is_encoder_decoder:
        cache["blocks"], cross = collected
        cache["cross"] = cross
    else:
        cache["blocks"] = collected
    cache["pos"] = jnp.full((), s, jnp.int32)
    x = norm_fwd(params["final_norm"], x, cfg.norm)
    logits = lm_head_fwd(params["embed"], x[:, -1:], cfg, pal)
    logits = all_gather_model(logits, pal, axis=2)[:, 0]
    return logits, cache
