from repro.models.parallel import Parallel
from repro.models.transformer import (
    init_params, loss_fn, prefill, decode_step, init_decode_cache,
    layer_pattern, n_superblocks,
)
