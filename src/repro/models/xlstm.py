"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
exponential gating), per arXiv:2405.04517, alternating in the stack.

TP: the value/output dimension of each head is sharded over the model axis
(the matrix memory C (hd_v, hd_k) shards on rows); q/k projections are
replicated (small). sLSTM recurrent kernels are omitted (input-driven gates
only) — noted in DESIGN.md; the exponential-gating stabilizer state (m) is
kept exactly as in the paper.

Both blocks are pre-LN residual blocks with internal up/down projections
(mLSTM proj factor 2, sLSTM 4/3) — the assigned config has d_ff=0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_norm, norm_fwd
from repro.models.parallel import (
    Parallel, all_gather_model, psum_model, psum_scatter_model, shard_slice,
)


def _mlstm_dims(cfg, pal: Parallel):
    d_inner = int(cfg.ssm.mlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    hd = d_inner // h
    hdv_l = shard_slice(hd, pal)          # value dim rows sharded
    return d_inner, h, hd, hdv_l


def init_mlstm(key, cfg, pal: Parallel):
    d = cfg.d_model
    d_inner, h, hd, hdv_l = _mlstm_dims(cfg, pal)
    ks = jax.random.split(key, 8)
    return {
        "norm": init_norm(cfg),
        "up": dense_init(ks[0], d, d_inner),          # replicated (pre-split)
        # head-major 3-D layouts: the v dim of each head is sharded on its
        # OWN axis so the global array layout is tp-independent
        "up_gate": dense_init(ks[1], d, h * hdv_l).reshape(d, h, hdv_l),
        "wq": dense_init(ks[2], d_inner, h * hd),     # replicated
        "wk": dense_init(ks[3], d_inner, h * hd),
        "wv": dense_init(ks[4], d_inner, h * hdv_l).reshape(d_inner, h, hdv_l),
        "wif": dense_init(ks[5], d_inner, 2 * h, scale=0.02),  # i,f gates
        "ln_h": jnp.ones((h, hdv_l), jnp.float32),
        "down": dense_init(ks[6], h * hdv_l, d).reshape(h, hdv_l, d),
    }


def _mlstm_scan(q, k, v, ig, fg, state):
    """q,k: (B,S,H,hd); v: (B,S,H,hdv_l); ig,fg: (B,S,H) raw gates.
    state: (C (B,H,hdv_l,hd), n (B,H,hd), m (B,H)). Sequential lax.scan over
    S with stabilized exponential gating."""
    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, it, ft = inp                      # (B,H,hd)... (B,H)
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c = f_[..., None, None] * c + i_[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])      # (B,H,hdv_l,hd)
        n = f_[..., None] * n + i_[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        h = num / den[..., None]
        return (c, n, m_new), h

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (q, k, v))
    gs = tuple(t.transpose(1, 0, 2) for t in (ig, fg))
    (c, n, m), hs = jax.lax.scan(step, state, xs + gs)
    return hs.transpose(1, 0, 2, 3), (c, n, m)        # (B,S,H,hdv_l)


def mlstm_fwd(p, x, cfg, pal: Parallel, state=None, return_state=False):
    if pal.seq_parallel:
        x = all_gather_model(x, pal, axis=1)
    b, s, _ = x.shape
    d_inner, h, hd, hdv_l = _mlstm_dims(cfg, pal)
    xi = norm_fwd(p["norm"], x, cfg.norm)
    u = (xi @ p["up"].astype(xi.dtype))
    og = jax.nn.sigmoid(jnp.einsum("bsd,dhv->bshv", xi, p["up_gate"].astype(xi.dtype)))
    q = (u @ p["wq"].astype(u.dtype)).reshape(b, s, h, hd)
    k = (u @ p["wk"].astype(u.dtype)).reshape(b, s, h, hd) * hd ** -0.5
    v = jnp.einsum("bsu,uhv->bshv", u, p["wv"].astype(u.dtype))
    gf = (u @ p["wif"].astype(u.dtype)).astype(jnp.float32)
    ig, fg = gf[..., :h], jax.nn.log_sigmoid(gf[..., h:])
    state = state if state is not None else (
        jnp.zeros((b, h, hdv_l, hd), jnp.float32),
        jnp.zeros((b, h, hd), jnp.float32),
        jnp.zeros((b, h), jnp.float32))
    hs, (c, n, m) = _mlstm_scan(q.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), ig, fg, state)
    hs = (hs * p["ln_h"]).astype(x.dtype) * og            # (B,S,h,hdv_l)
    out = jnp.einsum("bshv,hvd->bsd", hs, p["down"].astype(hs.dtype))
    out = (psum_scatter_model(out, pal, axis=1) if pal.seq_parallel
           else psum_model(out, pal))
    if return_state:
        return out, {"c": c, "n": n, "m": m}
    return out


def init_mlstm_cache(cfg, pal: Parallel, batch: int):
    _, h, hd, hdv_l = _mlstm_dims(cfg, pal)
    return {"c": jnp.zeros((batch, h, hdv_l, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


def mlstm_decode(p, x, cache, cfg, pal: Parallel):
    b = x.shape[0]
    d_inner, h, hd, hdv_l = _mlstm_dims(cfg, pal)
    xi = norm_fwd(p["norm"], x[:, 0], cfg.norm)
    u = xi @ p["up"].astype(xi.dtype)
    og = jax.nn.sigmoid(jnp.einsum("bd,dhv->bhv", xi, p["up_gate"].astype(xi.dtype)))
    q = (u @ p["wq"].astype(u.dtype)).reshape(b, h, hd).astype(jnp.float32)
    k = ((u @ p["wk"].astype(u.dtype)).reshape(b, h, hd)
         * hd ** -0.5).astype(jnp.float32)
    v = jnp.einsum("bu,uhv->bhv", u, p["wv"].astype(u.dtype)).astype(jnp.float32)
    gf = (u @ p["wif"].astype(u.dtype)).astype(jnp.float32)
    ig, fg = gf[..., :h], jax.nn.log_sigmoid(gf[..., h:])
    c, n, m = cache["c"], cache["n"], cache["m"]
    m_new = jnp.maximum(fg + m, ig)
    i_ = jnp.exp(ig - m_new)
    f_ = jnp.exp(fg + m - m_new)
    c = (f_[..., None, None] * c
         + i_[..., None, None] * (v[..., :, None] * k[..., None, :]))
    n = f_[..., None] * n + i_[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    hv = (num / den[..., None])                            # (B,h,hdv_l)
    hv = (hv * p["ln_h"]).astype(x.dtype) * og
    out = jnp.einsum("bhv,hvd->bd", hv, p["down"].astype(hv.dtype))[:, None]
    out = psum_model(out, pal)
    return out, {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_dims(cfg, pal: Parallel):
    # round up to a multiple of 16 so d_inner is mesh-independent and
    # MXU-aligned (same shapes at tp=1 and tp=16)
    d_inner = -(-int(cfg.ssm.slstm_proj_factor * cfg.d_model) // 16) * 16
    dil = shard_slice(d_inner, pal)
    return d_inner, dil


def init_slstm(key, cfg, pal: Parallel):
    d = cfg.d_model
    d_inner, dil = _slstm_dims(cfg, pal)
    ks = jax.random.split(key, 4)
    kk = jax.random.split(ks[0], 4)
    return {
        "norm": init_norm(cfg),
        "wi": dense_init(kk[0], d, dil),              # col-parallel gates
        "wf": dense_init(kk[1], d, dil),
        "wz": dense_init(kk[2], d, dil),
        "wo": dense_init(kk[3], d, dil),
        "ln_h": jnp.ones((dil,), jnp.float32),
        "down": dense_init(ks[1], dil, d),            # row-parallel
    }


def slstm_fwd(p, x, cfg, pal: Parallel, state=None, return_state=False):
    if pal.seq_parallel:
        x = all_gather_model(x, pal, axis=1)
    b, s, _ = x.shape
    _, dil = _slstm_dims(cfg, pal)
    xi = norm_fwd(p["norm"], x, cfg.norm)
    ig = (xi @ p["wi"].astype(xi.dtype)).astype(jnp.float32)
    fg = (xi @ p["wf"].astype(xi.dtype)).astype(jnp.float32)
    zg = (xi @ p["wz"].astype(xi.dtype)).astype(jnp.float32)
    og = (xi @ p["wo"].astype(xi.dtype)).astype(jnp.float32)
    fg = jax.nn.log_sigmoid(fg)
    zg = jnp.tanh(zg)
    og = jax.nn.sigmoid(og)
    state = state if state is not None else (
        jnp.zeros((b, dil), jnp.float32), jnp.zeros((b, dil), jnp.float32),
        jnp.zeros((b, dil), jnp.float32))

    def step(carry, inp):
        c, n, m = carry
        it, ft, zt, ot = inp
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c = f_ * c + i_ * zt
        n = f_ * n + i_
        h = ot * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), h

    xs = tuple(t.transpose(1, 0, 2) for t in (ig, fg, zg, og))
    (c, n, m), hs = jax.lax.scan(step, state, xs)
    hs = hs.transpose(1, 0, 2)
    hs = (hs * p["ln_h"]).astype(x.dtype)
    out = hs @ p["down"].astype(hs.dtype)
    out = (psum_scatter_model(out, pal, axis=1) if pal.seq_parallel
           else psum_model(out, pal))
    if return_state:
        return out, {"c": c, "n": n, "m": m}
    return out


def init_slstm_cache(cfg, pal: Parallel, batch: int):
    _, dil = _slstm_dims(cfg, pal)
    z = jnp.zeros((batch, dil), jnp.float32)
    return {"c": z, "n": z, "m": z}


def slstm_decode(p, x, cache, cfg, pal: Parallel):
    xi = norm_fwd(p["norm"], x[:, 0], cfg.norm)
    ig = (xi @ p["wi"].astype(xi.dtype)).astype(jnp.float32)
    fg = (xi @ p["wf"].astype(xi.dtype)).astype(jnp.float32)
    zg = (xi @ p["wz"].astype(xi.dtype)).astype(jnp.float32)
    og = (xi @ p["wo"].astype(xi.dtype)).astype(jnp.float32)
    fg = jax.nn.log_sigmoid(fg)
    zg = jnp.tanh(zg)
    og = jax.nn.sigmoid(og)
    c, n, m = cache["c"], cache["n"], cache["m"]
    m_new = jnp.maximum(fg + m, ig)
    i_ = jnp.exp(ig - m_new)
    f_ = jnp.exp(fg + m - m_new)
    c = f_ * c + i_ * zg
    n = f_ * n + i_
    h = og * c / jnp.maximum(n, 1.0)
    h = (h * p["ln_h"]).astype(x.dtype)
    out = (h @ p["down"].astype(h.dtype))[:, None]
    return psum_model(out, pal), {"c": c, "n": n, "m": m_new}
