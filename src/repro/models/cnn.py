"""Small residual CNN classifier (ResNet-18-class analogue for §4.2).

Pure JAX; used by the paper-claim benchmark that stands in for
ResNet-18/CIFAR-10 (no dataset in this container — DESIGN.md §1). Three
residual stages over 16x16x3 synthetic images, ~200k params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * fan ** -0.5


def init_cnn(key, n_classes=10, width=32):
    ks = jax.random.split(key, 12)
    w = width
    p = {
        "stem": _conv_init(ks[0], 3, 3, 3, w),
        "b1a": _conv_init(ks[1], 3, 3, w, w),
        "b1b": _conv_init(ks[2], 3, 3, w, w),
        "b2a": _conv_init(ks[3], 3, 3, w, 2 * w),
        "b2b": _conv_init(ks[4], 3, 3, 2 * w, 2 * w),
        "b2s": _conv_init(ks[5], 1, 1, w, 2 * w),
        "b3a": _conv_init(ks[6], 3, 3, 2 * w, 4 * w),
        "b3b": _conv_init(ks[7], 3, 3, 4 * w, 4 * w),
        "b3s": _conv_init(ks[8], 1, 1, 2 * w, 4 * w),
        "head_w": jax.random.normal(ks[9], (4 * w, n_classes)) * (4 * w) ** -0.5,
        "head_b": jnp.zeros((n_classes,)),
    }
    return p


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _norm(x):
    # parameter-free group-norm-ish normalization (keeps the bench about the
    # sparsifier, not BN statistics synchronization)
    mu = jnp.mean(x, axis=(1, 2, 3), keepdims=True)
    sd = jnp.std(x, axis=(1, 2, 3), keepdims=True) + 1e-5
    return (x - mu) / sd


def cnn_fwd(p, x):
    h = jax.nn.relu(_norm(_conv(x, p["stem"])))
    r = h
    h = jax.nn.relu(_norm(_conv(h, p["b1a"])))
    h = jax.nn.relu(r + _norm(_conv(h, p["b1b"])))
    r = _conv(h, p["b2s"], 2)
    h = jax.nn.relu(_norm(_conv(h, p["b2a"], 2)))
    h = jax.nn.relu(r + _norm(_conv(h, p["b2b"])))
    r = _conv(h, p["b3s"], 2)
    h = jax.nn.relu(_norm(_conv(h, p["b3a"], 2)))
    h = jax.nn.relu(r + _norm(_conv(h, p["b3b"])))
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["head_w"] + p["head_b"]


def cnn_loss(p, x, y):
    logits = cnn_fwd(p, x)
    nll = -jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y]
    return jnp.mean(nll)


def cnn_accuracy(p, x, y, batch=250):
    n = x.shape[0]
    correct = 0
    fwd = jax.jit(cnn_fwd)
    for i in range(0, n, batch):
        logits = fwd(p, x[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + batch]))
    return correct / n
