"""Mixture-of-Experts FFN with expert parallelism over the model axis.

Fixed-capacity top-k routing (GShard/Switch style), TPU-friendly: static
shapes, scatter/gather dispatch, ``all_to_all`` expert exchange. Expert count
is padded to a multiple of the model-axis size (granite-moe: 40 -> 48 at
tp=16; padded experts are masked to -inf in the router and carry zero-init
weights). Shared experts (DeepSeek-V2) run as a dense column/row-parallel
MLP alongside the routed path.

Aux losses: Switch load-balance loss and router z-loss, returned per call
and averaged over layers by the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.parallel import (
    Parallel, pad_to, shard_slice,
)


def _padded_experts(cfg, pal: Parallel) -> int:
    return pad_to(cfg.moe.n_experts, max(pal.tp, 1))


def init_moe(key, cfg, pal: Parallel):
    m = cfg.moe
    d = cfg.d_model
    e_pad = _padded_experts(cfg, pal)
    el = shard_slice(e_pad, pal)
    ks = jax.random.split(key, 5)

    def expert_stack(k, d_in, d_out):
        return jax.random.normal(k, (el, d_in, d_out), jnp.float32) * d_in ** -0.5

    p = {
        "router": dense_init(ks[0], d, e_pad, scale=0.02),
        "gate": expert_stack(ks[1], d, m.d_expert),
        "up": expert_stack(ks[2], d, m.d_expert),
        "down": expert_stack(ks[3], m.d_expert, d),
    }
    if m.n_shared_experts:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg, pal,
                               d_ff=m.d_expert * m.n_shared_experts)
    return p


def capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k / n_experts * factor)
    return max(8, pad_to(c, 8))


def moe_fwd(p, x, cfg, pal: Parallel):
    """x: (B, T, d) local tokens (seq-sharded over model in SP mode).
    Returns (y, aux) with aux = {lb_loss, z_loss, drop_frac}."""
    m = cfg.moe
    b, t, d = x.shape
    xt = x.reshape(b * t, d)
    n_tok = b * t
    e_pad = _padded_experts(cfg, pal)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    if e_pad > m.n_experts:
        pad_mask = jnp.arange(e_pad) >= m.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, -1)                       # (T, E)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)             # (T, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # aux losses (computed on the real experts only)
    me = jnp.mean(probs[:, :m.n_experts], 0)
    sel = jax.nn.one_hot(top_e, e_pad, dtype=jnp.float32)    # (T, K, E)
    fe = jnp.mean(jnp.sum(sel, 1), 0)[:m.n_experts]
    lb_loss = m.n_experts * jnp.sum(me * fe)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)

    # capacity + slot assignment: position of each (token, choice) within its
    # expert's queue, in token order, choices flattened K-major.
    cap = capacity(n_tok, e_pad, m.top_k, m.capacity_factor)
    sel_flat = sel.reshape(n_tok * m.top_k, e_pad)
    pos_in_e = (jnp.cumsum(sel_flat, 0) - sel_flat)          # (T*K, E)
    slot = jnp.sum(pos_in_e * sel_flat, -1).astype(jnp.int32)  # (T*K,)
    expert = top_e.reshape(-1).astype(jnp.int32)
    keep = slot < cap
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))

    # dispatch: scatter tokens into (E, cap, d)
    flat_idx = jnp.where(keep, expert * cap + slot, e_pad * cap)  # OOB -> drop row
    buf = jnp.zeros((e_pad * cap + 1, d), xt.dtype)
    tok_rep = jnp.repeat(xt, m.top_k, axis=0)                # (T*K, d)
    buf = buf.at[flat_idx].add(tok_rep)
    buf = buf[:-1].reshape(e_pad, cap, d)

    if pal.tp_on:
        # EP: every rank holds (e_pad, cap, d) contributions for all experts;
        # all_to_all splits the expert dim across ranks and concatenates the
        # tp source shards along the capacity dim -> (el, tp*cap, d).
        buf = jax.lax.all_to_all(buf, pal.model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)

    # expert FFN (local experts, batched einsum)
    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(buf.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(buf.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(buf.dtype)))
    out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(buf.dtype))

    if pal.tp_on:
        out = jax.lax.all_to_all(out, pal.model_axis, split_axis=1,
                                 concat_axis=0, tiled=True)

    # combine: gather each kept (token, choice) slot, weight by router prob
    out_flat = jnp.concatenate([out.reshape(e_pad * cap, d),
                                jnp.zeros((1, d), out.dtype)], 0)
    per_choice = out_flat[flat_idx]                          # (T*K, d)
    w = (top_p.reshape(-1) * keep).astype(per_choice.dtype)
    y = jnp.sum((per_choice * w[:, None]).reshape(n_tok, m.top_k, d), 1)
    y = y.reshape(b, t, d)

    if m.n_shared_experts:
        from repro.models.layers import mlp_fwd
        y = y + mlp_fwd(p["shared"], x, cfg, pal)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "drop_frac": drop_frac}
    return y, aux
