"""Mamba-1 selective SSM block, channel-parallel over the model axis.

TPU adaptation (DESIGN.md §2.2): SSM channels (d_inner) are independent, so
TP shards channels — each rank scans the FULL sequence for its channel slice
(no sequential cross-rank dependency). In sequence-parallel mode the block
all-gathers the seq dim on entry and psum_scatters on exit, exactly like the
attention block. The (B, C, dt) data-dependent projections need the full
d_inner, so their input projection is row-parallel with one small psum.

Prefill/train uses a chunked scan: sequential ``lax.scan`` over seq chunks,
associative scan inside the chunk — bounds the (B, chunk, d_inner_l, d_state)
working set. Decode carries (conv_buf, ssm_state) and is O(1) in context
length (this is what makes long_500k native for mamba archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.parallel import (
    Parallel, all_gather_model, psum_model, psum_scatter_model, shard_slice,
)

SCAN_CHUNK = 512


def _dims(cfg, pal: Parallel):
    d_inner = cfg.ssm.expand * cfg.d_model
    dil = shard_slice(d_inner, pal)
    dt_rank = cfg.ssm.dt_rank or max(1, -(-cfg.d_model // 16))
    return d_inner, dil, dt_rank


def init_mamba(key, cfg, pal: Parallel):
    d = cfg.d_model
    ds = cfg.ssm.d_state
    dc = cfg.ssm.d_conv
    d_inner, dil, dt_rank = _dims(cfg, pal)
    ks = jax.random.split(key, 8)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (dil, 1))
    return {
        "in_x": dense_init(ks[0], d, dil),                   # col-parallel
        "in_z": dense_init(ks[6], d, dil),
        "conv_w": jax.random.normal(ks[1], (dc, dil), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((dil,), jnp.float32),
        "x_proj": dense_init(ks[2], dil, dt_rank + 2 * ds),  # row-parallel -> psum
        "dt_proj": dense_init(ks[3], dt_rank, dil, scale=dt_rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (dil,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "A_log": jnp.log(a),
        "D": jnp.ones((dil,), jnp.float32),
        "out_proj": dense_init(ks[5], dil, d),               # row-parallel
    }


def _conv1d(x, w, b):
    """Depthwise causal conv. x (B, S, C), w (K, C) -> (B, S, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b.astype(out.dtype)


def _ssm_scan_chunked(u, dt, bmat, cmat, a, d_skip, h0):
    """Selective scan. u,dt: (B,S,C); bmat,cmat: (B,S,N); a: (C,N).
    Returns (y (B,S,C), h_final (B,C,N)). Chunked over S."""
    bsz, s, c = u.shape
    n = bmat.shape[-1]
    chunk = min(SCAN_CHUNK, s)
    if s % chunk:
        chunk = s
    ns = s // chunk

    da = jnp.exp(dt[..., None] * (-a))                       # (B,S,C,N) decay
    dbu = (dt * u)[..., None] * bmat[:, :, None, :]          # (B,S,C,N) input

    def chunk_body(h, inp):
        da_c, dbu_c, c_c = inp                               # (B,chunk,C,N)...

        def assoc(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b2 + a2 * b1

        aa, bb = jax.lax.associative_scan(assoc, (da_c, dbu_c), axis=1)
        h_all = aa * h[:, None] + bb                          # (B,chunk,C,N)
        y_c = jnp.einsum("bscn,bsn->bsc", h_all, c_c)
        return h_all[:, -1], y_c

    da_s = da.reshape(bsz, ns, chunk, c, n).transpose(1, 0, 2, 3, 4)
    dbu_s = dbu.reshape(bsz, ns, chunk, c, n).transpose(1, 0, 2, 3, 4)
    c_s = cmat.reshape(bsz, ns, chunk, n).transpose(1, 0, 2, 3)
    h_fin, ys = jax.lax.scan(chunk_body, h0, (da_s, dbu_s, c_s))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s, c)
    return y + u * d_skip, h_fin


def mamba_fwd(p, x, cfg, pal: Parallel, h0=None, return_state=False):
    """Full-seq forward. x (B, S/tp, d) if seq-parallel else (B, S, d).
    With return_state=True also returns the decode cache {conv, h}."""
    seq_ax = 1
    if pal.seq_parallel:
        x = all_gather_model(x, pal, axis=seq_ax)
    bsz, s, _ = x.shape
    _, dil, dt_rank = _dims(cfg, pal)
    ds = cfg.ssm.d_state
    dc = cfg.ssm.d_conv

    u_pre = x @ p["in_x"].astype(x.dtype)
    z = x @ p["in_z"].astype(x.dtype)
    u = jax.nn.silu(_conv1d(u_pre, p["conv_w"].astype(u_pre.dtype), p["conv_b"]))

    dbc = psum_model((u @ p["x_proj"].astype(u.dtype)).astype(jnp.float32), pal)
    dt_low, bmat, cmat = (dbc[..., :dt_rank], dbc[..., dt_rank:dt_rank + ds],
                          dbc[..., dt_rank + ds:])
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])  # (B,S,dil) fp32

    a = jnp.exp(p["A_log"])
    h0 = h0 if h0 is not None else jnp.zeros((bsz, dil, ds), jnp.float32)
    y, h_fin = _ssm_scan_chunked(u.astype(jnp.float32), dt, bmat, cmat, a,
                                 p["D"], h0)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(y.dtype)
    if pal.seq_parallel:
        out = psum_scatter_model(out, pal, axis=seq_ax)
    else:
        out = psum_model(out, pal)
    if return_state:
        conv_buf = jnp.zeros((bsz, dc - 1, dil), x.dtype)
        take = min(dc - 1, s)
        conv_buf = conv_buf.at[:, dc - 1 - take:].set(
            u_pre[:, s - take:].astype(conv_buf.dtype))
        return out, {"conv": conv_buf, "h": h_fin}
    return out


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg, pal: Parallel, batch: int, dtype):
    _, dil, _ = _dims(cfg, pal)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, dil), dtype),
        "h": jnp.zeros((batch, dil, cfg.ssm.d_state), jnp.float32),
    }


def mamba_decode(p, x, cache, cfg, pal: Parallel):
    """x (B, 1, d) -> (y (B, 1, d), cache). O(1) per token."""
    _, dil, dt_rank = _dims(cfg, pal)
    ds = cfg.ssm.d_state
    u = x[:, 0] @ p["in_x"].astype(x.dtype)
    z = x[:, 0] @ p["in_z"].astype(x.dtype)
    win = jnp.concatenate([cache["conv"], u[:, None]], 1)    # (B, dc, dil)
    conv = (jnp.sum(win * p["conv_w"].astype(win.dtype), 1)
            + p["conv_b"].astype(win.dtype))
    u = jax.nn.silu(conv)
    dbc = psum_model((u @ p["x_proj"].astype(u.dtype)).astype(jnp.float32), pal)
    dt_low, bmat, cmat = (dbc[..., :dt_rank], dbc[..., dt_rank:dt_rank + ds],
                          dbc[..., dt_rank + ds:])
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])
    a = jnp.exp(p["A_log"])
    da = jnp.exp(dt[..., None] * (-a))                       # (B, dil, ds)
    h = da * cache["h"] + (dt * u.astype(jnp.float32))[..., None] * bmat[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, cmat) + u.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(y.dtype))[:, None]
    out = psum_model(out, pal)
    new_cache = {"conv": win[:, 1:], "h": h}
    return out, new_cache
