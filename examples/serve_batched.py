"""Batched serving example: prefill a prompt batch, then stream greedy
decode steps with a sliding-window cache variant — exercises the decode
paths the long_500k dry-run shape lowers. The second half runs the
learning-while-serving loop (DESIGN.md §2.10): a trainer thread
publishes versioned sparse deltas over a faulty in-process channel
while the replica applies them between decode steps.

  PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.core import faults
from repro.models import (Parallel, decode_step, init_params, prefill)
from repro.serve.delta import (DeltaApplier, DeltaPublisher, FaultyChannel,
                               MemoryChannel)


def live_delta_demo(cfg, pal, params, key):
    """Trainer thread publishes, replica applies between decode steps."""
    import tempfile
    publisher = DeltaPublisher(params, k=2048)
    chan = FaultyChannel(MemoryChannel(),
                         faults.parse_channel_schedule("reorder:2,seed=7"))
    applier = DeltaApplier(params)
    versions = 24
    snap_dir = tempfile.mkdtemp(prefix="delta_snaps_")

    @jax.jit
    def train_update(p, k):
        leaves, td = jax.tree_util.tree_flatten(p)
        new = [l + (1e-3 * jax.random.normal(
            jax.random.fold_in(k, i), l.shape)).astype(l.dtype)
            for i, l in enumerate(leaves)]
        return jax.tree_util.tree_unflatten(td, new)

    # warm the jitted update + publisher top-k/scatter before racing the
    # decode loop: v1 is a zero-diff delta, harmless to apply
    jax.block_until_ready(train_update(params, key))
    chan.send(publisher.publish(params))

    def trainer():
        cur = params
        for t in range(versions):
            cur = train_update(cur, jax.random.fold_in(key, t))
            chan.send(publisher.publish(cur))
            if publisher.version % 8 == 0:
                publisher.write_snapshot(snap_dir)
            time.sleep(0.2)
        chan.flush()
        publisher.write_snapshot(snap_dir)

    B, S, new = 4, 48, 16
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, cache = jax.jit(
        lambda p, b: prefill(p, b, cfg, pal, max_seq=S + new))(
            params, {"tokens": prompt})
    dec = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, pal))
    # the in-flight stream pins the version it started on; the LIVE
    # tree advances underneath it
    pinned, pinned_v = applier.acquire()
    th = threading.Thread(target=trainer)
    th.start()   # trainer publishes while the replica decodes
    for step in range(new):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, cache = dec(pinned, cache, nxt)
        for p in chan.recv():
            applier.offer(p)
        if applier.needs_resync and applier.can_resync(snap_dir):
            applier.resync_from(snap_dir)
        m = applier.metrics()
        print(f"  decode step {step:2d}: pinned v{pinned_v}, live "
              f"v{m['param_version']}, applied {m['applied']}, "
              f"stale {m['dropped_stale']}, gaps {m['gaps_detected']}, "
              f"resyncs {m['resyncs']}")
    th.join()
    for p in chan.recv():
        applier.offer(p)
    if applier.needs_resync and applier.can_resync(snap_dir):
        applier.resync_from(snap_dir)
    print("  final delta health:", applier.metrics())


def main():
    pal = Parallel()
    key = jax.random.PRNGKey(0)
    for attn_kind, window in (("full", 0), ("sliding", 32)):
        cfg = reduced_config(get_config("granite-8b"))
        if attn_kind == "sliding":
            cfg = dataclasses.replace(cfg, attn_kind="sliding", window=window)
        params = init_params(cfg, pal, key)
        B, S, new = 4, 48, 16
        prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, b: prefill(p, b, cfg, pal, max_seq=S + new))(
                params, {"tokens": prompt})
        dec = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, pal))
        toks = []
        for _ in range(new):
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(nxt)
            logits, cache = dec(params, cache, nxt)
        dt = time.time() - t0
        cache_len = (cache["blocks"]["l0"]["k"].shape[2]
                     if "k" in cache["blocks"]["l0"] else "-")
        print(f"{attn_kind:8s} window={window:3d} cache_seq={cache_len} "
              f"decoded {new} tokens x batch {B} in {dt:.2f}s "
              f"(pos={int(cache['pos'])})")

    print("learning-while-serving (DESIGN.md §2.10): live delta apply "
          "over a reordering channel")
    cfg = reduced_config(get_config("granite-8b"))
    live_delta_demo(cfg, pal, init_params(cfg, pal, key), key)


if __name__ == "__main__":
    main()
