"""Batched serving example: prefill a prompt batch, then stream greedy
decode steps with a sliding-window cache variant — exercises the decode
paths the long_500k dry-run shape lowers.

  PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.models import (Parallel, decode_step, init_params, prefill)


def main():
    pal = Parallel()
    key = jax.random.PRNGKey(0)
    for attn_kind, window in (("full", 0), ("sliding", 32)):
        cfg = reduced_config(get_config("granite-8b"))
        if attn_kind == "sliding":
            cfg = dataclasses.replace(cfg, attn_kind="sliding", window=window)
        params = init_params(cfg, pal, key)
        B, S, new = 4, 48, 16
        prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, b: prefill(p, b, cfg, pal, max_seq=S + new))(
                params, {"tokens": prompt})
        dec = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, pal))
        toks = []
        for _ in range(new):
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(nxt)
            logits, cache = dec(params, cache, nxt)
        dt = time.time() - t0
        cache_len = (cache["blocks"]["l0"]["k"].shape[2]
                     if "k" in cache["blocks"]["l0"] else "-")
        print(f"{attn_kind:8s} window={window:3d} cache_seq={cache_len} "
              f"decoded {new} tokens x batch {B} in {dt:.2f}s "
              f"(pos={int(cache['pos'])})")


if __name__ == "__main__":
    main()
