"""Reproduce the paper's three experiments end-to-end (Figures 1-3).

  PYTHONPATH=src:. python examples/paper_validation.py [--full]

Prints the toy-example stall, the linear-regression optimality-gap table,
and the DNN accuracy comparison (synthetic stand-in for CIFAR-10 — see
DESIGN.md §1).
"""
import argparse
import sys

sys.path.insert(0, ".")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from benchmarks.paper_experiments import (fig1_toy_logistic, fig2_linreg,
                                              fig3_nn)

    print("=== Fig 1: toy logistic regression (J=2, N=2, eta=0.9) ===")
    out = fig1_toy_logistic(iters=100)
    stall = sum(1 for v in out["topk"] if abs(v - out["topk"][0]) < 1e-6)
    print(f"TOP-1 stays at the initial loss for {stall} iterations "
          f"(paper: ~50).")
    for t in (0, 5, 20, 99):
        print(f"  iter {t:3d}: dense {out['none'][t]:.4f}  "
              f"top-1 {out['topk'][t]:.4f}  regtop-1 {out['regtopk'][t]:.4f}")

    iters = 3000 if args.full else 1000
    print(f"\n=== Fig 2: linear regression, 20 workers ({iters} iters) ===")
    res = fig2_linreg(iters=iters)
    print(f"{'S':>5} {'dense':>10} {'TOP-k':>10} {'REGTOP-k':>10}")
    for S in (0.4, 0.5, 0.6):
        print(f"{S:5.1f} {res[(S, 'none')][-1]:10.2e} "
              f"{res[(S, 'topk')][-1]:10.2e} {res[(S, 'regtopk')][-1]:10.2e}")

    iters = 400 if args.full else 150
    print(f"\n=== Fig 3 analogue: CNN, N=8, S=0.001 ({iters} iters) ===")
    out = fig3_nn(iters=iters, eval_every=max(iters // 4, 1))
    for kind, accs in out.items():
        tail = "  ".join(f"@{t}: {a:.3f}" for t, a in accs)
        print(f"  {kind:8s} {tail}")


if __name__ == "__main__":
    main()
