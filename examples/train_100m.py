"""End-to-end driver: train a ~100M-parameter member of the stablelm family
for a few hundred steps with REGTOP-k sparsified gradient sync over
simulated data-parallel workers.

Full run (a few hundred steps; takes a while on CPU):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_100m.py --steps 300

Smoke (CI-speed): --steps 5 --tiny
"""
import argparse
import dataclasses
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro.configs.base import (OptimizerConfig, RunConfig, SHAPES,
                                SparsifierConfig, get_config, reduced_config)
from repro.data import lm_batch
from repro.launch.mesh import make_mesh
from repro.train.step import (build_parallel, build_train_step,
                              init_train_state)


def model_100m():
    """~100M-param member of the stablelm family (same code path)."""
    base = get_config("stablelm-3b")
    return dataclasses.replace(
        base, name="stablelm-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=12, head_dim=64, d_ff=2048, vocab_size=50304,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--sparsity", type=float, default=0.01)
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    cfg = reduced_config(get_config("stablelm-3b")) if args.tiny else model_100m()
    run = RunConfig(
        model=cfg, shape=SHAPES["train_4k"],
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=args.sparsity,
                                    mu=0.5, comm_mode="sparse"),
        optimizer=OptimizerConfig(kind="adam", lr=3e-4, warmup_steps=20,
                                  schedule="cosine", total_steps=args.steps),
    )
    mesh = make_mesh(data=4, model=2)
    pal = build_parallel(mesh)
    key = jax.random.PRNGKey(0)
    with mesh:
        params, opt_state, ef_state = init_train_state(run, mesh, pal, key)
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        print(f"{cfg.name}: {n/1e6:.1f}M params, REGTOP-k S={args.sparsity}, "
              f"sparse all-gather DP sync, ZeRO-1 Adam")
        step, _, _ = build_train_step(run, mesh, pal)
        jstep = jax.jit(step, donate_argnums=(0, 1, 2))
        t0 = time.time()
        for t in range(args.steps):
            batch = lm_batch(cfg, args.batch, args.seq, 0, t)
            params, opt_state, ef_state, m = jstep(params, opt_state,
                                                   ef_state, batch, key)
            if t % 10 == 0 or t == args.steps - 1:
                print(f"step {t:4d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['gnorm_local']):.2f} "
                      f"({time.time()-t0:.0f}s)", flush=True)
        if args.checkpoint_dir:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(args.checkpoint_dir, args.steps, params,
                            opt_state, ef_state)
            print("checkpoint saved to", args.checkpoint_dir)


if __name__ == "__main__":
    main()
