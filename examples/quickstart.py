"""Quickstart: train a reduced-config LM with REGTOP-k sparsified data
parallelism on simulated workers (8 host devices), then serve it.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/quickstart.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import (OptimizerConfig, RunConfig, SHAPES,
                                SparsifierConfig, get_config, reduced_config)
from repro.data import lm_batch
from repro.launch.mesh import make_mesh
from repro.serve.step import build_decode_step, build_prefill, serve_parallel
from repro.train.step import (build_parallel, build_train_step,
                              init_train_state)


def main():
    cfg = reduced_config(get_config("stablelm-3b"))
    run = RunConfig(
        model=cfg, shape=SHAPES["train_4k"],
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.01, mu=0.5,
                                    comm_mode="sparse"),
        optimizer=OptimizerConfig(kind="adam", lr=1e-3),
    )
    mesh = make_mesh(data=4, model=2)
    pal = build_parallel(mesh)
    key = jax.random.PRNGKey(0)

    with mesh:
        params, opt_state, ef_state = init_train_state(run, mesh, pal, key)
        step, _, _ = build_train_step(run, mesh, pal)
        jstep = jax.jit(step, donate_argnums=(0, 1, 2))
        print(f"training {cfg.name} with {run.sparsifier.kind} "
              f"(S={run.sparsifier.sparsity}, sparse all-gather comm) on "
              f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")
        for t in range(30):
            batch = lm_batch(cfg, 8, 64, 0, t)
            params, opt_state, ef_state, m = jstep(params, opt_state,
                                                   ef_state, batch, key)
            if t % 5 == 0:
                print(f"  step {t:3d} loss {float(m['loss']):.4f} "
                      f"nonzero-frac {float(m['agg_nonzero']):.4f}")

    # serve: prefill a prompt + greedy-decode a few tokens
    import dataclasses
    srun = dataclasses.replace(
        run, shape=dataclasses.replace(SHAPES["decode_32k"], seq_len=96,
                                       global_batch=8))
    spal = serve_parallel(mesh, srun, decode=True)
    with mesh:
        pre, _ = build_prefill(srun, mesh, spal)
        dec, _ = build_decode_step(srun, mesh, spal)
        prompt = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        logits, cache = jax.jit(pre)(params, {"tokens": prompt})
        toks = []
        for _ in range(8):
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(nxt)
            logits, cache = jax.jit(dec)(params, cache, nxt)
        out = jnp.concatenate(toks, 1)
        print("greedy decode (batch 8 x 8 new tokens):")
        print(out[:2])


if __name__ == "__main__":
    main()
