"""Checkpoint save/restore round-trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_step, read_manifest, restore_checkpoint,
                              save_checkpoint)


def _tree(key):
    ks = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(ks[0], (4, 5)),
        "nested": {"b": jax.random.normal(ks[1], (7,)),
                   "c": jnp.zeros((), jnp.int32)},
        "lst": [jax.random.normal(ks[2], (2, 2))],
    }


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    params = _tree(jax.random.PRNGKey(0))
    opt = {"m": jnp.arange(6.0), "step": jnp.int32(7)}
    ef = {"err": jnp.linspace(0, 1, 9)}
    save_checkpoint(d, 42, params, opt, ef)
    assert latest_step(d) == 42
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    zo = jax.tree_util.tree_map(jnp.zeros_like, opt)
    ze = jax.tree_util.tree_map(jnp.zeros_like, ef)
    p2, o2, e2 = restore_checkpoint(d, 42, z, zo, ze)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2["step"]) == 7


def test_latest_step_multiple(tmp_path):
    d = str(tmp_path)
    t = {"x": jnp.ones(3)}
    for s in (1, 5, 3):
        save_checkpoint(d, s, t, t, t)
    assert latest_step(d) == 5
    assert latest_step(str(tmp_path / "missing")) is None


def test_legacy_ef_state_migrates_to_err_prev(tmp_path):
    """Checkpoints written by the (a_prev, s_prev) fused layout restore
    into the err_prev layout via the one-shot dense multiply
    err = a_prev * (1 - s_prev) at load time (checkpoint/io.py)."""
    d = str(tmp_path)
    j = 513
    key = jax.random.PRNGKey(1)
    a_prev = jax.random.normal(key, (j,))
    s_prev = (jax.random.uniform(jax.random.fold_in(key, 1), (j,)) < 0.05
              ).astype(jnp.uint8)
    legacy_ef = {"a_prev": a_prev, "s_prev": s_prev,
                 "step": jnp.int32(9)}
    t = {"x": jnp.ones(2)}
    save_checkpoint(d, 3, t, t, legacy_ef)
    tmpl = {"err_prev": jnp.zeros((j,)), "step": jnp.int32(0)}
    _, _, ef2 = restore_checkpoint(d, 3, t, t, tmpl)
    np.testing.assert_array_equal(
        np.asarray(ef2["err_prev"]),
        np.asarray(a_prev) * (1.0 - np.asarray(s_prev, np.float32)))
    assert int(ef2["step"]) == 9


def test_current_ef_state_roundtrips_through_train_state(tmp_path):
    """New-layout fused EF state (err_prev + O(k) posterior) saves and
    restores unchanged — and a missing leaf with no legacy pair to
    migrate from is a hard error, not a silent zero-fill."""
    import pytest
    from repro.configs.base import SparsifierConfig
    from repro.core import sparsify
    d = str(tmp_path)
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.02, mu=0.5,
                           pipeline="fused")
    j = 777
    st = sparsify.init_state(cfg, j)
    out = sparsify.compress(cfg, st, jax.random.normal(
        jax.random.PRNGKey(2), (j,)))
    st = sparsify.observe_aggregate(cfg, out.state,
                                    0.5 * sparsify.dense_ghat(out, j))
    t = {"x": jnp.ones(2)}
    save_checkpoint(d, 1, t, t, st)
    z = jax.tree_util.tree_map(jnp.zeros_like, st)
    _, _, st2 = restore_checkpoint(d, 1, t, t, z)
    for k_ in st:
        np.testing.assert_array_equal(np.asarray(st[k_]),
                                      np.asarray(st2[k_]), err_msg=k_)
    bad = dict(z)
    bad["not_there"] = jnp.zeros((3,))
    with pytest.raises(KeyError):
        restore_checkpoint(d, 1, t, t, bad)


def test_param_version_stamp_roundtrip(tmp_path):
    """param_version (DESIGN.md §2.10) rides the manifest: stamped when
    given, absent for legacy checkpoints (manifest.get -> None)."""
    d = str(tmp_path)
    t = {"x": jnp.ones(3)}
    save_checkpoint(d, 10, t, t, t, param_version=37)
    assert read_manifest(d, 10)["param_version"] == 37
    save_checkpoint(d, 11, t, t, t)
    assert read_manifest(d, 11).get("param_version") is None


def test_restored_floor_rejects_predating_deltas(tmp_path):
    """A delta at/below the restored checkpoint's param_version predates
    the restored state: strict apply is a hard error, never a skip."""
    import pytest
    from repro.serve.delta import (DeltaApplier, DeltaVersionError,
                                   read_snapshot, write_snapshot)
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4))}
    write_snapshot(str(tmp_path), params, 12)
    restored, version = read_snapshot(str(tmp_path), params)
    assert version == 12
    app = DeltaApplier(restored, version=version)
    from repro.serve.delta import DeltaPayload
    for v in (3, 12):
        old = DeltaPayload.stamp(v, np.zeros(4, np.float32),
                                 np.arange(4, dtype=np.int32), 4, 32)
        with pytest.raises(DeltaVersionError, match="floor"):
            app.apply(old)
    # tolerant intake drops the same payloads on the stale counter
    assert app.offer(DeltaPayload.stamp(
        12, np.zeros(4, np.float32), np.arange(4, dtype=np.int32),
        4, 32)) == "stale"
    assert app.counters["dropped_stale"] == 1


def test_bfloat16_leaves_roundtrip(tmp_path):
    """np.savez stores ml_dtypes bfloat16 as a void dtype; restore must
    view it back through the template dtype bit-exactly."""
    d = str(tmp_path)
    key = jax.random.PRNGKey(2)
    params = {"w": jax.random.normal(key, (6, 5), jnp.bfloat16),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (9,))}
    save_checkpoint(d, 1, params, {}, {})
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _, _ = restore_checkpoint(d, 1, z, {}, {})
    assert p2["w"].dtype == jnp.bfloat16
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
