"""Checkpoint save/restore round-trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _tree(key):
    ks = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(ks[0], (4, 5)),
        "nested": {"b": jax.random.normal(ks[1], (7,)),
                   "c": jnp.zeros((), jnp.int32)},
        "lst": [jax.random.normal(ks[2], (2, 2))],
    }


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    params = _tree(jax.random.PRNGKey(0))
    opt = {"m": jnp.arange(6.0), "step": jnp.int32(7)}
    ef = {"err": jnp.linspace(0, 1, 9)}
    save_checkpoint(d, 42, params, opt, ef)
    assert latest_step(d) == 42
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    zo = jax.tree_util.tree_map(jnp.zeros_like, opt)
    ze = jax.tree_util.tree_map(jnp.zeros_like, ef)
    p2, o2, e2 = restore_checkpoint(d, 42, z, zo, ze)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2["step"]) == 7


def test_latest_step_multiple(tmp_path):
    d = str(tmp_path)
    t = {"x": jnp.ones(3)}
    for s in (1, 5, 3):
        save_checkpoint(d, s, t, t, t)
    assert latest_step(d) == 5
    assert latest_step(str(tmp_path / "missing")) is None
