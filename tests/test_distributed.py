"""Distributed correctness tests — run in SUBPROCESSES so they can set
--xla_force_host_platform_device_count without polluting the main test
process (which must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.base import (get_config, reduced_config, RunConfig,
                                SparsifierConfig, OptimizerConfig, SHAPES)
from repro.train.step import build_parallel, build_train_step, init_train_state
from repro.data import lm_batch

def make_run(arch, sp_kind="regtopk", comm="simulate", opt="adam", sparsity=0.05):
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
        sparsifier=SparsifierConfig(kind=sp_kind, sparsity=sparsity, mu=0.5,
                                    comm_mode=comm, selector="exact"),
        optimizer=OptimizerConfig(kind=opt, lr=1e-3))

def train(run, mesh_shape, steps=3, key_seed=0, fixed_batch=False):
    # fixed_batch: uniform-random token streams carry no cross-batch signal;
    # convergence assertions must overfit one batch to be meaningful
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    pal = build_parallel(mesh)
    key = jax.random.PRNGKey(key_seed)
    with mesh:
        params, opt_state, ef_state = init_train_state(run, mesh, pal, key)
        step, _, _ = build_train_step(run, mesh, pal)
        jstep = jax.jit(step)
        losses = []
        for t in range(steps):
            batch = lm_batch(run.model, 8, 64, 0, 0 if fixed_batch else t)
            params, opt_state, ef_state, m = jstep(
                params, opt_state, ef_state, batch, key)
            losses.append(float(m["loss"]))
    return losses, m
"""


def test_dp_equivalence_dense_sync():
    """dp=4 with dense sync must equal dp=1 (grad averaging is exact)."""
    out = run_py(COMMON + """
run = make_run("stablelm-3b", sp_kind="none")
l1, _ = train(run, (1, 1))
l4, _ = train(run, (4, 1))
assert np.allclose(l1, l4, rtol=2e-4), (l1, l4)
print("OK", l1[-1])
""")
    assert "OK" in out


def test_sparse_comm_equals_simulate():
    """allgather(values, idx) + scatter-add == masked dense all-reduce."""
    out = run_py(COMMON + """
r1 = make_run("stablelm-3b", comm="simulate")
r2 = make_run("stablelm-3b", comm="sparse")
l1, _ = train(r1, (4, 2), steps=4)
l2, _ = train(r2, (4, 2), steps=4)
assert np.allclose(l1, l2, rtol=1e-4), (l1, l2)
print("OK", l1, l2)
""")
    assert "OK" in out


@pytest.mark.parametrize("arch", ["stablelm-3b", "jamba-v0.1-52b",
                                  "xlstm-125m", "deepseek-v2-lite-16b"])
def test_tp_matches_single_device(arch):
    """Sharded (2,4) forward loss == single-device on reassembled params."""
    out = run_py(COMMON + f"""
from repro.models import Parallel, loss_fn
run = make_run("{arch}", sp_kind="none", opt="sgd")
run = dataclasses.replace(run, optimizer=OptimizerConfig(kind="sgd", lr=1e-2))
mesh = jax.make_mesh((2, 4), ("data", "model"))
pal = build_parallel(mesh)
key = jax.random.PRNGKey(0)
with mesh:
    params, opt_state, ef_state = init_train_state(run, mesh, pal, key)
    step, _, _ = build_train_step(run, mesh, pal)
    batch = lm_batch(run.model, 8, 64, 0, 0)
    p2, o2, e2, m = jax.jit(step)(params, opt_state, ef_state, batch, key)
host = jax.tree_util.tree_map(lambda x: jnp.asarray(np.array(x)), params)
lref, _ = jax.jit(lambda p, b: loss_fn(p, b, run.model, Parallel()))(host, batch)
d = abs(float(m["loss"]) - float(lref))
assert d < 5e-3, d
# one-step param update vs reference gradient
gref = jax.jit(jax.grad(lambda p: loss_fn(p, batch, run.model, Parallel())[0]))(host)
import jax.flatten_util as fu
v_ref = fu.ravel_pytree(jax.tree_util.tree_map(lambda p, g: p - 0.01*g, host, gref))[0]
v_new = fu.ravel_pytree(jax.tree_util.tree_map(
    lambda x: jnp.asarray(np.array(x)), p2))[0]
du = float(jnp.max(jnp.abs(v_ref - v_new)))
assert du < 5e-4, du
print("OK", d, du)
""")
    assert "OK" in out


def test_bucketed_sparse_comm_matches_flat():
    """num_buckets > 1 chunked all-gather + scatter-add == the monolithic
    sparse path AND the simulate path, with REAL axis size > 1 (rank
    stacking, replicated padded tails)."""
    out = run_py(COMMON + """
run_sim = make_run("stablelm-3b", comm="simulate")
run_b1 = make_run("stablelm-3b", comm="sparse")
run_b4 = dataclasses.replace(run_b1, sparsifier=dataclasses.replace(
    run_b1.sparsifier, pipeline="fused", num_buckets=4))
l_sim, _ = train(run_sim, (4, 2), steps=4)
l_b1, _ = train(run_b1, (4, 2), steps=4)
l_b4, m = train(run_b4, (4, 2), steps=4)
assert np.allclose(l_b1, l_b4, rtol=1e-4), (l_b1, l_b4)
assert np.allclose(l_sim, l_b4, rtol=1e-4), (l_sim, l_b4)
assert 0 < float(m["agg_nonzero"]) < 0.5
print("OK", l_b1, l_b4)
""")
    assert "OK" in out


def test_regtopk_trains_distributed():
    out = run_py(COMMON + """
run = make_run("stablelm-3b", sp_kind="regtopk", comm="sparse", sparsity=0.02)
losses, m = train(run, (4, 2), steps=10, fixed_batch=True)
assert losses[-1] < losses[0], losses
assert 0 < float(m["agg_nonzero"]) < 0.3
print("OK", losses[0], losses[-1])
""")
    assert "OK" in out


def test_serve_decode_sharded_batch():
    """decode step under shard_map, batch over data + heads over model."""
    out = run_py(COMMON + """
from repro.serve.step import build_decode_step, build_prefill, serve_parallel
from repro.models import init_params, prefill as mprefill, decode_step as mdecode
from repro.models import Parallel
from jax.sharding import PartitionSpec as P
from repro.models.specs import param_specs

run = make_run("granite-8b", sp_kind="none")
run = dataclasses.replace(run, shape=dataclasses.replace(
    SHAPES["decode_32k"], seq_len=64, global_batch=8))
mesh = jax.make_mesh((4, 2), ("data", "model"))
pal = serve_parallel(mesh, run, decode=True)
assert pal.cache_seq_axis is None
with mesh:
    tmpl = __import__("repro.train.step", fromlist=["x"]).abstract_params(run, pal)
    pspecs = param_specs(tmpl)
    def init_fn(k):
        kf = jax.random.fold_in(k, jax.lax.axis_index("model"))
        from repro.models.specs import replicated_mask
        pu = init_params(run.model, pal, k)
        pf = init_params(run.model, pal, kf)
        return jax.tree_util.tree_map(lambda u, f, r: u if r else f, pu, pf,
                                      replicated_mask(pu))
    params = jax.jit(jax.shard_map(
        init_fn, mesh=mesh, in_specs=(P(),), out_specs=pspecs,
        check_vma=False))(jax.random.PRNGKey(0))
    pre, _ = build_prefill(run, mesh, pal)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (8, 63), 0, run.model.vocab_size)}
    logits, cache = jax.jit(pre)(params, batch)
    dec, _ = build_decode_step(run, mesh, pal)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(dec)(params, cache, tok)
    assert logits2.shape[0] == 8
    assert not bool(jnp.isnan(logits2).any())
    # reference: single-device
    host = jax.tree_util.tree_map(lambda x: jnp.asarray(np.array(x)), params)
    pal1 = Parallel()
    lg1, c1 = mprefill(host, batch, run.model, pal1, max_seq=64)
    lg2, _ = mdecode(host, c1, tok, run.model, pal1)
    scale = float(jnp.max(jnp.abs(lg2))) + 1e-6
    err = float(jnp.max(jnp.abs(np.array(logits2)[:, :run.model.vocab_size] -
                                np.array(lg2)[:, :run.model.vocab_size]))) / scale
    assert err < 5e-3, err
print("OK")
""")
    assert "OK" in out


def test_decode_context_parallel_cache():
    """batch=1 decode: cache seq-sharded over data with LSE merge — must
    match the single-device decode."""
    out = run_py(COMMON + """
from repro.serve.step import build_decode_step, serve_parallel, decode_cache_specs
from repro.models import (init_params, prefill as mprefill,
                          decode_step as mdecode, Parallel)
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.specs import param_specs

run = make_run("granite-8b", sp_kind="none")
run = dataclasses.replace(run, shape=dataclasses.replace(
    SHAPES["long_500k"], seq_len=64, global_batch=1))
mesh = jax.make_mesh((4, 2), ("data", "model"))
pal = serve_parallel(mesh, run, decode=True)
assert pal.cache_seq_axis == "data"
# single-device reference prefill builds the cache; shard it onto the mesh
pal1 = Parallel()
params1 = init_params(run.model, pal1, jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(
    jax.random.PRNGKey(1), (1, 48), 0, run.model.vocab_size)}
lg1, c1 = mprefill(params1, batch, run.model, pal1, max_seq=64)
tok = jnp.argmax(lg1, -1)[:, None].astype(jnp.int32)
lg_ref, _ = mdecode(params1, c1, tok, run.model, pal1)

# sharded: tp=1 on model axis? use (4,1) mesh to isolate ctx-parallel over data
mesh = jax.make_mesh((4, 1), ("data", "model"))
pal = serve_parallel(mesh, run, decode=True)
with mesh:
    dec, (pspecs, cspecs, tok_spec) = build_decode_step(run, mesh, pal)
    cache_sharded = jax.device_put(c1, jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cspecs))
    params_sharded = jax.device_put(params1, NamedSharding(mesh, P()))
    lg2, _ = jax.jit(dec)(params_sharded, cache_sharded, tok)
err = (float(jnp.max(jnp.abs(np.array(lg2) - np.array(lg_ref))))
       / (float(jnp.max(jnp.abs(lg_ref))) + 1e-6))
assert err < 5e-3, err
print("OK", err)
""")
    assert "OK" in out


def test_multipod_mesh_small():
    """3-axis (pod, data, model) mesh trains and matches 2-axis semantics."""
    out = run_py(COMMON + """
run = make_run("stablelm-3b", sp_kind="topk", comm="sparse", sparsity=0.1)
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
pal3 = build_parallel(mesh3)
key = jax.random.PRNGKey(0)
with mesh3:
    params, opt_state, ef_state = init_train_state(run, mesh3, pal3, key)
    step, _, _ = build_train_step(run, mesh3, pal3)
    jstep = jax.jit(step)
    losses = []
    for t in range(10):
        batch = lm_batch(run.model, 8, 64, 0, t)
        params, opt_state, ef_state, m = jstep(params, opt_state, ef_state, batch, key)
        losses.append(float(m["loss"]))
import math
assert all(math.isfinite(l) for l in losses)
assert min(losses[5:]) < losses[0], losses
print("OK", losses)
""")
    assert "OK" in out


def test_elastic_fault_injection_trains():
    """30% iid worker drop (decayed EF) still overfits the fixed batch,
    within tolerance of the full-participation run, and the step metrics
    report the fluctuating active count."""
    out = run_py(COMMON + """
import math
run = make_run("stablelm-3b", sp_kind="regtopk", comm="sparse", sparsity=0.05)
run = dataclasses.replace(run, sparsifier=dataclasses.replace(
    run.sparsifier, err_decay=0.9))
run_f = dataclasses.replace(run, fault_schedule="iid:0.3,seed=0")
l_full, _ = train(run, (4, 2), steps=12, fixed_batch=True)
l_drop, m = train(run_f, (4, 2), steps=12, fixed_batch=True)
assert all(math.isfinite(l) for l in l_drop), l_drop
assert l_drop[-1] < l_drop[0], l_drop
# convergence contract: the faulted run's progress stays within 35% of
# the full-participation run's progress on the same overfit batch
prog_full = l_full[0] - l_full[-1]
prog_drop = l_drop[0] - l_drop[-1]
assert prog_full > 0, l_full
assert prog_drop > 0.65 * prog_full, (l_full, l_drop)
assert 0 < float(m["n_active"]) <= 4
print("OK", prog_full, prog_drop)
""")
    assert "OK" in out


def test_elastic_nonfinite_payload_guard():
    """A worker whose gradient goes NaN is dropped for the step by the
    payload guard: the aggregate stays finite, n_active excludes it, and
    the health counter reports exactly one drop."""
    out = run_py(COMMON + """
from jax.sharding import PartitionSpec as P
from repro.core import aggregate as agg
from repro.core import sparsify
cfg = SparsifierConfig(kind="topk", sparsity=0.02, comm_mode="sparse",
                       selector="exact", pipeline="fused")
j = 4096
mesh = jax.make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, j), jnp.float32)
g = g.at[3].set(jnp.nan)                       # worker 3 poisoned
def body(gw):
    gw = gw.reshape(-1)
    state = sparsify.init_state(cfg, j)
    g_agg, _, stats = agg.GradientSync(cfg, ("data",))(
        state, gw, participate=jnp.ones((), jnp.bool_), with_stats=True)
    return g_agg, stats["n_active"], stats["dropped_nonfinite"]
with mesh:
    g_agg, na, dr = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P("data"),), out_specs=(P(), P(), P()),
        check_vma=False))(g)
assert np.isfinite(np.array(g_agg)).all()
assert float(np.ravel(na)[0]) == 7.0, na
assert float(np.ravel(dr)[0]) == 1.0, dr
print("OK")
""")
    assert "OK" in out


def test_elastic_combine_bucket_invariant_8dev():
    """Partial participation on a REAL 8-way axis: the chunked elastic
    all-gather combine (num_buckets 1 vs 4) and both combine modes are
    bucketing-invariant."""
    out = run_py(COMMON + """
from jax.sharding import PartitionSpec as P
from repro.core import aggregate as agg
from repro.core import sparsify
j = 4096
mesh = jax.make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, j), jnp.float32)
absent = np.array([0, 0, 1, 0, 0, 1, 0, 0], bool)      # workers 2,5 out
def make(combine, nb):
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.02, mu=0.5,
                           comm_mode="sparse", selector="exact",
                           pipeline="fused", num_buckets=nb,
                           combine=combine, err_decay=0.9)
    def body(gw, pw):
        state = sparsify.init_state(cfg, j)
        g_agg, _ = agg.GradientSync(cfg, ("data",))(
            state, gw.reshape(-1), participate=pw.reshape(()))
        return g_agg
    return jax.jit(jax.shard_map(body, mesh=mesh,
                                 in_specs=(P("data"), P("data")),
                                 out_specs=P(), check_vma=False))
p = jnp.asarray(~absent)
with mesh:
    for combine in ("mean", "support"):
        a1 = np.array(make(combine, 1)(g, p))
        a4 = np.array(make(combine, 4)(g, p))
        np.testing.assert_array_equal(a1, a4)
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_elastic_long_horizon_convergence():
    """Long-horizon fault-injection contract (CI fault-injection job):
    40 fixed-batch steps under 30% iid drop land within 25% of the
    full-participation loss."""
    out = run_py(COMMON + """
run = make_run("stablelm-3b", sp_kind="regtopk", comm="sparse", sparsity=0.05)
run = dataclasses.replace(run, sparsifier=dataclasses.replace(
    run.sparsifier, err_decay=0.9))
run_f = dataclasses.replace(run, fault_schedule="iid:0.3,seed=1")
l_full, _ = train(run, (4, 2), steps=40, fixed_batch=True)
l_drop, _ = train(run_f, (4, 2), steps=40, fixed_batch=True)
prog_full = l_full[0] - l_full[-1]
prog_drop = l_drop[0] - l_drop[-1]
assert prog_full > 0, l_full
assert prog_drop > 0.75 * prog_full, (l_full[-1], l_drop[-1])
print("OK", l_full[-1], l_drop[-1])
""", timeout=1800)
    assert "OK" in out


def test_delta_apply_sharded_with_psum_health_guard():
    """§2.10 on a real 8-way mesh: versioned deltas scatter into SHARDED
    replica params bit-identically to the host-replica reference (and
    keep their shardings); the payload_health guard evaluates the same
    verdict on every rank and psums into a global health counter; a
    pinned (acquire'd) tree stays bit-unchanged while the live one
    advances."""
    out = run_py(COMMON + """
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.serve.delta import DeltaApplier, DeltaPublisher, payload_health

mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
host = {"w": jax.random.normal(key, (16, 8)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (64,))}

def walk(tree, t):
    leaves, td = jax.tree_util.tree_flatten(tree)
    k = jax.random.PRNGKey(100 + t)
    return jax.tree_util.tree_unflatten(td, [
        l + 0.1 * jax.random.normal(jax.random.fold_in(k, i), l.shape)
        for i, l in enumerate(leaves)])

with mesh:
    sharded = {
        "w": jax.device_put(host["w"], NamedSharding(mesh, P("model", None))),
        "b": jax.device_put(host["b"], NamedSharding(mesh, P("data"))),
    }
    pub = DeltaPublisher(host, k=24)
    app_host = DeltaApplier(host)
    app_shard = DeltaApplier(sharded)
    cur = host
    for t in range(4):
        cur = walk(cur, t)
        p = pub.publish(cur)
        assert app_host.offer(p) == "applied"
        assert app_shard.offer(p) == "applied"
    pinned, pv = app_shard.acquire()
    frozen = np.array(pinned["w"], copy=True)
    for t in range(4, 8):
        cur = walk(cur, t)
        p = pub.publish(cur)
        app_host.offer(p); app_shard.offer(p)
    # sharded replica == host replica, bit for bit, shardings kept
    for a, b in zip(jax.tree_util.tree_leaves(app_host.params),
                    jax.tree_util.tree_leaves(app_shard.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert app_shard.params["w"].sharding.spec == P("model", None), \
        app_shard.params["w"].sharding
    # the pinned tree never moved
    np.testing.assert_array_equal(np.asarray(pinned["w"]), frozen)
    assert app_shard.version == 8 and pv == 4

    # psum'd intake guard: flip one bit, every rank sees 'corrupt',
    # global counter = 1 drop x 8 ranks
    bad = np.array(p.values, np.float32)
    bad.view(np.uint32)[0] ^= np.uint32(1 << 9)
    def guard(vals, idx):
        ok, corrupt, nonfinite = payload_health(
            vals, idx, jnp.uint32(p.checksum), p.version, p.count, p.j)
        one = lambda b: jax.lax.psum(
            jnp.where(b, 1, 0), ("data", "model"))
        return one(corrupt), one(nonfinite), one(ok)
    c, nf, ok = jax.jit(jax.shard_map(
        guard, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P(), P()),
        check_vma=False))(jnp.asarray(bad), jnp.asarray(p.indices))
    assert int(np.ravel(c)[0]) == 8 and int(np.ravel(nf)[0]) == 0
    c2, nf2, ok2 = jax.jit(jax.shard_map(
        guard, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P(), P()),
        check_vma=False))(jnp.asarray(p.values), jnp.asarray(p.indices))
    assert int(np.ravel(ok2)[0]) == 8 and int(np.ravel(c2)[0]) == 0
print("OK")
""")
    assert "OK" in out
