"""Unit + property tests for the core sparsification library (the paper)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st   # hypothesis or skip-stubs

from repro.configs.base import SparsifierConfig
from repro.core import select, sparsify
from repro.core.aggregate import comm_bytes_per_step


def _cfg(kind="topk", **kw):
    kw.setdefault("selector", "exact")
    return SparsifierConfig(kind=kind, **kw)


class TestSelect:
    def test_exact_mask_counts(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=1000))
        for k in (1, 10, 500, 1000):
            m = select.topk_mask_exact(x, k)
            assert int(m.sum()) == k

    def test_exact_mask_selects_largest(self):
        x = jnp.asarray([0.1, -5.0, 2.0, 0.0, 3.0])
        m = select.topk_mask_exact(x, 2)
        assert m.tolist() == [0, 1, 0, 0, 1]

    def test_histogram_brackets_k(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=20_000) * np.exp(rng.normal(size=20_000)))
        for k in (20, 200, 2000):
            m = select.topk_mask(x, k, "histogram")
            n = int(m.sum())
            assert n >= k
            assert n <= k * 1.2 + 32   # at most one bin of over-selection

    def test_scale_invariance(self):
        x = jnp.asarray(np.random.default_rng(2).normal(size=512))
        m1 = select.topk_mask_exact(x, 32)
        m2 = select.topk_mask_exact(4.0 * x, 32)
        assert (m1 == m2).all()


class TestErrorFeedback:
    @pytest.mark.parametrize("kind", ["topk", "regtopk", "dgc", "thresholdk"])
    def test_ef_invariant(self, kind):
        """a^t == ghat + eps^{t+1} (error feedback conserves mass)."""
        cfg = _cfg(kind, sparsity=0.05, mu=0.5)
        j = 400
        st_ = sparsify.init_state(cfg, j)
        key = jax.random.PRNGKey(0)
        for t in range(4):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            mom = st_.get("mom")
            out = sparsify.compress(cfg, st_, g, key=key)
            if kind == "dgc":
                a = st_["err"] + (cfg.momentum * mom + g)
            else:
                a = st_["err"] + g
            np.testing.assert_allclose(np.asarray(a),
                                       np.asarray(out.ghat + out.state["err"]),
                                       rtol=1e-6, atol=1e-6)
            st_ = sparsify.observe_aggregate(cfg, out.state, out.ghat)

    def test_regtopk_reduces_to_topk_mu_small(self):
        """mu -> 0 => tanh(|1+Delta|/mu) -> 1 (a.e.) => same mask as TOP-k."""
        j, k = 300, 15
        key = jax.random.PRNGKey(1)
        cfg_t = _cfg("topk", k=k)
        cfg_r = _cfg("regtopk", k=k, mu=1e-6, Q=0.0)
        st_t = sparsify.init_state(cfg_t, j)
        st_r = sparsify.init_state(cfg_r, j)
        for t in range(5):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            ot = sparsify.compress(cfg_t, st_t, g)
            orr = sparsify.compress(cfg_r, st_r, g)
            assert (ot.mask == orr.mask).all(), f"step {t}"
            agg = 0.5 * (ot.ghat + orr.ghat)
            st_t = sparsify.observe_aggregate(cfg_t, ot.state, agg)
            st_r = sparsify.observe_aggregate(cfg_r, orr.state, agg)

    def test_regtopk_damps_cancelling_entry(self):
        """Paper §3.2 discussion case (2): entries that cancel after
        aggregation get Delta = -1 and are damped to zero next round."""
        cfg = _cfg("regtopk", k=1, mu=0.5)
        j = 4
        # two workers, first entry large but opposite signs
        g1 = jnp.asarray([10.0, 1.0, 0.1, 0.1])
        g2 = jnp.asarray([-10.0, 1.0, 0.1, 0.1])
        states = [sparsify.init_state(cfg, j) for _ in range(2)]
        g_agg, states = sparsify.sparsified_round(cfg, states, [g1, g2])
        assert float(jnp.abs(g_agg).max()) == 0.0   # cancels at t=0 (TOP-k)
        g_agg, states = sparsify.sparsified_round(cfg, states, [g1, g2])
        # REGTOP-k now selects entry 1 (constructive), not entry 0
        assert float(g_agg[1]) > 0.0
        assert float(g_agg[0]) == 0.0

    def test_randk_mask_size(self):
        cfg = _cfg("randk", k=7)
        st_ = sparsify.init_state(cfg, 100)
        out = sparsify.compress(cfg, st_, jnp.ones(100),
                                key=jax.random.PRNGKey(0))
        assert int(out.mask.sum()) == 7


@settings(max_examples=25, deadline=None)
@given(
    j=st.integers(16, 400),
    sp=st.floats(0.01, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_topk_exact_k_and_ef(j, sp, seed):
    cfg = _cfg("topk", sparsity=sp)
    k = sparsify.resolve_k(cfg, j)
    g = jax.random.normal(jax.random.PRNGKey(seed), (j,))
    st_ = sparsify.init_state(cfg, j)
    out = sparsify.compress(cfg, st_, g)
    assert int(out.mask.sum()) == k
    np.testing.assert_allclose(np.asarray(out.ghat + out.state["err"]),
                               np.asarray(g), rtol=1e-5, atol=1e-6)
    # ghat entries are exactly a*mask
    assert float(jnp.abs(out.ghat * (1 - out.mask)).max()) == 0.0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 6))
def test_property_regtopk_round_deterministic_and_conservative(seed, n):
    """Multi-worker round: aggregated gradient only contains selected
    entries; state step counters advance; permuting workers permutes
    nothing (aggregation is symmetric)."""
    j, k = 64, 5
    cfg = _cfg("regtopk", k=k, mu=0.7)
    key = jax.random.PRNGKey(seed)
    grads = [jax.random.normal(jax.random.fold_in(key, i), (j,))
             for i in range(n)]
    states = [sparsify.init_state(cfg, j) for _ in range(n)]
    agg1, st1 = sparsify.sparsified_round(cfg, states, grads)
    agg2, _ = sparsify.sparsified_round(
        cfg, list(reversed(states)), list(reversed(grads)))
    np.testing.assert_allclose(np.asarray(agg1), np.asarray(agg2), rtol=1e-6)
    assert int(jnp.sum(agg1 != 0)) <= n * k


def test_comm_volume_model():
    cfg = _cfg("topk", sparsity=0.001, comm_mode="sparse")
    j, n = 10_000_000, 16
    v = comm_bytes_per_step(cfg, j, n)
    assert v["ratio"] < 0.05          # >20x reduction at S=0.1%
    assert v["bytes"] == n * v["k"] * 8
