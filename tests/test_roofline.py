"""Roofline machinery: HLO parser on a synthetic module + real compiled
module; term computation."""
import jax
import jax.numpy as jnp

from repro.roofline.analysis import HW_V5E, model_flops, roofline_terms
from repro.roofline.hlo_parser import analyze_hlo

SYNTH = """
HloModule test

%region_body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128] get-tuple-element(%p), index=1
  %ag = f32[32,128]{1,0} all-gather(%x), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %w = f32[128,128]{1,0} constant({...})
  %y = f32[32,128]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%y), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%region_body
  ROOT %t = (s32[], f32[8,128]) tuple(%i, %rs)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main.1 (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128] parameter(0)
  %init = (s32[], f32[8,128]) tuple(%a, %a)
  %w1 = (s32[], f32[8,128]) while(%init), condition=%cond, body=%region_body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,128] get-tuple-element(%w1), index=1
}
"""


def test_parser_loop_multiplier_and_collectives():
    out = analyze_hlo(SYNTH, 4)
    # dot per iter: 2 * (32*128) * 128 = 1,048,576 flops; x10 loops
    assert out["flops"] == 10 * 2 * 32 * 128 * 128
    # all-gather out 32*128*4B=16384: wire = 16384*3/4; x10
    assert abs(out["collectives"]["all-gather"] - 10 * 16384 * 0.75) < 1
    # reduce-scatter out 8*128*4=4096: wire = 4096*3; x10
    assert abs(out["collectives"]["reduce-scatter"] - 10 * 4096 * 3) < 1
    assert out["unknown_trip_loops"] == 0


def test_parser_on_real_compiled_module():
    """Compile a scanned 2x matmul and check the trip-count multiplication
    against the analytic dot count."""
    def f(x, ws):
        def body(c, w_):
            return c @ w_, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.zeros((32, 64))
    ws = jnp.zeros((6, 64, 64))
    compiled = jax.jit(f).lower(x, ws).compile()
    out = analyze_hlo(compiled.as_text(), 1)
    expect = 6 * 2 * 32 * 64 * 64
    assert abs(out["flops"] - expect) / expect < 0.05, out["flops"]


def test_roofline_terms_and_dominance():
    rec = {
        "mesh": {"data": 16, "model": 16},
        "kind": "train", "shape": "train_4k",
        "active_params": 3_000_000_000,
        "flops": 1e14, "bytes_accessed": 1e12,
        "collective_bytes": {"total": 1e11},
        "hlo_flops": 1e14, "hlo_bytes": 8e11,
        "hlo_collective_wire_bytes": 2e11,
    }
    t = roofline_terms(rec, HW_V5E)
    assert t["compute_s"] > 0 and t["memory_s"] > 0 and t["collective_s"] > 0
    assert t["dominant"] == "collective"      # 2e11/50e9 = 4s dominates
    mf = model_flops("train", 3e9, 256, 4096)
    assert t["model_flops"] == mf
    assert 0 < t["useful_ratio"]


def test_model_flops_kinds():
    assert model_flops("train", 1e9, 8, 128) == 6e9 * 8 * 128
    assert model_flops("prefill", 1e9, 8, 128) == 2e9 * 8 * 128
    assert model_flops("decode", 1e9, 8, 128) == 2e9 * 8


def test_bucketed_collective_overlap_term():
    from repro.roofline.analysis import pipelined_overlap_s
    # B=1 serializes; large B converges to max(t_coll, t_local)
    assert pipelined_overlap_s(4.0, 1.0, 1) == 5.0
    assert pipelined_overlap_s(4.0, 1.0, 4) == 4.25
    assert abs(pipelined_overlap_s(4.0, 1.0, 1000) - 4.0) < 0.01
    assert pipelined_overlap_s(1.0, 4.0, 4) == pipelined_overlap_s(4.0, 1.0, 4)
    rec = {
        "mesh": {"data": 16, "model": 16},
        "kind": "train", "shape": "train_4k",
        "active_params": 3_000_000_000,
        "flops": 1e14, "bytes_accessed": 1e12,
        "collective_bytes": {"total": 1e11},
        "hlo_flops": 1e14, "hlo_bytes": 8e11,
        "hlo_collective_wire_bytes": 2e11,
    }
    flat = roofline_terms(rec, HW_V5E)
    assert "collective_exposed_s" not in flat
    t = roofline_terms(dict(rec, num_buckets=8), HW_V5E)
    # exposed time: strictly more than the pure wire term (one combine
    # chunk sticks out), strictly less than full serialization
    assert t["collective_s"] < t["collective_exposed_s"]
    assert t["collective_exposed_s"] < \
        t["collective_s"] + 2e11 / HW_V5E.hbm_bw
    assert t["num_buckets"] == 8
    # the three-term lower bound is unchanged by the diagnostic
    assert t["step_time_lb_s"] == flat["step_time_lb_s"]
