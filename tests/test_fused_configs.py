"""Contract suite for the capability-dispatched fused configs
(DESIGN.md §2.5): histogram-selector threshold selection, bf16 error
feedback, randk / thresholdk, auto-tuned num_buckets, and the explicit
sparse->simulate degrade.

Contracts (not all are bit-parity):

- selector="exact" configs stay BIT-identical to the reference exact
  selector for every num_buckets including auto (np.testing
  assert_array_equal, no allclose).
- selector="histogram": tau = key_bin_edge(exact k-th |score|) (== the
  sweep-1 bit-pattern histogram threshold), selected count in
  [k, hist_capacity(k, j)], selection is a superset of the exact top-k,
  packed pairs fixed-size with inert pads.
- ef_dtype="bfloat16": exact-k counts, selection/value drift vs the
  fp32 reference bounded by bf16 rounding (documented tolerances).
- comm_mode="sparse" configs without packed pairs warn once and degrade
  to simulate, queryably (effective_comm_mode).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import select, sparsify
from repro.core import aggregate as agg
from repro.kernels.compress import kernel as ck
from repro.kernels.compress import ops as cops
from repro.kernels.compress import ref as cref
from repro.kernels.compress.dispatch import (
    FUSED_EF_DTYPES,
    FUSED_KINDS,
    FUSED_SELECTORS,
    dispatch,
    effective_comm_mode,
    hist_capacity,
    packed_len,
)

BF16_EPS = 2.0 ** -8          # bf16 mantissa rounding unit


def _cfg(kind, **kw):
    kw.setdefault("selector", "exact")
    kw.setdefault("pipeline", "fused")
    return SparsifierConfig(kind=kind, **kw)


class TestDispatchTable:
    def test_full_matrix_is_fused(self):
        """No config in the advertised capability matrix falls back."""
        for kind in FUSED_KINDS:
            for sel in FUSED_SELECTORS:
                for ef in FUSED_EF_DTYPES:
                    cfg = _cfg(kind, selector=sel, ef_dtype=ef,
                               sparsity=0.02)
                    d = dispatch(cfg)
                    assert d.path == "fused", (kind, sel, ef, d.reason)
                    assert d.reason == ""
                    assert d.packs_pairs

    def test_reference_reasons_are_queryable(self):
        for cfg, frag in [
            (_cfg("topk", pipeline="reference"), "pipeline"),
            (_cfg("sketchtopk", pipeline="reference"), "pipeline"),
            (_cfg("globaltopk"), "kind"),
            (_cfg("topk", selector="histogram_kernel"), "selector"),
            (_cfg("topk", ef_dtype="float16"), "ef_dtype"),
        ]:
            d = dispatch(cfg)
            assert d.path == "reference"
            assert frag in d.reason, (d.reason, frag)

    def test_sketchtopk_dispatch(self):
        """sketchtopk registers in the capability table — fused when the
        sweep-1 encode serves it, queryable reasons otherwise, and the
        shared-mask wire contract on BOTH pipelines (DESIGN.md §2.9)."""
        d = dispatch(_cfg("sketchtopk"))
        assert d.path == "fused" and d.reason == ""
        assert d.selection == "sketch" and d.wire == "values"
        assert not d.packs_pairs          # no index list on the wire
        for cfg, frag in [
            (_cfg("sketchtopk", selector="histogram_kernel"), "selector"),
            (_cfg("sketchtopk", ef_dtype="float16"), "ef_dtype"),
        ]:
            d = dispatch(cfg)
            assert d.path == "reference"
            assert frag in d.reason, (d.reason, frag)
            assert d.selection == "sketch" and d.wire == "values"
        # shared mask -> packed payload is exactly k values
        cfg = _cfg("sketchtopk", sparsity=0.01)
        assert packed_len(cfg, 4096) == sparsify.resolve_k(cfg, 4096)

    def test_effective_comm_mode(self):
        sparse = dict(comm_mode="sparse")
        assert effective_comm_mode(_cfg("topk", **sparse)) == "sparse"
        assert effective_comm_mode(
            _cfg("topk", selector="histogram", **sparse)) == "sparse"
        # reference histogram packs nothing -> explicit degrade
        assert effective_comm_mode(_cfg(
            "topk", selector="histogram", pipeline="reference",
            **sparse)) == "simulate"
        assert effective_comm_mode(_cfg("none", **sparse)) == "dense"
        assert effective_comm_mode(_cfg("sketchtopk", **sparse)) == "sparse"
        assert effective_comm_mode(_cfg("topk", comm_mode="simulate")) == \
            "simulate"

    def test_reference_regtopk_sparse_state_packs(self):
        """regtopk state_format="sparse" packs exact-k pairs on the
        reference path REGARDLESS of selector (its O(k) layout selects
        via topk_indices unconditionally) — the table must report the
        sparse comm it actually runs, not a degrade."""
        cfg = SparsifierConfig(kind="regtopk", sparsity=0.01, mu=0.5,
                               state_format="sparse", selector="histogram",
                               comm_mode="sparse", pipeline="reference")
        assert dispatch(cfg).packs_pairs
        assert effective_comm_mode(cfg) == "sparse"
        j = 2_048
        out = sparsify.compress(cfg, sparsify.init_state(cfg, j),
                                jax.random.normal(jax.random.PRNGKey(0),
                                                  (j,)))
        assert out.values is not None
        assert out.values.shape == (sparsify.resolve_k(cfg, j),)

    def test_packed_len(self):
        j = 10_000
        cfg = _cfg("topk", sparsity=0.02)
        k = sparsify.resolve_k(cfg, j)
        assert packed_len(cfg, j) == k
        cfg_h = dataclasses.replace(cfg, selector="histogram")
        assert packed_len(cfg_h, j) == hist_capacity(k, j) > k
        # reference histogram packs k-sized nothing; packed_len reports k
        # (the fixed-count baseline) and packs_pairs=False carries the truth
        cfg_rh = dataclasses.replace(cfg_h, pipeline="reference")
        assert not dispatch(cfg_rh).packs_pairs

    def test_comm_bytes_uses_effective_mode(self):
        cfg = _cfg("topk", sparsity=0.001, selector="histogram",
                   pipeline="reference", comm_mode="sparse")
        v = agg.comm_bytes_per_step(cfg, 1_000_000, 8)
        assert v["effective_comm_mode"] == "simulate"
        assert v["ratio"] == 1.0
        cfg_f = dataclasses.replace(cfg, pipeline="fused")
        vf = agg.comm_bytes_per_step(cfg_f, 1_000_000, 8)
        assert vf["effective_comm_mode"] == "sparse"
        assert vf["bytes"] == 8 * vf["packed_len"] * 8


class TestFusedHistogram:
    """Threshold-selection contract: tau at the bit-pattern bin edge of
    the exact k-th |score|, count in [k, hist_capacity], superset of the
    exact top-k, fixed-size packing with inert pads."""

    @pytest.mark.parametrize("kind", ["topk", "dgc", "thresholdk"])
    def test_contract_multi_step(self, kind):
        j = 12_345
        cfg = _cfg(kind, sparsity=0.02, selector="histogram")
        k = sparsify.resolve_k(cfg, j)
        kcap = hist_capacity(k, j)
        st = sparsify.init_state(cfg, j)
        key = jax.random.PRNGKey(0)
        for t in range(4):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            out = sparsify.compress(cfg, st, g)
            mask = np.asarray(sparsify.dense_mask(out, j)).astype(bool)
            n = int(mask.sum())
            assert k <= n <= kcap, (t, n)
            # superset of the exact top-k of the same score (err_prev is
            # the one J-sized state vector — a = err_prev + g)
            if kind == "dgc":
                score = np.asarray(st["err_prev"]
                                   + (cfg.momentum * st["mom"] + g))
            else:
                score = np.asarray(st["err_prev"] + g)
            topk = np.argsort(-np.abs(score), kind="stable")[:k]
            assert mask[topk].all(), f"t={t}: top-k not covered"
            # every selected entry is >= the oracle tau (bin edge of kth)
            tau, mref = cref.hist_select_ref(jnp.asarray(score), k, kcap)
            assert (np.abs(score[mask]) >= float(tau) - 1e-7).all()
            np.testing.assert_array_equal(mask, np.asarray(mref))
            st = out.state

    def test_packed_pairs_fixed_size_inert_pads(self):
        j = 8_192
        cfg = _cfg("topk", sparsity=0.01, selector="histogram",
                   comm_mode="sparse")
        k = sparsify.resolve_k(cfg, j)
        kcap = hist_capacity(k, j)
        st = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(3), (j,))
        out = sparsify.compress(cfg, st, g)
        assert out.ghat is None                      # sparse comm: no dense
        assert out.values.shape == (kcap,)
        assert out.indices.shape == (kcap,)
        n = int(out.count)
        mask = np.asarray(sparsify.dense_mask(out, j)).astype(bool)
        assert n == int(mask.sum())
        vals = np.asarray(out.values)
        assert (vals[n:] == 0.0).all()               # inert tail
        assert (np.asarray(out.indices)[n:] == 0).all()
        dense = np.asarray(sparsify.dense_ghat(out, j))
        np.testing.assert_array_equal(
            dense != 0, mask & (np.asarray(st["err_prev"] + g) != 0))

    def test_regtopk_histogram_roundtrip(self):
        j = 9_999
        cfg = _cfg("regtopk", sparsity=0.02, mu=0.5, selector="histogram")
        k = sparsify.resolve_k(cfg, j)
        kcap = hist_capacity(k, j)
        st = sparsify.init_state(cfg, j)
        assert st["idx_prev"].shape == (kcap,)       # capacity-sized posterior
        key = jax.random.PRNGKey(1)
        for t in range(4):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            out = sparsify.compress(cfg, st, g, omega=0.25)
            n = int(sparsify.dense_mask(out, j).sum())
            assert k <= n <= kcap, (t, n)
            st = sparsify.observe_aggregate(
                cfg, out.state, 0.25 * sparsify.dense_ghat(out, j))
            assert int(st["nsel"]) == n              # live-slot count tracks

    @pytest.mark.parametrize("kind", ["topk", "regtopk"])
    @pytest.mark.parametrize("nb", [3, 8])
    def test_bucketed_parity_vs_flat(self, kind, nb):
        """Bucketing stays an execution-schedule choice for the histogram
        selector too: packed pairs and mask bitwise equal to flat."""
        j = 12_345
        cfg1 = _cfg(kind, sparsity=0.02, mu=0.5, selector="histogram")
        cfgb = dataclasses.replace(cfg1, num_buckets=nb)
        s1, sb = sparsify.init_state(cfg1, j), sparsify.init_state(cfgb, j)
        key = jax.random.PRNGKey(2)
        for t in range(3):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            o1 = sparsify.compress(cfg1, s1, g, omega=0.25)
            ob = sparsify.compress(cfgb, sb, g, omega=0.25)
            for f, x1, xb in (("idx", o1.indices, ob.indices),
                              ("val", o1.values, ob.values),
                              ("count", o1.count, ob.count)):
                np.testing.assert_array_equal(np.asarray(x1), np.asarray(xb),
                                              err_msg=f"{f} t={t}")
            aggd = 0.25 * sparsify.dense_ghat(o1, j)
            s1 = sparsify.observe_aggregate(cfg1, o1.state, aggd)
            sb = sparsify.observe_aggregate(cfgb, ob.state, aggd)

    @pytest.mark.parametrize("kind", ["topk", "regtopk"])
    def test_pallas_interpret_matches_xla(self, kind):
        """Both strategies realize the same threshold (merged-histogram
        tau == key_bin_edge(kth)) and, on tie-free data, the same
        selection and packing."""
        j, k = 2 * ck.BLOCK, 37
        kcap = hist_capacity(k, j)
        g = jax.random.normal(jax.random.PRNGKey(5), (j,))
        kw = {}
        if kind == "regtopk":
            kw = dict(idx_prev=jnp.zeros((kcap,), jnp.uint32),
                      a_prev_sel=jnp.zeros((kcap,)),
                      g_prev_sel=jnp.zeros((kcap,)),
                      nsel_prev=jnp.zeros((), jnp.int32))
        outs = {}
        for strat in ("pallas_interpret", "xla"):
            outs[strat] = cops.fused_compress_arrays(
                kind, g, jnp.zeros((j,)),
                jnp.zeros((), jnp.int32), k=k, omega=0.25, mu=0.5,
                selector="histogram", strategy=strat, **kw)
        for f in ("err", "values", "indices", "count"):
            np.testing.assert_array_equal(
                np.asarray(outs["pallas_interpret"][f]),
                np.asarray(outs["xla"][f]), err_msg=f)
        assert float(outs["pallas_interpret"]["tau"]) == \
            float(outs["xla"]["tau"])

    def test_adversarial_all_equal_capped(self):
        """Degenerate input (every entry ties): the reference histogram
        selector would select everything; the fused contract caps at the
        fixed capacity, still >= k."""
        j, k = 6_000, 64
        cfg = _cfg("topk", k=k, selector="histogram")
        out = sparsify.compress(cfg, sparsify.init_state(cfg, j),
                                jnp.ones((j,)))
        n = int(sparsify.dense_mask(out, j).sum())
        assert k <= n <= hist_capacity(k, j)

    def test_dgc_histogram_momentum_masking(self):
        j = 4_096
        cfg = _cfg("dgc", sparsity=0.02, selector="histogram")
        st = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(1), (j,))
        out = sparsify.compress(cfg, st, g)
        mom_expect = (cfg.momentum * np.asarray(st["mom"]) + np.asarray(g)) \
            * (1.0 - np.asarray(sparsify.dense_mask(out, j)))
        np.testing.assert_allclose(np.asarray(out.state["mom"]), mom_expect,
                                   rtol=1e-6, atol=1e-7)


class TestFusedBf16:
    """bf16 error feedback: bf16 J-sized state, fp32 in-register sweeps.
    Tolerance contract vs the fp32 reference (documented, not bit-parity):
    exact-k counts; step-0 selection flips confined to the bf16 rounding
    band around the k-th magnitude; selected-value drift bounded by bf16
    rounding; support overlap stays high across steps."""

    @pytest.mark.parametrize("kind", ["topk", "regtopk"])
    def test_tolerance_vs_fp32_reference(self, kind):
        j = 8_192
        cfg32 = SparsifierConfig(kind=kind, sparsity=0.02, mu=0.5,
                                 selector="exact")
        cfg16 = dataclasses.replace(cfg32, ef_dtype="bfloat16",
                                    pipeline="fused")
        k = sparsify.resolve_k(cfg32, j)
        s32 = sparsify.init_state(cfg32, j)
        s16 = sparsify.init_state(cfg16, j)
        key = jax.random.PRNGKey(2)
        for t in range(4):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            o32 = sparsify.compress(cfg32, s32, g, omega=0.25)
            o16 = sparsify.compress(cfg16, s16, g, omega=0.25)
            m32 = np.asarray(sparsify.dense_mask(o32, j)).astype(bool)
            m16 = np.asarray(sparsify.dense_mask(o16, j)).astype(bool)
            assert int(m16.sum()) == k               # exact-k preserved
            flips = int((m32 ^ m16).sum())
            assert flips <= max(2, int(0.1 * k)), f"t={t}: {flips} flips"
            if t == 0:
                # identical (zero) state: every flip sits in the bf16
                # rounding band around the k-th magnitude
                a_ref = np.asarray(g, np.float32)
                tau = np.sort(np.abs(a_ref))[-k]
                band = np.abs(np.abs(a_ref[m32 ^ m16]) - tau)
                assert (band <= 8 * BF16_EPS * tau + 1e-6).all()
            common = m32 & m16
            gd32 = np.asarray(o32.ghat)[common]
            gd16 = np.asarray(sparsify.dense_ghat(o16, j))[common]
            np.testing.assert_allclose(gd16, gd32, rtol=16 * BF16_EPS,
                                       atol=1e-4)
            aggd = 0.25 * np.asarray(o32.ghat)
            s32 = sparsify.observe_aggregate(cfg32, o32.state,
                                             jnp.asarray(aggd))
            s16 = sparsify.observe_aggregate(cfg16, o16.state,
                                             jnp.asarray(aggd))

    def test_state_is_bf16(self):
        j = 4_096
        cfg = _cfg("regtopk", sparsity=0.02, mu=0.5, ef_dtype="bfloat16")
        st = sparsify.init_state(cfg, j)
        assert st["err_prev"].dtype == jnp.bfloat16
        assert st["a_prev_sel"].dtype == jnp.bfloat16
        out = sparsify.compress(cfg, st, jax.random.normal(
            jax.random.PRNGKey(0), (j,)))
        assert out.state["err_prev"].dtype == jnp.bfloat16
        assert out.values.dtype == jnp.float32       # packed comm stays fp32

    @pytest.mark.parametrize("nb", [3, 8])
    def test_bucketed_bf16_bitwise_vs_flat(self, nb):
        """Bucketing-invariance is exact even under bf16 state (the
        sweeps read the SAME bf16 inputs either way)."""
        j = 6_000
        cfg1 = _cfg("topk", sparsity=0.02, ef_dtype="bfloat16")
        cfgb = dataclasses.replace(cfg1, num_buckets=nb)
        s1, sb = sparsify.init_state(cfg1, j), sparsify.init_state(cfgb, j)
        key = jax.random.PRNGKey(4)
        for t in range(3):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            o1 = sparsify.compress(cfg1, s1, g)
            ob = sparsify.compress(cfgb, sb, g)
            np.testing.assert_array_equal(np.asarray(o1.indices),
                                          np.asarray(ob.indices))
            np.testing.assert_array_equal(np.asarray(o1.state["err_prev"]),
                                          np.asarray(ob.state["err_prev"]))
            s1, sb = o1.state, ob.state


class TestWireBf16:
    """wire_dtype="bfloat16": the sparse all-gather moves bf16 VALUES
    (indices stay uint32) and upcasts in the scatter-add combine.
    Tolerance contract, mirroring TestFusedBf16's style: identical
    support (the wire cast happens AFTER selection), per-entry drift
    bounded by bf16 rounding, 25% wire-byte cut in the comm model."""

    def _sync(self, cfg, g, j):
        from jax.sharding import PartitionSpec as P
        st = sparsify.init_state(cfg, j)
        mesh = jax.make_mesh((1,), ("data",))

        def f(g_, st_):
            return agg.GradientSync(cfg, ("data",))(st_, g_)[0]

        with mesh:
            fn = jax.jit(jax.shard_map(
                f, mesh=mesh,
                in_specs=(P("data"), jax.tree_util.tree_map(
                    lambda _: P(), st)),
                out_specs=P("data"), check_vma=False))
            return np.asarray(fn(g, st))

    @pytest.mark.parametrize("nb", [1, 4])
    def test_tolerance_vs_fp32_wire(self, nb):
        j = 8_192
        cfg32 = _cfg("regtopk", sparsity=0.01, mu=0.5, comm_mode="sparse",
                     num_buckets=nb)
        cfg16 = dataclasses.replace(cfg32, wire_dtype="bfloat16")
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))
        a32 = self._sync(cfg32, g, j)
        a16 = self._sync(cfg16, g, j)
        # identical support: the cast never moves a value to/from zero
        np.testing.assert_array_equal(a32 != 0, a16 != 0)
        nz = a32 != 0
        rel = np.abs(a16[nz] - a32[nz]) / np.abs(a32[nz])
        assert rel.max() <= 2 * BF16_EPS, rel.max()

    def test_comm_model_is_dtype_aware(self):
        j, n = 1_000_000, 8
        cfg32 = _cfg("topk", sparsity=0.001, comm_mode="sparse")
        cfg16 = dataclasses.replace(cfg32, wire_dtype="bfloat16")
        b32 = agg.comm_bytes_per_step(cfg32, j, n)
        b16 = agg.comm_bytes_per_step(cfg16, j, n)
        assert b32["wire_value_bytes"] == 4 and b16["wire_value_bytes"] == 2
        assert b16["bytes"] == b32["bytes"] * 0.75   # (2+4) / (4+4)
        w16 = agg.sparse_gather_wire_bytes(cfg16, j, n)
        assert w16 == b16["bytes"]
        # off the sparse path there is no chunked gather payload
        assert agg.sparse_gather_wire_bytes(
            dataclasses.replace(cfg16, comm_mode="simulate"), j, n) is None


class TestFusedRandk:
    def test_roundtrip_parity_with_reference(self):
        j = 9_999
        cfgr = SparsifierConfig(kind="randk", k=50, selector="exact")
        cfgf = dataclasses.replace(cfgr, pipeline="fused")
        sr, sf = sparsify.init_state(cfgr, j), sparsify.init_state(cfgf, j)
        key = jax.random.PRNGKey(3)
        for t in range(4):
            g = jax.random.normal(jax.random.fold_in(key, 100 + t), (j,))
            kt = jax.random.fold_in(key, t)
            orr = sparsify.compress(cfgr, sr, g, key=kt)
            off = sparsify.compress(cfgf, sf, g, key=kt)
            np.testing.assert_array_equal(np.asarray(orr.indices),
                                          np.asarray(off.indices))
            np.testing.assert_allclose(
                np.asarray(orr.ghat),
                np.asarray(sparsify.dense_ghat(off, j)),
                rtol=1e-6, atol=1e-7)
            sr, sf = orr.state, off.state

    def test_sampler_is_uniform_and_distinct(self):
        j, k, rounds = 5_000, 64, 40
        seen = np.zeros(j)
        for i in range(rounds):
            idx = np.asarray(select.randk_indices(
                jax.random.PRNGKey(i), j, k))
            assert len(set(idx.tolist())) == k       # without replacement
            seen[idx] += 1
        # dispersion, not the (tautological) mean: per-index occupancy is
        # ~Binomial(40, k/j) under uniformity. A degenerate sampler that
        # repeats a fixed subset would put seen.max() == rounds and touch
        # exactly k indices; uniform draws touch ~j*(1-(1-k/j)^rounds)
        # ~ 2000 distinct indices with max occupancy ~4 (P(>=9) < 1e-6).
        assert seen.max() <= 8, seen.max()
        assert int((seen > 0).sum()) > 1_200

    def test_make_round_fn_randk_regression(self):
        """make_round_fn crashed for kind="randk" (no PRNG key threaded
        to its inner compress) before the capability-dispatch PR."""
        cfg = SparsifierConfig(kind="randk", k=16, selector="exact")
        n, j = 3, 500
        rf = sparsify.make_round_fn(cfg, n)
        states = sparsify.stack_states(
            [sparsify.init_state(cfg, j) for _ in range(n)])
        grads = jnp.stack([jax.random.normal(jax.random.PRNGKey(i), (j,))
                           for i in range(n)])
        g_agg, new_states = rf(states, grads, jax.random.PRNGKey(0))
        assert 0 < int((np.asarray(g_agg) != 0).sum()) <= n * 16
        assert int(new_states["step"][0]) == 1
        # matches the list-based sparsified_round driver (same fold_in)
        g_agg2, _ = sparsify.sparsified_round(
            cfg, [sparsify.init_state(cfg, j) for _ in range(n)],
            list(grads), key=jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(g_agg), np.asarray(g_agg2),
                                   rtol=1e-6, atol=1e-7)

    def test_fused_randk_sparse_comm(self):
        """randk participates in sparse comm now: packed pairs drive the
        all-gather, no dense ghat materialized."""
        from jax.sharding import PartitionSpec as P
        j = 4_096
        cfg = _cfg("randk", sparsity=0.01, comm_mode="sparse")
        st = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))
        out = sparsify.compress(cfg, st, g, key=jax.random.PRNGKey(7))
        assert out.ghat is None and out.values is not None
        mesh = jax.make_mesh((1,), ("data",))

        def f(g_, st_, key):
            return agg.GradientSync(cfg, ("data",))(st_, g_, key=key)[0]

        with mesh:
            fn = jax.jit(jax.shard_map(
                f, mesh=mesh,
                in_specs=(P("data"), jax.tree_util.tree_map(
                    lambda _: P(), st), P()),
                out_specs=P("data"), check_vma=False))
            g_agg = np.asarray(fn(g, st, jax.random.PRNGKey(7)))
        k = sparsify.resolve_k(cfg, j)
        assert int((g_agg != 0).sum()) <= k


class TestAutoNumBuckets:
    def test_model_shape(self):
        from repro.roofline.analysis import auto_num_buckets
        assert auto_num_buckets(0, 16) == 1
        assert auto_num_buckets(1000, 4) == 1        # latency-dominated
        big = auto_num_buckets(2_280_000, 16)        # qwen-scale payload
        assert big > 1
        assert auto_num_buckets(10 ** 9, 64) <= 16   # clamped

    def test_resolve_is_deterministic_and_manual_reproducible(self):
        cfg0 = _cfg("regtopk", sparsity=0.05, mu=0.5, num_buckets=0)
        j = 12_345
        nb = sparsify.resolve_num_buckets(cfg0, j, 64)
        assert nb == sparsify.resolve_num_buckets(cfg0, j, 64)
        assert sparsify.resolve_num_buckets(
            dataclasses.replace(cfg0, num_buckets=nb), j, 64) == nb

    def test_compress_bit_parity_auto_vs_manual(self):
        """num_buckets=0 output is BIT-identical to passing the resolved
        value manually (and to nb=1 — bucketing-invariance)."""
        j = 12_345
        cfg0 = _cfg("regtopk", sparsity=0.05, mu=0.5, num_buckets=0,
                    comm_mode="sparse")
        nb = sparsify.resolve_num_buckets(cfg0, j, 64)
        cfgm = dataclasses.replace(cfg0, num_buckets=nb)
        cfg1 = dataclasses.replace(cfg0, num_buckets=1)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))
        outs = [sparsify.compress(c, sparsify.init_state(c, j), g,
                                  omega=1 / 64)
                for c in (cfg0, cfgm, cfg1)]
        for o in outs[1:]:
            np.testing.assert_array_equal(np.asarray(outs[0].indices),
                                          np.asarray(o.indices))
            np.testing.assert_array_equal(np.asarray(outs[0].values),
                                          np.asarray(o.values))

    def test_sync_gradient_resolves_auto(self):
        from jax.sharding import PartitionSpec as P
        j = 4_096
        cfg0 = _cfg("regtopk", sparsity=0.01, mu=0.5, comm_mode="sparse",
                    num_buckets=0)
        cfg1 = dataclasses.replace(cfg0, num_buckets=1)
        mesh = jax.make_mesh((1,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def run(cfg):
            st = sparsify.init_state(cfg, j)

            def f(g_, st_):
                return agg.GradientSync(cfg, ("data",))(st_, g_)[0]

            with mesh:
                fn = jax.jit(jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"), jax.tree_util.tree_map(
                        lambda _: P(), st)),
                    out_specs=P("data"), check_vma=False))
                return np.asarray(fn(g, st))

        np.testing.assert_array_equal(run(cfg0), run(cfg1))


class TestSparseDegrade:
    def test_reference_histogram_warns_once_and_simulates(self):
        from jax.sharding import PartitionSpec as P
        agg._DEGRADE_WARNED.clear()
        j = 2_048
        cfg = SparsifierConfig(kind="topk", sparsity=0.01,
                               selector="histogram", comm_mode="sparse")
        assert effective_comm_mode(cfg) == "simulate"
        mesh = jax.make_mesh((1,), ("data",))
        st = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(g_, st_):
            return agg.GradientSync(cfg, ("data",))(st_, g_)[0]

        def trace():
            with mesh:
                fn = jax.jit(jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"), jax.tree_util.tree_map(
                        lambda _: P(), st)),
                    out_specs=P("data"), check_vma=False))
                return np.asarray(fn(g, st))

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = trace()
            msgs = [str(x.message) for x in w
                    if issubclass(x.category, RuntimeWarning)]
        assert any("degrading to a dense simulate" in m for m in msgs), msgs
        # warned once per config, not per trace
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            trace()
            again = [str(x.message) for x in w
                     if "degrading" in str(x.message)]
        assert not again
        # numerics are the simulate path's
        cfg_sim = dataclasses.replace(cfg, comm_mode="simulate")
        st2 = sparsify.init_state(cfg_sim, j)

        def f2(g_, st_):
            return agg.GradientSync(cfg_sim, ("data",))(st_, g_)[0]

        with mesh:
            fn2 = jax.jit(jax.shard_map(
                f2, mesh=mesh,
                in_specs=(P("data"), jax.tree_util.tree_map(
                    lambda _: P(), st2)),
                out_specs=P("data"), check_vma=False))
            np.testing.assert_allclose(out, np.asarray(fn2(g, st2)),
                                       rtol=1e-6, atol=1e-7)

    def test_fused_histogram_does_not_degrade(self):
        agg._DEGRADE_WARNED.clear()
        from jax.sharding import PartitionSpec as P
        j = 2_048
        cfg = _cfg("topk", sparsity=0.01, selector="histogram",
                   comm_mode="sparse")
        assert effective_comm_mode(cfg) == "sparse"
        mesh = jax.make_mesh((1,), ("data",))
        st = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(g_, st_):
            return agg.GradientSync(cfg, ("data",))(st_, g_)[0]

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            with mesh:
                fn = jax.jit(jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"), jax.tree_util.tree_map(
                        lambda _: P(), st)),
                    out_specs=P("data"), check_vma=False))
                fn(g, st)
            assert not [x for x in w
                        if "degrading" in str(x.message)]


class TestSketchSyncBigvec:
    def test_sketch_sparse_uses_buckets_and_bigvec(self):
        """The sketch-coordinated sync routes its value gather through
        bigvec and threads num_buckets into the chunked shared-mask
        combine; numerics match the simulate path."""
        from jax.sharding import PartitionSpec as P
        j = 4_096
        cfg = SparsifierConfig(kind="sketchtopk", sparsity=0.02,
                               comm_mode="sparse", num_buckets=4,
                               sketch_rows=3)
        cfg_sim = dataclasses.replace(cfg, comm_mode="simulate")
        mesh = jax.make_mesh((1,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def run(c):
            st = sparsify.init_state(c, j)

            def f(g_, st_):
                return agg.GradientSync(c, ("data",))(st_, g_)[0]

            with mesh:
                fn = jax.jit(jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"), jax.tree_util.tree_map(
                        lambda _: P(), st)),
                    out_specs=P("data"), check_vma=False))
                return np.asarray(fn(g, st))

        np.testing.assert_allclose(run(cfg), run(cfg_sim),
                                   rtol=1e-5, atol=1e-6)
