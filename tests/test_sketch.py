"""CountSketch coordination (beyond-paper): estimator quality by regime,
linearity, and end-to-end convergence on the paper's linreg study."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SparsifierConfig
from repro.core import select, sketch, sparsify


def test_sketch_linearity():
    j, rows, width = 5000, 3, 512
    a = jax.random.normal(jax.random.PRNGKey(0), (j,))
    b = jax.random.normal(jax.random.PRNGKey(1), (j,))
    s1 = sketch.encode(a, rows, width) + sketch.encode(b, rows, width)
    s2 = sketch.encode(a + b, rows, width)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)


def test_sketch_recall_powerlaw_vs_flat():
    """Heavy-tailed vectors: high top-k recall; flat vectors: poor — the
    regime boundary documented in EXPERIMENTS.md §1."""
    rng = np.random.default_rng(0)
    j, k, width = 40_000, 40, 8192
    perm = rng.permutation(j)

    def recall(x):
        x = jnp.asarray(x, jnp.float32)
        true = set(np.asarray(select.topk_indices(x, k)).tolist())
        est = sketch.estimate(sketch.encode(x, 5, width), j)
        got = set(np.asarray(select.topk_indices(est, k)).tolist())
        return len(true & got) / k

    power = rng.normal(size=j) * (np.arange(1, j + 1) ** -0.7)[perm]
    flat = rng.normal(size=j)
    assert recall(power) > 0.9
    assert recall(flat) < 0.5


def test_sketchtopk_round_shared_mask_and_ef():
    cfg = SparsifierConfig(kind="sketchtopk", sparsity=0.1, sketch_width=512)
    j, n = 400, 6
    key = jax.random.PRNGKey(2)
    grads = [jax.random.normal(jax.random.fold_in(key, i), (j,))
             for i in range(n)]
    states = [sparsify.init_state(cfg, j) for _ in range(n)]
    g_agg, new_states = sparsify.sparsified_round(cfg, states, grads)
    k = sparsify.resolve_k(cfg, j)
    assert int(jnp.sum(g_agg != 0)) <= k          # ONE shared mask
    # EF invariant per worker
    for g, st in zip(grads, new_states):
        a = g  # first round: err was 0
        sel = a - st["err"]
        assert int(jnp.sum(sel != 0)) <= k


def test_sketchtopk_converges_linreg():
    from repro.data.synthetic import linreg_dataset
    xs, ys, w_star = linreg_dataset(10, 200, 50, seed=1)
    grad_all = jax.jit(lambda w: jnp.stack(
        [(X.T @ (X @ w - y)) / X.shape[0] for X, y in zip(xs, ys)]))
    cfg = SparsifierConfig(kind="sketchtopk", sparsity=0.5, sketch_width=256)
    states = sparsify.stack_states(
        [sparsify.init_state(cfg, 50) for _ in range(10)])
    rf = sparsify.make_round_fn(cfg, 10)
    w = jnp.zeros((50,))
    for _ in range(1200):
        g, states = rf(states, grad_all(w))
        w = w - 1e-2 * g
    assert float(jnp.linalg.norm(w - w_star)) < 5e-3


def test_two_stage_topk_exact():
    import repro.core.select as S
    x = jax.random.normal(jax.random.PRNGKey(3), (100_000,))
    for k in (1, 64, 1000):
        ref = np.sort(np.asarray(jax.lax.top_k(jnp.abs(x), k)[1]))
        old = S._ROW_LIMIT
        S._ROW_LIMIT = 1 << 13
        try:
            got = np.sort(np.asarray(S._two_stage_topk(jnp.abs(x), k)))
        finally:
            S._ROW_LIMIT = old
        assert (ref == got).all()


def test_regtopk_sparse_state_bit_identical():
    import dataclasses
    cfgd = SparsifierConfig(kind="regtopk", sparsity=0.02, mu=0.5,
                            state_format="dense")
    cfgs = dataclasses.replace(cfgd, state_format="sparse")
    j = 20_000
    sd = sparsify.init_state(cfgd, j)
    ss = sparsify.init_state(cfgs, j)
    key = jax.random.PRNGKey(4)
    for t in range(4):
        g = jax.random.normal(jax.random.fold_in(key, t), (j,))
        od = sparsify.compress(cfgd, sd, g, omega=0.1)
        os_ = sparsify.compress(cfgs, ss, g, omega=0.1)
        assert (od.mask == os_.mask).all(), t
        np.testing.assert_array_equal(np.asarray(od.ghat),
                                      np.asarray(os_.ghat))
        agg = 0.1 * od.ghat
        sd = sparsify.observe_aggregate(cfgd, od.state, agg)
        ss = sparsify.observe_aggregate(cfgs, os_.state, agg)
    # state sizes: dense 4J + scalars, sparse J + 3k
    dsize = sum(x.size for x in jax.tree_util.tree_leaves(sd))
    ssize = sum(x.size for x in jax.tree_util.tree_leaves(ss))
    assert ssize < dsize / 3
