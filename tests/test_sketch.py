"""CountSketch coordination (beyond-paper): estimator quality by regime,
linearity, the fused sweep-1 encode (bit-parity + audit budget,
DESIGN.md §2.9), the shared-mask wire model, and end-to-end convergence
on the paper's linreg study."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import select, sketch, sparsify


def test_sketch_linearity():
    j, rows, width = 5000, 3, 512
    a = jax.random.normal(jax.random.PRNGKey(0), (j,))
    b = jax.random.normal(jax.random.PRNGKey(1), (j,))
    s1 = sketch.encode(a, rows, width) + sketch.encode(b, rows, width)
    s2 = sketch.encode(a + b, rows, width)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)


def test_sketch_recall_powerlaw_vs_flat():
    """Heavy-tailed vectors: high top-k recall; flat vectors: poor — the
    regime boundary documented in EXPERIMENTS.md §1."""
    rng = np.random.default_rng(0)
    j, k, width = 40_000, 40, 8192
    perm = rng.permutation(j)

    def recall(x):
        x = jnp.asarray(x, jnp.float32)
        true = set(np.asarray(select.topk_indices(x, k)).tolist())
        est = sketch.estimate(sketch.encode(x, 5, width), j)
        got = set(np.asarray(select.topk_indices(est, k)).tolist())
        return len(true & got) / k

    power = rng.normal(size=j) * (np.arange(1, j + 1) ** -0.7)[perm]
    flat = rng.normal(size=j)
    assert recall(power) > 0.9
    assert recall(flat) < 0.5


def test_sketchtopk_round_shared_mask_and_ef():
    cfg = SparsifierConfig(kind="sketchtopk", sparsity=0.1, sketch_width=512)
    j, n = 400, 6
    key = jax.random.PRNGKey(2)
    grads = [jax.random.normal(jax.random.fold_in(key, i), (j,))
             for i in range(n)]
    states = [sparsify.init_state(cfg, j) for _ in range(n)]
    g_agg, new_states = sparsify.sparsified_round(cfg, states, grads)
    k = sparsify.resolve_k(cfg, j)
    assert int(jnp.sum(g_agg != 0)) <= k          # ONE shared mask
    # EF invariant per worker
    for g, st in zip(grads, new_states):
        a = g  # first round: err was 0
        sel = a - st["err"]
        assert int(jnp.sum(sel != 0)) <= k


def test_sketchtopk_converges_linreg():
    from repro.data.synthetic import linreg_dataset
    xs, ys, w_star = linreg_dataset(10, 200, 50, seed=1)
    grad_all = jax.jit(lambda w: jnp.stack(
        [(X.T @ (X @ w - y)) / X.shape[0] for X, y in zip(xs, ys)]))
    cfg = SparsifierConfig(kind="sketchtopk", sparsity=0.5, sketch_width=256)
    states = sparsify.stack_states(
        [sparsify.init_state(cfg, 50) for _ in range(10)])
    rf = sparsify.make_round_fn(cfg, 10)
    w = jnp.zeros((50,))
    for _ in range(1200):
        g, states = rf(states, grad_all(w))
        w = w - 1e-2 * g
    assert float(jnp.linalg.norm(w - w_star)) < 5e-3


def test_two_stage_topk_exact():
    import repro.core.select as S
    x = jax.random.normal(jax.random.PRNGKey(3), (100_000,))
    for k in (1, 64, 1000):
        ref = np.sort(np.asarray(jax.lax.top_k(jnp.abs(x), k)[1]))
        old = S._ROW_LIMIT
        S._ROW_LIMIT = 1 << 13
        try:
            got = np.sort(np.asarray(S._two_stage_topk(jnp.abs(x), k)))
        finally:
            S._ROW_LIMIT = old
        assert (ref == got).all()


def test_sketch_recovery_rate_bound():
    """Seeded recovery-rate contract at the DEFAULT provisioning
    (sketch_rows=3 x resolve_width's 4k): planted heavy hitters at
    j = 2*width recover >= 80% of the true top-k (measures 0.875 at
    this pinned seed — the deterministic hash constants make the whole
    test reproducible, so a hash-constant or decode regression fails
    this loudly instead of showing up as convergence drift).

    The 4x width provisioning bounds PER-BUCKET noise, not top-k
    precision: a non-hitter coordinate that lands in hitter buckets in
    2 of 3 rows inherits a hitter-sized median estimate, and there are
    ~0.065*j such false positives regardless of j/width. Top-k recovery
    at default width is therefore only strong while j stays within a
    few multiples of width — larger J wants sketch_width above the 4k
    auto-size (EXPERIMENTS.md documents the regime boundary)."""
    rng = np.random.default_rng(7)
    j, k, rows = 2048, 256, 3
    width = sketch.resolve_width(k, 0)
    assert width == 4 * k
    x = rng.normal(size=j) * 0.01
    spikes = rng.choice(j, k, replace=False)
    x[spikes] = rng.choice([-1, 1], k) * rng.uniform(5, 10, k)
    x = jnp.asarray(x, jnp.float32)
    est = sketch.estimate(sketch.encode(x, rows, width), j)
    true = set(np.asarray(select.topk_indices(x, k)).tolist())
    got = set(np.asarray(select.topk_indices(est, k)).tolist())
    assert len(true & got) / k >= 0.8, len(true & got) / k


def test_resolve_width_caps_and_warns_once():
    k_huge = (sketch._WIDTH_CAP // 4) + 1
    sketch._CAP_WARNED.discard(k_huge)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert sketch.resolve_width(k_huge) == sketch._WIDTH_CAP
        assert sketch.resolve_width(k_huge) == sketch._WIDTH_CAP
    caps = [x for x in w if "auto-width cap" in str(x.message)]
    assert len(caps) == 1                      # warn once per k
    # explicit width is returned verbatim, above the cap, no warning
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert sketch.resolve_width(k_huge, sketch._WIDTH_CAP * 2) == \
            sketch._WIDTH_CAP * 2
    assert not [x for x in w if "auto-width cap" in str(x.message)]


class TestFusedSketchEncode:
    """ops.fused_sketch_encode: bit-parity with the legacy encode and
    the absolute 2.0-traversal / 2.0-write-unit audit budget."""

    @pytest.mark.parametrize("strategy", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("j", [100, 4096, 5000, 131072])
    def test_bit_parity_with_legacy_encode(self, strategy, j):
        from repro.kernels.compress import ops as cops
        rows, width = 3, 512
        key = jax.random.PRNGKey(j)
        g = jax.random.normal(key, (j,))
        err = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (j,))
        out = cops.fused_sketch_encode(g, err, rows=rows, width=width,
                                       strategy=strategy)
        a = err + g
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(a))
        np.testing.assert_array_equal(
            np.asarray(out["sketch"]),
            np.asarray(sketch.encode(a, rows, width)))

    @pytest.mark.parametrize("strategy", ["xla", "pallas_interpret"])
    def test_audit_budget(self, strategy):
        """The encode rides sweep 1 within the fused pipeline's absolute
        budget (DESIGN.md §2.3/§2.9): <= 2.0 traversals, <= 2.0 J-sized
        writes. The legacy vmap encode materializes (rows, J) hash/sign
        intermediates and blows it — that contrast is what the
        BENCH_compress fused_sketch group tracks."""
        from repro.kernels.compress import ops as cops
        from repro.kernels.compress.audit import audit_fn
        j = 1 << 18
        rows, width = 3, 1024
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))
        err = jnp.zeros((j,), jnp.float32)

        def f(err, g):
            out = cops.fused_sketch_encode(g, err, rows=rows, width=width,
                                           strategy=strategy)
            return out["a"], out["sketch"]

        res = audit_fn(f, err, g, j=j, donate_argnums=(0,))
        assert res["traversals"] <= 2.0, res
        assert res["write_units"] <= 2.0, res


def test_shared_mask_wire_halves_sparse_bytes():
    """Shared-mask wire mode (DESIGN.md §2.9): sketchtopk ships VALUES
    only, so its per-value exchange is exactly half of topk's packed
    (fp32 value + uint32 index) pairs at the same k — and compounds with
    wire_dtype=bfloat16 to a quarter. The sketch all-reduce is reported
    separately (participation-invariant pre-selection collective)."""
    import dataclasses
    from repro.core import aggregate
    j, n = 1 << 20, 16
    cfg_sk = SparsifierConfig(kind="sketchtopk", sparsity=0.001,
                              comm_mode="sparse")
    cfg_tk = dataclasses.replace(cfg_sk, kind="topk")
    sk = aggregate.comm_bytes_per_step(cfg_sk, j, n)
    tk = aggregate.comm_bytes_per_step(cfg_tk, j, n)
    assert sk["k"] == tk["k"]
    vals_only = sk["bytes"] - sk["sketch_bytes"]
    assert vals_only == 0.5 * tk["bytes"]
    cfg_bf = dataclasses.replace(cfg_sk, wire_dtype="bfloat16")
    bf = aggregate.comm_bytes_per_step(cfg_bf, j, n)
    assert bf["bytes"] - bf["sketch_bytes"] == 0.25 * tk["bytes"]
    assert bf["sketch_bytes"] == sk["sketch_bytes"]
    # the sketch barrier stays tiny vs the dense all-reduce it replaces:
    # TOTAL coordinated bytes (sketch + values) under 5% of dense
    assert sk["ratio"] < 0.05, sk["ratio"]
    assert sk["effective_comm_mode"] == "sparse"


def test_sketch_sync_sparse_matches_round():
    """GradientSync.__call__ (collective path, 1-device mesh) and
    GradientSync.round (in-process path) realize the same sketch-
    coordinated aggregate — one shared mask, identical EF updates."""
    from jax.sharding import PartitionSpec as P
    from repro.core import aggregate
    j, n = 4096, 1
    cfg = SparsifierConfig(kind="sketchtopk", sparsity=0.02,
                           comm_mode="sparse", pipeline="fused",
                           sketch_width=512)
    g = jax.random.normal(jax.random.PRNGKey(5), (j,))
    st = sparsify.init_state(cfg, j)
    mesh = jax.make_mesh((1,), ("data",))

    def f(g_, st_):
        return aggregate.GradientSync(cfg, ("data",))(st_, g_)

    with mesh:
        fn = jax.jit(jax.shard_map(
            f, mesh=mesh,
            in_specs=(P("data"), jax.tree_util.tree_map(lambda _: P(), st)),
            out_specs=(P("data"), jax.tree_util.tree_map(lambda _: P(), st)),
            check_vma=False))
        g_sync, st_sync = fn(g, st)
    g_round, st_round = sparsify.sparsified_round(
        cfg, [sparsify.init_state(cfg, j)], [g])
    np.testing.assert_allclose(np.asarray(g_sync), np.asarray(g_round),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(st_sync["err_prev"]),
                               np.asarray(st_round[0]["err_prev"]),
                               rtol=1e-6, atol=1e-7)


def test_regtopk_sparse_state_bit_identical():
    import dataclasses
    cfgd = SparsifierConfig(kind="regtopk", sparsity=0.02, mu=0.5,
                            state_format="dense")
    cfgs = dataclasses.replace(cfgd, state_format="sparse")
    j = 20_000
    sd = sparsify.init_state(cfgd, j)
    ss = sparsify.init_state(cfgs, j)
    key = jax.random.PRNGKey(4)
    for t in range(4):
        g = jax.random.normal(jax.random.fold_in(key, t), (j,))
        od = sparsify.compress(cfgd, sd, g, omega=0.1)
        os_ = sparsify.compress(cfgs, ss, g, omega=0.1)
        assert (od.mask == os_.mask).all(), t
        np.testing.assert_array_equal(np.asarray(od.ghat),
                                      np.asarray(os_.ghat))
        agg = 0.1 * od.ghat
        sd = sparsify.observe_aggregate(cfgd, od.state, agg)
        ss = sparsify.observe_aggregate(cfgs, os_.state, agg)
    # state sizes: dense 4J + scalars, sparse J + 3k
    dsize = sum(x.size for x in jax.tree_util.tree_leaves(sd))
    ssize = sum(x.size for x in jax.tree_util.tree_leaves(ss))
    assert ssize < dsize / 3
