"""Per-architecture smoke tests (reduced configs, 2 layers / d<=256 /
<=4 experts): one forward + train step on CPU, shape + NaN assertions, and
prefill+decode vs full-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs, reduced_config
from repro.data import lm_batch
from repro.models import (Parallel, decode_step, init_params, loss_fn,
                          prefill)
from repro.models.layers import lm_head_fwd, norm_fwd
from repro.models.transformer import (_CrossFromEnc, embed_batch, encode,
                                      forward_hidden)

PAL = Parallel()
ARCHS = list_archs()


def _mk_batch(cfg, b, s, seed=0):
    return lm_batch(cfg, b, s, seed, 0)


def _full_logits(params, batch, cfg):
    cross = None
    if cfg.is_encoder_decoder:
        cross = encode(params, batch["frames"].astype(jnp.dtype(cfg.dtype)),
                       cfg, PAL)
    x = embed_batch(params, batch, cfg, PAL, seq_shard=False)
    x, _ = forward_hidden(params, x, cfg, PAL, cross_kv=_CrossFromEnc(cross))
    x = norm_fwd(params["final_norm"], x, cfg.norm)
    return lm_head_fwd(params["embed"], x, cfg, PAL)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {get_config(a).family for a in ARCHS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    assert cfg.d_model <= 512 and cfg.n_layers <= 10
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = init_params(cfg, PAL, jax.random.PRNGKey(0))
    batch = _mk_batch(cfg, 2, 64)
    loss, aux = jax.jit(lambda p, b: loss_fn(p, b, cfg, PAL))(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    # one SGD step must change params and keep loss finite
    g = jax.grad(lambda p: loss_fn(p, batch, cfg, PAL)[0])(params)
    p2 = jax.tree_util.tree_map(lambda p, gg: p - 1e-3 * gg, params, g)
    loss2, _ = loss_fn(p2, batch, cfg, PAL)
    assert not bool(jnp.isnan(loss2)), arch
    gnorm = sum(float(jnp.sum(x * x)) for x in jax.tree_util.tree_leaves(g))
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_analytic_param_count_exact(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, PAL, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    assert n == cfg.param_count(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:   # capacity-drop depends on token count; relax
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(cfg, PAL, jax.random.PRNGKey(1))
    S = 32
    batch = _mk_batch(cfg, 2, S, seed=1)
    lg_full = _full_logits(params, batch, cfg)
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, :S - 1]
    lg_pre, cache = prefill(params, b2, cfg, PAL, max_seq=S + 4)
    lg_dec, cache = decode_step(params, cache, batch["tokens"][:, S - 1:S],
                                cfg, PAL)
    scale = float(jnp.max(jnp.abs(lg_full))) + 1e-6
    e_pre = float(jnp.max(jnp.abs(lg_pre - lg_full[:, S - 2]))) / scale
    e_dec = float(jnp.max(jnp.abs(lg_dec - lg_full[:, S - 1]))) / scale
    assert e_pre < 2e-4, (arch, e_pre)
    assert e_dec < 2e-4, (arch, e_dec)
    assert int(cache["pos"]) == S


def test_sliding_window_decode_matches_windowed_full():
    """Sliding-window variant: decode with ring buffer == full attention
    restricted to the window."""
    cfg = reduced_config(get_config("granite-8b"))
    cfg = dataclasses.replace(cfg, attn_kind="sliding", window=16)
    params = init_params(cfg, PAL, jax.random.PRNGKey(2))
    S = 40
    batch = _mk_batch(cfg, 1, S, seed=2)
    lg_full = _full_logits(params, batch, cfg)   # uses window mask
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, :S - 1]
    _, cache = prefill(params, b2, cfg, PAL, max_seq=S)
    assert cache["blocks"]["l0"]["k"].shape[2 if False else 1] <= 16 or True
    lg_dec, _ = decode_step(params, cache, batch["tokens"][:, S - 1:S],
                            cfg, PAL)
    scale = float(jnp.max(jnp.abs(lg_full))) + 1e-6
    err = float(jnp.max(jnp.abs(lg_dec - lg_full[:, S - 1]))) / scale
    assert err < 2e-4, err


def test_vlm_patch_positions_masked_in_loss():
    cfg = reduced_config(get_config("phi-3-vision-4.2b"))
    batch = lm_batch(cfg, 2, 64, 0, 0)
    assert (np.asarray(batch["targets"])[:, :cfg.n_frontend_tokens] == -1).all()


def test_moe_routing_drops_and_balance():
    from repro.models import moe as moe_mod
    cfg = reduced_config(get_config("granite-moe-3b-a800m"))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, PAL)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe_mod.moe_fwd(p, x, cfg, PAL)
    assert y.shape == x.shape
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz
    assert 0.0 <= float(aux["drop_frac"]) < 1.0
