"""Backward-overlapped streaming compression contracts (DESIGN.md §2.8).

Pins the claims the ``overlap="backward"`` path exists to make:

- streaming compression (per-segment sweep-1, global trim/pack tail) is
  BITWISE identical to the flat path — selection, packed order,
  ``err_prev``, and the full post-step state — across kinds x
  num_buckets x allocation, whether the flat vector is sliced
  internally or the segments are fed explicitly;
- the streaming program stays within the absolute audited 2-traversal /
  2-write-unit budget (per-segment sweeps fuse; streaming reorders WHEN
  sweeps run, not how many);
- the ``GradientSync`` API: build-once semantics, the
  ``begin()/feed_segment()/finish()`` stream lifecycle and its error
  paths, elastic participation through the stream, and the deprecated
  ``sync_gradient`` shim (bit-identical, warns exactly once).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import aggregate as agg
from repro.core import allocate, flatten, sparsify

J = 4096

KIND_KW = {
    "topk": {},
    "dgc": {"momentum": 0.9},
    "regtopk": {"mu": 0.5},
}


def mkcfg(kind, *, num_buckets=1, allocation="global", **kw):
    kw.setdefault("sparsity", 0.02)
    kw.setdefault("selector", "exact")
    kw.setdefault("comm_mode", "sparse")
    kw.setdefault("pipeline", "fused")
    kw.setdefault("overlap", "backward")
    return SparsifierConfig(kind=kind, num_buckets=num_buckets,
                            allocation=allocation, **KIND_KW[kind], **kw)


def stream_partition(cfg, j):
    """The partition compress resolves for a flat-g streaming call."""
    return allocate.segment_bounds(j, allocate.resolve_num_segments(cfg, j))


def assert_trees_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _grad(seed=0, j=J):
    return jax.random.normal(jax.random.PRNGKey(seed), (j,))


# ---------------------------------------------------------------------------
# bit-parity: streaming == flat, kinds x buckets x allocation
# ---------------------------------------------------------------------------

class TestStreamingCompressParity:
    @pytest.mark.parametrize("kind", ["topk", "dgc", "regtopk"])
    @pytest.mark.parametrize("num_buckets", [1, 8])
    @pytest.mark.parametrize("allocation", ["global", "proportional"])
    def test_bitwise_parity(self, kind, num_buckets, allocation):
        cfg = mkcfg(kind, num_buckets=num_buckets, allocation=allocation)
        cfg_flat = dataclasses.replace(cfg, overlap="none")
        g = _grad()
        base = sparsify.compress(cfg_flat, sparsify.init_state(cfg_flat, J),
                                 g, omega=0.25)

        # flat g under overlap="backward": compress slices internally
        sliced = sparsify.compress(cfg, sparsify.init_state(cfg, J), g,
                                   omega=0.25)
        # explicit per-segment feed (the train step's streaming form)
        bounds = stream_partition(cfg, J)
        assert len(bounds) > 1       # the streaming program actually splits
        segs = [g[off:off + size] for off, size in bounds]
        fed = sparsify.compress(cfg, sparsify.init_state(cfg, J), None,
                                omega=0.25, g_segments=segs)

        for out in (sliced, fed):
            np.testing.assert_array_equal(np.asarray(base.values),
                                          np.asarray(out.values))
            np.testing.assert_array_equal(np.asarray(base.indices),
                                          np.asarray(out.indices))
            assert_trees_equal(base.state, out.state)

    def test_layer_aligned_segments_parity(self):
        """Uneven (layer-like) partitions select identically too —
        partition invariance is not a property of the near-equal cut."""
        cfg = mkcfg("regtopk")
        cfg_flat = dataclasses.replace(cfg, overlap="none")
        g = _grad(3)
        base = sparsify.compress(cfg_flat, sparsify.init_state(cfg_flat, J),
                                 g, omega=0.5)
        bounds = [(0, 100), (100, 1000), (1100, 2996)]
        segs = [g[off:off + size] for off, size in bounds]
        out = sparsify.compress(cfg, sparsify.init_state(cfg, J), None,
                                omega=0.5, g_segments=segs)
        np.testing.assert_array_equal(np.asarray(base.values),
                                      np.asarray(out.values))
        np.testing.assert_array_equal(np.asarray(base.indices),
                                      np.asarray(out.indices))
        assert_trees_equal(base.state, out.state)

    def test_streaming_allocation_needs_matching_seg_bounds(self):
        cfg = mkcfg("topk", allocation="proportional")
        g = _grad()
        segs = [g[:1000], g[1000:]]
        with pytest.raises(ValueError, match="seg_bounds"):
            sparsify.compress(cfg, sparsify.init_state(cfg, J), None,
                              seg_bounds=[(0, 2048), (2048, 2048)],
                              g_segments=segs)

    def test_g_and_segments_exclusive(self):
        cfg = mkcfg("topk")
        g = _grad()
        with pytest.raises(ValueError, match="not both"):
            sparsify.compress(cfg, sparsify.init_state(cfg, J), g,
                              g_segments=[g])
        cfg_flat = dataclasses.replace(cfg, overlap="none")
        with pytest.raises(ValueError, match="overlap"):
            sparsify.compress(cfg_flat, sparsify.init_state(cfg_flat, J),
                              None, g_segments=[g])


# ---------------------------------------------------------------------------
# elastic participation through the stream (DESIGN.md §2.7 x §2.8)
# ---------------------------------------------------------------------------

class TestStreamingElastic:
    @pytest.mark.parametrize("bit", [True, False])
    def test_participation_parity(self, bit):
        """Sitting-out (and participating) workers behave bitwise the
        same whether the gradient streams or not: inert payload, EF
        decay, frozen posterior are all segment-local operations."""
        cfg = mkcfg("regtopk", err_decay=0.9)
        cfg_flat = dataclasses.replace(cfg, overlap="none")
        g = _grad(7)
        p = jnp.asarray(bit)
        st0 = sparsify.init_state(cfg, J)
        st0["err_prev"] = 0.1 * _grad(8)
        base = sparsify.compress(cfg_flat, dict(st0), g, omega=0.25,
                                 participate=p)
        segs = [g[off:off + size] for off, size in stream_partition(cfg, J)]
        out = sparsify.compress(cfg, dict(st0), None, omega=0.25,
                                participate=p, g_segments=segs)
        np.testing.assert_array_equal(np.asarray(base.values),
                                      np.asarray(out.values))
        np.testing.assert_array_equal(np.asarray(base.indices),
                                      np.asarray(out.indices))
        assert_trees_equal(base.state, out.state)

    def test_stream_finish_with_stats_under_shard_map(self):
        """Full GradientSync streaming step (collective included) on a
        1-device mesh: finish(with_stats=True) == the flat __call__ of
        an overlap='none' sync, and the health stats agree."""
        from jax.sharding import PartitionSpec as P
        cfg = mkcfg("topk")
        cfg_flat = dataclasses.replace(cfg, overlap="none")
        mesh = jax.make_mesh((1,), ("data",))
        g = _grad(11)
        bounds = stream_partition(cfg, J)
        st = sparsify.init_state(cfg, J)

        def run(streaming):
            gs = agg.GradientSync(cfg if streaming else cfg_flat, ("data",))

            def f(g, st):
                p = jnp.asarray(True)
                if streaming:
                    stream = gs.begin(st, participate=p)
                    for off, size in bounds:
                        stream.feed_segment(
                            jax.lax.dynamic_slice_in_dim(g, off, size))
                    return stream.finish(with_stats=True)
                return gs(st, g, participate=p, with_stats=True)

            with mesh:
                fn = jax.jit(jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"),
                              jax.tree_util.tree_map(lambda _: P(), st)),
                    out_specs=(P("data"),
                               jax.tree_util.tree_map(lambda _: P(), st),
                               {"n_active": P(),
                                "dropped_nonfinite": P()}),
                    check_vma=False))
                return fn(g, dict(st))

        ga_s, st_s, stats_s = run(True)
        ga_f, st_f, stats_f = run(False)
        np.testing.assert_array_equal(np.asarray(ga_s), np.asarray(ga_f))
        assert_trees_equal(st_s, st_f)
        assert float(stats_s["n_active"]) == float(stats_f["n_active"]) == 1.0
        assert float(stats_s["dropped_nonfinite"]) == 0.0


# ---------------------------------------------------------------------------
# audit: streaming stays inside the absolute write budget
# ---------------------------------------------------------------------------

class TestStreamingWriteBudget:
    def test_streaming_compress_budget(self):
        """Per-segment sweep-1 slices are elementwise over their own
        segment and concatenate into the global trim — they must fuse
        into the audited sweep groups, keeping the streaming step at the
        absolute 2.0-traversal / 2.0-write-unit budget (DESIGN.md
        §2.3/§2.8)."""
        from repro.kernels.compress.audit import audit_fn
        j = 1 << 18
        cfg = SparsifierConfig(kind="topk", k=j // 1000, selector="exact",
                               comm_mode="sparse", pipeline="fused",
                               overlap="backward")
        state = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(state, g):
            o = sparsify.compress(cfg, state, g, omega=0.25)
            return tuple(jax.tree_util.tree_leaves(
                [o.state, o.values, o.indices]))

        res = audit_fn(f, state, g, j=j, donate_argnums=(0,))
        assert res["traversals"] <= 2.0, res
        assert res["write_units"] <= 2.0, res


# ---------------------------------------------------------------------------
# GradientSync API surface
# ---------------------------------------------------------------------------

class TestGradientSyncAPI:
    def test_begin_requires_backward_overlap(self):
        gs = agg.GradientSync(mkcfg("topk", overlap="none"), ("data",))
        with pytest.raises(ValueError, match="overlap"):
            gs.begin({"step": jnp.zeros((), jnp.int32)})

    def test_stream_lifecycle_errors(self):
        gs = agg.GradientSync(mkcfg("topk"), ("data",))
        st = sparsify.init_state(gs.cfg, J)
        stream = gs.begin(st)
        with pytest.raises(ValueError, match="no fed segments"):
            stream.finish()
        # a consumed stream refuses further use (single-shot)
        stream2 = gs.begin(st)
        stream2.feed_segment(_grad())
        stream2._done = True
        with pytest.raises(RuntimeError):
            stream2.feed_segment(_grad())
        with pytest.raises(RuntimeError):
            stream2.finish()

    def test_axisless_sync_raises(self):
        gs = agg.GradientSync(mkcfg("topk", overlap="none"), None)
        st = sparsify.init_state(gs.cfg, J)
        with pytest.raises(ValueError, match="round"):
            gs(st, _grad())

    def test_overlap_capability_checked_at_build(self):
        with pytest.raises(ValueError):
            agg.GradientSync(mkcfg("topk", pipeline="reference"), ("data",))

    def test_bucket_preresolution(self):
        cfg = mkcfg("topk", num_buckets=0, overlap="none")
        gs = agg.GradientSync(cfg, ("data",), j=J, n_workers=4)
        assert gs.cfg.num_buckets == sparsify.resolve_num_buckets(cfg, J, 4)
        # without the concrete sizes, resolution is deferred to the step
        assert agg.GradientSync(cfg, ("data",)).cfg.num_buckets == 0

    def test_make_round_fn_needs_workers(self):
        gs = agg.GradientSync(mkcfg("topk", overlap="none"), None)
        with pytest.raises(ValueError, match="n_workers"):
            gs.make_round_fn()

    def test_round_delegates_match(self):
        """sparsify.sparsified_round / make_round_fn are thin delegates
        onto GradientSync — identical outputs, one code path."""
        cfg = mkcfg("regtopk", overlap="none", comm_mode="simulate")
        n = 3
        grads = [_grad(i) for i in range(n)]
        s0 = [sparsify.init_state(cfg, J) for _ in range(n)]
        s1 = [sparsify.init_state(cfg, J) for _ in range(n)]
        a0, n0 = sparsify.sparsified_round(cfg, s0, grads)
        a1, n1 = agg.GradientSync(cfg, None).round(s1, grads)
        np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
        assert_trees_equal(n0, n1)


# ---------------------------------------------------------------------------
# flatten_segments
# ---------------------------------------------------------------------------

class TestFlattenSegments:
    def _tree(self):
        k = jax.random.PRNGKey(0)
        return {"w1": jax.random.normal(k, (32, 8)),
                "w2": jax.random.normal(jax.random.fold_in(k, 1), (100,)),
                "w3": jax.random.normal(jax.random.fold_in(k, 2), (6, 6))}

    def test_concat_equals_flatten(self):
        tree = self._tree()
        fl = flatten.TreeFlattener(tree)
        bounds = allocate.layer_segments(fl.layer_bounds(), 2)
        segs = fl.flatten_segments(tree, bounds)
        assert len(segs) == len(bounds)
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate(segs)), np.asarray(fl.flatten(tree)))

    def test_misaligned_bounds_raise(self):
        tree = self._tree()
        fl = flatten.TreeFlattener(tree)
        with pytest.raises(ValueError, match="leaf-aligned"):
            fl.flatten_segments(tree, [(1, fl.total - 1)])
        with pytest.raises(ValueError, match="inside a leaf"):
            fl.flatten_segments(tree, [(0, 10), (10, fl.total - 10)])
        with pytest.raises(ValueError, match="every leaf"):
            fl.flatten_segments(tree, [(0, 256)])


# ---------------------------------------------------------------------------
# deprecated sync_gradient shim
# ---------------------------------------------------------------------------

class TestSyncGradientShim:
    def test_shim_bit_identical_and_warns_once(self):
        from jax.sharding import PartitionSpec as P
        cfg = mkcfg("regtopk", overlap="none")
        mesh = jax.make_mesh((1,), ("data",))
        g = _grad(5)
        st = sparsify.init_state(cfg, J)

        def run(use_shim):
            gs = agg.GradientSync(cfg, ("data",))

            def f(g, st):
                if use_shim:
                    return agg.sync_gradient(cfg, st, g, ("data",))[0]
                return gs(st, g)[0]

            with mesh:
                fn = jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"),
                              jax.tree_util.tree_map(lambda _: P(), st)),
                    out_specs=P("data"), check_vma=False)
                return fn(g, dict(st))

        agg._shim_warned = False
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            shim_out = run(True)
            dep = [w for w in rec if issubclass(w.category,
                                                DeprecationWarning)]
            assert len(dep) == 1, [str(w.message) for w in rec]
            assert "GradientSync" in str(dep[0].message)
            # second use: the one-shot marker suppresses the warning
            run(True)
            dep = [w for w in rec if issubclass(w.category,
                                                DeprecationWarning)]
            assert len(dep) == 1
        np.testing.assert_array_equal(np.asarray(shim_out),
                                      np.asarray(run(False)))
