"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps and hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st   # hypothesis or skip-stubs

from repro.kernels.fused_ef import ops as ef_ops
from repro.kernels.fused_ef import ref as ef_ref
from repro.kernels.topk_select import ops as tk_ops
from repro.kernels.topk_select import ref as tk_ref
from repro.kernels.topk_select.kernel import BLOCK, histogram_pallas


@pytest.mark.parametrize("j", [BLOCK, 2 * BLOCK, 5 * BLOCK])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_histogram_matches_ref(j, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(j), (j,)) * 3).astype(dtype)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    hk = histogram_pallas(xf, amax)
    hr = tk_ref.histogram_ref(xf, amax)
    assert (hk == hr).all()
    assert int(hk.sum()) == j


@pytest.mark.parametrize("j,k", [(4096, 41), (10_000, 100), (50_000, 50),
                                 (100_001, 5000)])
def test_threshold_topk_brackets_exact(j, k):
    rng = np.random.default_rng(j + k)
    x = jnp.asarray(rng.normal(size=j) * np.exp(rng.normal(size=j)),
                    jnp.float32)
    mask = tk_ops.topk_mask_op(x, k)
    nsel = int(mask.sum())
    assert nsel >= k
    # over-selection bounded by one bin's population
    kth = float(jnp.sort(jnp.abs(x))[-k])
    tau = float(tk_ops.histogram_threshold_op(x, k))
    assert tau <= kth + 1e-6
    # every selected entry is >= tau; every |x| >= kth is selected
    sel = np.abs(np.asarray(x))[np.asarray(mask) > 0]
    assert (sel >= tau - 1e-7).all()
    exact_mask = np.abs(np.asarray(x)) >= kth
    assert (np.asarray(mask)[exact_mask] > 0).all()


@settings(max_examples=20, deadline=None)
@given(j=st.integers(100, 30_000), seed=st.integers(0, 2**31 - 1),
       logk=st.floats(0.0, 0.8))
def test_property_threshold_selection(j, seed, logk):
    k = max(1, int(j ** logk))
    x = jax.random.normal(jax.random.PRNGKey(seed), (j,), jnp.float32)
    mask = tk_ops.topk_mask_op(x, k)
    assert int(mask.sum()) >= min(k, j)


@pytest.mark.parametrize("j", [1000, BLOCK, 123_457])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_scores_matches_ref(j, dtype):
    key = jax.random.PRNGKey(j)
    ks = jax.random.split(key, 5)
    g = (jax.random.normal(ks[0], (j,)) * 2).astype(dtype)
    err = jax.random.normal(ks[1], (j,))
    a_prev = jax.random.normal(ks[2], (j,))
    g_agg = jax.random.normal(ks[3], (j,))
    s_prev = (jax.random.uniform(ks[4], (j,)) < 0.4).astype(jnp.float32)
    kw = dict(omega=1 / 8, mu=0.5)
    a1, s1 = ef_ops.fused_regtopk_scores(g, err, a_prev, g_agg, s_prev,
                                         Q=0.0, **kw)
    a2, s2 = ef_ref.scores_ref(g.astype(jnp.float32), err, a_prev, g_agg,
                               s_prev, q=0.0, **kw)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6,
                               atol=1e-6)


def test_fused_apply_matches_ref():
    j = 77_777
    a = jax.random.normal(jax.random.PRNGKey(0), (j,))
    mask = (jax.random.uniform(jax.random.PRNGKey(1), (j,)) < 0.01).astype(
        jnp.float32)
    g1, e1 = ef_ops.fused_apply_mask(a, mask)
    g2, e2 = ef_ref.apply_ref(a, mask)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-7)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-7)
    # invariant: ghat + err == a
    np.testing.assert_allclose(np.asarray(g1 + e1), np.asarray(a), rtol=1e-6)


def test_fused_compress_path_equals_plain():
    """core.sparsify with cfg.pipeline="fused" matches the reference path
    (support bit-identical, ghat to fp rounding). The exhaustive matrix
    lives in tests/test_compress_pipeline.py."""
    import dataclasses
    from repro.configs.base import SparsifierConfig
    from repro.core import sparsify
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.02, mu=0.5,
                           selector="exact")
    cfg_f = dataclasses.replace(cfg, pipeline="fused")
    j = 12_345
    key = jax.random.PRNGKey(3)
    s1 = sparsify.init_state(cfg, j)
    s2 = sparsify.init_state(cfg_f, j)
    for t in range(3):
        g = jax.random.normal(jax.random.fold_in(key, t), (j,))
        o1 = sparsify.compress(cfg, s1, g, omega=0.25)
        o2 = sparsify.compress(cfg_f, s2, g, omega=0.25)
        assert (sparsify.dense_mask(o1, j) == sparsify.dense_mask(o2, j)).all()
        np.testing.assert_allclose(np.asarray(o1.ghat),
                                   np.asarray(sparsify.dense_ghat(o2, j)),
                                   rtol=1e-6, atol=1e-7)
        agg = 0.25 * o1.ghat
        s1 = sparsify.observe_aggregate(cfg, o1.state, agg)
        s2 = sparsify.observe_aggregate(cfg_f, o2.state, agg)
