"""Elastic aggregation contract tests (DESIGN.md §2.7).

Single-device surface: fault-schedule parsing/determinism, the
full-participation bit-identity contract, sitting-out semantics (EF
decay, frozen DGC momentum / REGTOP-k posterior, inert payloads),
support-weighted combine properties, the fused write-budget audit under
participation, the Pallas DGC gate operand, worker-count-tolerant EF
checkpoint restore, and the participation-aware cost models.

Multi-device behavior (forced-host subprocesses) lives in
test_distributed.py alongside the other collective tests.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import faults, sparsify
from repro.core.faults import (FaultSchedule, expected_active,
                               format_schedule, parse_schedule,
                               participation_matrix)

J = 4096


def mkcfg(kind="regtopk", pipeline="fused", **kw):
    kw.setdefault("sparsity", 0.02)
    kw.setdefault("mu", 0.5)
    kw.setdefault("selector", "exact")
    kw.setdefault("comm_mode", "sparse")
    return SparsifierConfig(kind=kind, pipeline=pipeline, **kw)


def err_key(cfg):
    return "err" if cfg.pipeline == "reference" else "err_prev"


# ---------------------------------------------------------------------------
# fault schedules
# ---------------------------------------------------------------------------

class TestFaultSchedules:
    def test_parse_format_roundtrip(self):
        for spec in ("iid:0.3,seed=7",
                     "bursty:period=10,outage=3,workers=1+4",
                     "permanent:step=20,workers=2"):
            sched = parse_schedule(spec)
            assert format_schedule(sched) == spec
            assert parse_schedule(format_schedule(sched)) == sched

    def test_empty_and_none_specs(self):
        assert parse_schedule("") is None
        assert parse_schedule("none") is None
        assert parse_schedule(None) is None
        assert format_schedule(None) == ""

    def test_parse_errors(self):
        with pytest.raises(ValueError):
            parse_schedule("gamma:0.3")
        with pytest.raises(ValueError):
            parse_schedule("iid:1.5")
        with pytest.raises(ValueError):
            parse_schedule("bursty:period=0,outage=1")
        with pytest.raises(ValueError):
            parse_schedule("bursty:period=4,outage=9")
        with pytest.raises(ValueError):
            parse_schedule("permanent:oops")

    def test_iid_deterministic_and_rate(self):
        sched = parse_schedule("iid:0.3,seed=5")
        m1 = participation_matrix(sched, 100, 8)
        m2 = participation_matrix(sched, 100, 8)
        np.testing.assert_array_equal(m1, m2)
        # seeded per (step, worker): loose CLT band around 0.7
        assert 0.6 < m1.mean() < 0.8, m1.mean()
        # a different seed is a different stream
        m3 = participation_matrix(parse_schedule("iid:0.3,seed=6"), 100, 8)
        assert (m1 != m3).any()

    def test_bursty_and_permanent_patterns(self):
        m = participation_matrix(
            parse_schedule("bursty:period=4,outage=2,workers=1"), 8, 3)
        exp = np.ones((8, 3), bool)
        exp[[0, 1, 4, 5], 1] = False
        np.testing.assert_array_equal(m, exp)
        m = participation_matrix(
            parse_schedule("permanent:step=3,workers=0+2"), 6, 3)
        exp = np.ones((6, 3), bool)
        exp[3:, [0, 2]] = False
        np.testing.assert_array_equal(m, exp)

    def test_traced_participates_matches_host_replay(self):
        sched = parse_schedule("iid:0.4,seed=1")
        host = participation_matrix(sched, 10, 4)
        f = jax.jit(lambda t, w: faults.participates(sched, t, w))
        traced = np.array([[bool(f(t, w)) for w in range(4)]
                           for t in range(10)])
        np.testing.assert_array_equal(host, traced)

    def test_expected_active(self):
        assert expected_active(None, 8) == 8.0
        assert expected_active(parse_schedule("iid:0.25"), 8) == 6.0
        assert expected_active(
            parse_schedule("bursty:period=4,outage=1,workers=0+1"), 8) == 7.5
        assert expected_active(
            parse_schedule("permanent:step=0,workers=1+9"), 8) == 7.0
        d = faults.describe(parse_schedule("iid:0.5"), 4)
        assert d["kind"] == "iid" and d["n_active_expected"] == 2.0

    def test_schedule_is_hashable_static(self):
        # build_train_step closes over the schedule; it must be a
        # hashable static (frozen dataclass)
        s = FaultSchedule("iid", drop_prob=0.1)
        assert hash(s) == hash(FaultSchedule("iid", drop_prob=0.1))


# ---------------------------------------------------------------------------
# full-participation bit-identity + sitting-out semantics
# ---------------------------------------------------------------------------

ALL_KINDS = ["topk", "thresholdk", "dgc", "randk", "regtopk"]


class TestFullParticipationParity:
    """participate=all-ones must be byte-identical to participate=None:
    the elastic machinery may not perturb fault-free numerics."""

    def _roll(self, cfg, participate, steps=3, seed=0):
        st = sparsify.init_state(cfg, J)
        outs = []
        for t in range(steps):
            g = jax.random.normal(jax.random.fold_in(
                jax.random.PRNGKey(seed), t), (J,))
            o = sparsify.compress(cfg, st, g,
                                  key=jax.random.PRNGKey(7 + t), omega=0.25,
                                  participate=participate)
            st = o.state
            if cfg.kind == "regtopk":
                st = sparsify.observe_aggregate(
                    cfg, st, sparsify.dense_ghat(o, J),
                    participate=participate)
            outs.append(o)
        return outs, st

    @pytest.mark.parametrize("kind", ALL_KINDS)
    @pytest.mark.parametrize("pipeline", ["reference", "fused"])
    def test_all_ones_bitwise(self, kind, pipeline):
        cfg = mkcfg(kind, pipeline, err_decay=0.5)   # decay must NOT fire
        outs0, st0 = self._roll(cfg, None)
        outs1, st1 = self._roll(cfg, jnp.asarray(True))
        for o0, o1 in zip(outs0, outs1):
            np.testing.assert_array_equal(np.asarray(o0.values),
                                          np.asarray(o1.values))
            np.testing.assert_array_equal(np.asarray(o0.indices),
                                          np.asarray(o1.indices))
        for k in st0:
            np.testing.assert_array_equal(np.asarray(st0[k]),
                                          np.asarray(st1[k]), err_msg=k)

    @pytest.mark.parametrize("num_buckets", [1, 3])
    def test_all_ones_bitwise_histogram(self, num_buckets):
        cfg = mkcfg("regtopk", "fused", selector="histogram",
                    num_buckets=num_buckets)
        outs0, st0 = self._roll(cfg, None)
        outs1, st1 = self._roll(cfg, jnp.asarray(True))
        for o0, o1 in zip(outs0, outs1):
            np.testing.assert_array_equal(np.asarray(o0.values),
                                          np.asarray(o1.values))
            np.testing.assert_array_equal(np.asarray(o0.count),
                                          np.asarray(o1.count))
        for k in st0:
            np.testing.assert_array_equal(np.asarray(st0[k]),
                                          np.asarray(st1[k]), err_msg=k)

    @pytest.mark.parametrize("buckets", [[1, 3], [1, 8]])
    def test_bucket_invariance_under_partial_participation(self, buckets):
        """Selection state after a sit-out/rejoin pattern is identical
        across bucket counts (the §2.4 invariant survives §2.7)."""
        pattern = [True, False, True]
        states = []
        for nb in buckets:
            cfg = mkcfg("regtopk", "fused", num_buckets=nb, err_decay=0.9)
            st = sparsify.init_state(cfg, J)
            for t, p in enumerate(pattern):
                g = jax.random.normal(jax.random.PRNGKey(t), (J,))
                o = sparsify.compress(cfg, st, g, omega=0.25,
                                      participate=jnp.asarray(p))
                st = sparsify.observe_aggregate(
                    cfg, o.state, sparsify.dense_ghat(o, J),
                    participate=jnp.asarray(p))
            states.append(st)
        for k in states[0]:
            np.testing.assert_array_equal(np.asarray(states[0][k]),
                                          np.asarray(states[1][k]),
                                          err_msg=k)


class TestSitOutSemantics:
    @pytest.mark.parametrize("kind", ALL_KINDS)
    @pytest.mark.parametrize("pipeline", ["reference", "fused"])
    def test_inert_payload_and_err_decay(self, kind, pipeline):
        cfg = mkcfg(kind, pipeline, err_decay=0.25)
        st = sparsify.init_state(cfg, J)
        # one participating step to accumulate a non-trivial residual
        g0 = jax.random.normal(jax.random.PRNGKey(0), (J,))
        o = sparsify.compress(cfg, st, g0, key=jax.random.PRNGKey(1),
                              omega=0.25)
        st = o.state
        ek = err_key(cfg)
        g1 = jax.random.normal(jax.random.PRNGKey(2), (J,))
        off = sparsify.compress(cfg, st, g1, key=jax.random.PRNGKey(3),
                                omega=0.25, participate=jnp.asarray(False))
        # inert payload: zero values, index 0, count 0
        assert float(jnp.sum(jnp.abs(off.values))) == 0.0
        assert int(jnp.max(off.indices)) == 0
        assert int(off.count) == 0
        # decayed EF memory: err' = err_decay * err, nothing else
        want = (0.25 * np.asarray(st[ek]).astype(np.float32)).astype(
            np.asarray(st[ek]).dtype)
        np.testing.assert_array_equal(np.asarray(off.state[ek]), want)

    def test_dgc_momentum_frozen(self):
        for pipeline in ("reference", "fused"):
            cfg = mkcfg("dgc", pipeline, err_decay=1.0)
            st = sparsify.init_state(cfg, J)
            g0 = jax.random.normal(jax.random.PRNGKey(0), (J,))
            st = sparsify.compress(cfg, st, g0, omega=0.25).state
            g1 = jax.random.normal(jax.random.PRNGKey(1), (J,))
            off = sparsify.compress(cfg, st, g1, omega=0.25,
                                    participate=jnp.asarray(False))
            np.testing.assert_allclose(
                np.asarray(off.state["mom"]),
                cfg.momentum * np.asarray(st["mom"]),
                rtol=1e-6, err_msg=pipeline)

    def test_regtopk_posterior_frozen(self):
        cfg = mkcfg("regtopk", "fused")
        st = sparsify.init_state(cfg, J)
        g0 = jax.random.normal(jax.random.PRNGKey(0), (J,))
        o = sparsify.compress(cfg, st, g0, omega=0.25)
        st = sparsify.observe_aggregate(cfg, o.state,
                                        sparsify.dense_ghat(o, J))
        g1 = jax.random.normal(jax.random.PRNGKey(1), (J,))
        off = sparsify.compress(cfg, st, g1, omega=0.25,
                                participate=jnp.asarray(False))
        st2 = sparsify.observe_aggregate(cfg, off.state,
                                         jnp.zeros((J,), jnp.float32),
                                         participate=jnp.asarray(False))
        for k in ("idx_prev", "a_prev_sel", "g_prev_sel"):
            np.testing.assert_array_equal(np.asarray(st2[k]),
                                          np.asarray(st[k]), err_msg=k)
        # ...but the step counter still advances (schedules replay on it)
        assert int(st2["step"]) == int(st["step"]) + 1


# ---------------------------------------------------------------------------
# elastic combine properties (in-process sparsified_round)
# ---------------------------------------------------------------------------

class TestElasticRound:
    N = 4

    def _grads(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return [jax.random.normal(jax.random.fold_in(k, i), (J,))
                for i in range(self.N)]

    def _manual(self, cfg, grads, pm, combine):
        dense = np.zeros(J, np.float32)
        cnt = np.zeros(J, np.float32)
        for i, g in enumerate(grads):
            if not pm[i]:
                continue
            o = sparsify.compress(cfg, sparsify.init_state(cfg, J), g,
                                  omega=1.0 / self.N)
            dense += np.asarray(sparsify.dense_ghat(o, J), np.float32)
            cnt += np.asarray(sparsify.dense_mask(o, J), np.float32)
        if combine == "support":
            return np.where(cnt > 0, dense / np.maximum(cnt, 1.0), 0.0)
        return dense / max(int(sum(pm)), 1)

    @pytest.mark.parametrize("combine", ["mean", "support"])
    def test_combine_matches_masked_dense_oracle(self, combine):
        cfg = mkcfg("topk", "fused", combine=combine)
        grads = self._grads()
        pm = [True, False, True, True]
        states = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        g_agg, _ = sparsify.sparsified_round(
            cfg, states, grads, participate=pm)
        ref = self._manual(cfg, grads, pm, combine)
        np.testing.assert_allclose(np.asarray(g_agg), ref,
                                   rtol=1e-6, atol=1e-7)

    def test_support_weights_duplicate_indices(self):
        """Coordinates selected by SEVERAL active workers divide by their
        support count — duplicated strong coordinates are not double
        counted relative to singletons."""
        base = jnp.zeros((J,))
        spike = base.at[jnp.arange(64)].set(100.0)   # shared support
        grads = [spike + 0.01 * g for g in self._grads()]
        cfg = mkcfg("topk", "fused", combine="support")
        pm = [True, True, True, False]
        states = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        g_agg, _ = sparsify.sparsified_round(cfg, states, grads,
                                             participate=pm)
        ref = self._manual(cfg, grads, pm, "support")
        np.testing.assert_allclose(np.asarray(g_agg), ref,
                                   rtol=1e-6, atol=1e-7)
        # the shared spike averages across the 3 live workers: ~100
        assert abs(float(g_agg[0]) - 100.0) < 1.0

    def test_bucket_invariance_of_combine(self):
        pm = [True, False, True, True]
        grads = self._grads(3)
        aggs = []
        for nb in (1, 4):
            cfg = mkcfg("regtopk", "fused", num_buckets=nb)
            states = [sparsify.init_state(cfg, J) for _ in range(self.N)]
            g_agg, _ = sparsify.sparsified_round(cfg, states, grads,
                                                 participate=pm)
            aggs.append(np.asarray(g_agg))
        np.testing.assert_allclose(aggs[0], aggs[1], rtol=1e-6, atol=1e-7)

    def test_all_absent_round(self):
        cfg = mkcfg("topk", "fused", err_decay=0.5)
        grads = self._grads(1)
        states = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        # accumulate residuals first
        _, states = sparsify.sparsified_round(cfg, states, grads)
        prev = [np.asarray(s["err_prev"]) for s in states]
        g_agg, states = sparsify.sparsified_round(
            cfg, states, grads, participate=[False] * self.N)
        assert float(jnp.sum(jnp.abs(g_agg))) == 0.0
        for s, p in zip(states, prev):
            np.testing.assert_array_equal(
                np.asarray(s["err_prev"]),
                (0.5 * p.astype(np.float32)).astype(p.dtype))

    def test_full_participation_matches_unmasked(self):
        cfg = mkcfg("regtopk", "fused")
        grads = self._grads(5)
        s0 = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        s1 = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        a0, _ = sparsify.sparsified_round(cfg, s0, grads)
        a1, _ = sparsify.sparsified_round(cfg, s1, grads,
                                          participate=[True] * self.N)
        np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))

    @pytest.mark.parametrize("kind,pipeline", [("globaltopk", "reference"),
                                               ("sketchtopk", "fused"),
                                               ("sketchtopk", "reference")])
    def test_coordinated_all_ones_matches_unmasked(self, kind, pipeline):
        """Coordinated (genie / sketch-coordinated) rounds accept
        participation masks; the all-ones mask is BIT-identical to no
        mask (DESIGN.md §2.7 contract extended to §2.9 kinds)."""
        cfg = mkcfg(kind, pipeline)
        grads = self._grads(2)
        s0 = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        s1 = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        a0, n0 = sparsify.sparsified_round(cfg, s0, grads)
        a1, n1 = sparsify.sparsified_round(cfg, s1, grads,
                                           participate=[True] * self.N)
        np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
        for x, y in zip(jax.tree_util.tree_leaves(n0),
                        jax.tree_util.tree_leaves(n1)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_globaltopk_partial_mask_renormalizes(self):
        """Genie selection under a partial mask = top-k of the ACTIVE
        mean (absent workers contribute nothing; divide by n_active)."""
        cfg = mkcfg("globaltopk", "reference")
        grads = self._grads(3)
        pm = [True, False, True, True]
        states = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        g_agg, _ = sparsify.sparsified_round(cfg, states, grads,
                                             participate=pm)
        a = np.mean([np.asarray(g) for g, p in zip(grads, pm) if p],
                    axis=0)
        k = sparsify.resolve_k(cfg, J)
        keep = np.argsort(-np.abs(a))[:k]
        ref = np.zeros(J, np.float32)
        ref[keep] = a[keep]
        np.testing.assert_allclose(np.asarray(g_agg), ref,
                                   rtol=1e-6, atol=1e-7)

    def test_sketch_partial_mask_matches_active_subset(self):
        """A partial mask renormalizes the sketch all-reduce by
        n_active: the 4-worker round with one absent worker aggregates
        like the 3-active-worker round (sketches, shared mask, and value
        combine all divide by the live count)."""
        cfg = mkcfg("sketchtopk", "fused")
        grads = self._grads(4)
        pm = [True, False, True, True]
        states = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        g_elastic, ns = sparsify.sparsified_round(cfg, states, grads,
                                                  participate=pm)
        live = [i for i, p in enumerate(pm) if p]
        sub_states = [sparsify.init_state(cfg, J) for _ in live]
        g_sub, ns_sub = sparsify.sparsified_round(
            cfg, sub_states, [grads[i] for i in live])
        np.testing.assert_allclose(np.asarray(g_elastic),
                                   np.asarray(g_sub),
                                   rtol=1e-6, atol=1e-7)
        ek = err_key(cfg)
        for i, w in enumerate(live):
            np.testing.assert_allclose(np.asarray(ns[w][ek]),
                                       np.asarray(ns_sub[i][ek]),
                                       rtol=1e-6, atol=1e-7)

    def test_coordinated_rejects_explicit_omegas_with_mask(self):
        cfg = mkcfg("sketchtopk", "fused")
        states = [sparsify.init_state(cfg, J) for _ in range(self.N)]
        with pytest.raises(ValueError):
            sparsify.sparsified_round(cfg, states, self._grads(),
                                      omegas=[0.25] * self.N,
                                      participate=[True] * self.N)


# ---------------------------------------------------------------------------
# write-budget audit under participation
# ---------------------------------------------------------------------------

class TestElasticWriteBudget:
    def test_fused_compress_budget_with_participation(self):
        """The participation `where`s are elementwise and must fuse into
        the existing sweeps: the elastic fused step stays within the
        audited 2-traversal / 2-write-unit budget of DESIGN.md §2.3."""
        from repro.kernels.compress.audit import audit_fn
        j = 1 << 18
        cfg = SparsifierConfig(kind="topk", k=j // 1000, selector="exact",
                               comm_mode="sparse", pipeline="fused",
                               err_decay=0.9)
        state = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(state, g, p):
            o = sparsify.compress(cfg, state, g, omega=0.25, participate=p)
            return tuple(jax.tree_util.tree_leaves(
                [o.state, o.values, o.indices]))

        res = audit_fn(f, state, g, jnp.asarray(True), j=j,
                       donate_argnums=(0,))
        assert res["traversals"] <= 2.0, res
        assert res["write_units"] <= 2.0, res


# ---------------------------------------------------------------------------
# Pallas DGC gate operand (interpret mode on CPU)
# ---------------------------------------------------------------------------

class TestPallasGate:
    def _inputs(self):
        k = jax.random.PRNGKey(0)
        g = jax.random.normal(k, (4096,))
        err = 0.1 * jax.random.normal(jax.random.fold_in(k, 1), (4096,))
        mom = 0.2 * jax.random.normal(jax.random.fold_in(k, 2), (4096,))
        return g, err, mom

    def test_gate_one_is_bitwise_passthrough(self):
        from repro.kernels.compress import kernel as pk
        g, err, mom = self._inputs()
        base = pk.sweep1_pallas(g, err, 1.0, mode="dgc", momentum=0.9,
                                mom=mom, interpret=True)
        gated = pk.sweep1_pallas(g, err, 1.0, mode="dgc", momentum=0.9,
                                 mom=mom, gate=1.0, interpret=True)
        for b, x in zip(base, gated):
            if b is not None:
                np.testing.assert_array_equal(np.asarray(b), np.asarray(x))

    def test_gate_zero_excludes_momentum_stream(self):
        from repro.kernels.compress import kernel as pk
        g, err, mom = self._inputs()
        a, score, mom_out, _, _ = pk.sweep1_pallas(
            g, err, 1.0, mode="dgc", momentum=0.9, mom=mom, gate=0.0,
            interpret=True)
        # a excludes the momentum stream entirely; mom_out still advances
        np.testing.assert_array_equal(np.asarray(a).reshape(-1),
                                      np.asarray(err, np.float32))
        np.testing.assert_allclose(
            np.asarray(mom_out).reshape(-1),
            np.asarray(0.9 * mom + g, np.float32), rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# worker-count-tolerant EF checkpoint restore
# ---------------------------------------------------------------------------

class TestElasticCheckpointResume:
    def _trees(self, dp, j=256, fill=None):
        v = (np.arange(dp * j, dtype=np.float32).reshape(dp, 1, j)
             if fill is None else np.full((dp, 1, j), fill, np.float32))
        params = {"w": np.ones((4,), np.float32)}
        opt = {"m": np.zeros((2, 1, 8), np.float32)}
        ef = {"err_prev": v, "step": np.int32(5)}
        return params, opt, ef

    def test_shrink_and_grow_worker_count(self, tmp_path):
        from repro.checkpoint.io import restore_checkpoint, save_checkpoint
        p, o, ef = self._trees(4)
        save_checkpoint(str(tmp_path), 5, p, o, ef)
        # shrink 4 -> 2: surviving workers keep their rows
        _, _, ef2 = restore_checkpoint(str(tmp_path), 5, *self._trees(2))
        np.testing.assert_array_equal(ef2["err_prev"],
                                      ef["err_prev"][:2])
        assert int(ef2["step"]) == 5
        # grow 4 -> 6: rejoined workers start with ZERO residual
        _, _, ef6 = restore_checkpoint(str(tmp_path), 5, *self._trees(6))
        np.testing.assert_array_equal(ef6["err_prev"][:4], ef["err_prev"])
        assert not ef6["err_prev"][4:].any()

    def test_roundtrip_same_count_unchanged(self, tmp_path):
        from repro.checkpoint.io import restore_checkpoint, save_checkpoint
        p, o, ef = self._trees(4)
        save_checkpoint(str(tmp_path), 5, p, o, ef)
        _, _, ef4 = restore_checkpoint(str(tmp_path), 5, *self._trees(4))
        np.testing.assert_array_equal(ef4["err_prev"], ef["err_prev"])

    def test_model_shape_mismatch_still_raises(self, tmp_path):
        from repro.checkpoint.io import restore_checkpoint, save_checkpoint
        p, o, ef = self._trees(4, j=256)
        save_checkpoint(str(tmp_path), 5, p, o, ef)
        with pytest.raises(ValueError, match="trailing per-rank dims"):
            restore_checkpoint(str(tmp_path), 5, *self._trees(4, j=128))


# ---------------------------------------------------------------------------
# participation-aware cost models
# ---------------------------------------------------------------------------

class TestElasticCostModels:
    def test_comm_bytes_scale_with_n_active(self):
        from repro.core.aggregate import comm_bytes_per_step
        cfg = mkcfg("regtopk", "fused")
        full = comm_bytes_per_step(cfg, J, 8)
        el = comm_bytes_per_step(cfg, J, 8, n_active=6.0)
        assert "n_active" not in full
        assert el["n_active"] == 6.0
        np.testing.assert_allclose(el["bytes"], full["bytes"] * 6.0 / 8.0)
        # the ratio denominator stays the FULL-fleet dense all-reduce
        np.testing.assert_allclose(el["ratio"],
                                   full["ratio"] * 6.0 / 8.0)

    def test_sparse_gather_wire_bytes_n_active(self):
        from repro.core.aggregate import sparse_gather_wire_bytes
        cfg = mkcfg("regtopk", "fused")
        full = sparse_gather_wire_bytes(cfg, J, 8)
        el = sparse_gather_wire_bytes(cfg, J, 8, n_active=5.6)
        np.testing.assert_allclose(el, full * 5.6 / 8.0)

    def test_roofline_straggler_term(self):
        from repro.roofline.analysis import roofline_terms
        rec = {
            "mesh": {"data": 8, "model": 1}, "kind": "train",
            "shape": "train_4k", "arch": "x", "active_params": 10 ** 9,
            "flops": 1e12, "bytes_accessed": 1e9,
            "collective_bytes": {"total": 4e8},
            "sparse_gather_wire_bytes": 2e8,
            "fault": {"schedule": "iid:0.3,seed=0",
                      "n_active_expected": 5.6,
                      "sparse_gather_wire_bytes_active": 1.4e8},
        }
        t = roofline_terms(rec)
        assert t["n_active_expected"] == 5.6
        assert t["straggler_wire_gain_s"] > 0
        np.testing.assert_allclose(
            t["collective_elastic_s"] + t["straggler_wire_gain_s"],
            t["collective_s"])

    def test_dryrun_record_carries_fault_config(self):
        os.environ.setdefault("XLA_FLAGS", "")
        from repro.launch.dryrun import dryrun_one  # noqa: F401 (import ok)
        # full dryrun compile is exercised by test_system; here just the
        # schedule-description plumbing
        d = faults.describe(parse_schedule("bursty:period=10,outage=2"), 8)
        assert d["schedule"].startswith("bursty:")
        assert d["n_active_expected"] == 7.8
