import os
import sys

# NB: no XLA_FLAGS here on purpose — unit/smoke tests run on the single CPU
# device. Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# hypothesis is an optional test dependency (declared in pyproject.toml /
# requirements.txt). When absent, property tests SKIP instead of erroring
# the whole module at collection.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest as _pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda fn: _pytest.mark.skip(
            reason="hypothesis not installed (pip install -e .[test])")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
