"""The doc CI gate itself (benchmarks/check_docs.py): the committed
README/DESIGN must pass, and the checker must actually detect stale
flags, config fields, and paths (a gate that can't fail is no gate)."""
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, ROOT)

from benchmarks import check_docs  # noqa: E402


def test_committed_docs_pass():
    assert check_docs.check(["README.md", "DESIGN.md"]) == []


def test_detects_stale_references(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text(
        "`--no-such-flag` `SparsifierConfig.bogus_field` "
        "`src/repro/core/nonexistent.py` `missing_file.py`\n")
    failures = check_docs.check([str(bad)])
    assert len(failures) == 4
    assert any("--no-such-flag" in f for f in failures)
    assert any("bogus_field" in f for f in failures)
    assert any("nonexistent.py" in f for f in failures)
    assert any("missing_file.py" in f for f in failures)


def test_existing_references_resolve():
    # representative resolution styles the docs rely on
    flags = check_docs._source_flags()
    assert "--allocation" in flags and "--num-segments" in flags
    names = check_docs._all_basenames()
    assert "allocate.py" in names
    for tok in ("src/repro/core/allocate.py", "core/aggregate.sync_gradient",
                "src/repro/kernels/{topk_select,fused_ef}/",
                "tests/test_allocate.py::TestApportionment",
                "benchmarks/check_compress.py"):
        assert any(os.path.exists(c) for c in
                   check_docs._path_candidates(tok)), tok
