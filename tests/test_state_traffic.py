"""State-traffic contracts of the two-traversal fused pipeline
(DESIGN.md §2.2/§2.3): the O(k)-written err_prev state must stay
BIT-identical to the reference's a * (1 - s) across every kind and
bucketing, and the audit's write accounting must bill streamed writes,
O(k) scatters, donation aliasing, and bucketed partial writes the way
the model documents.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import sparsify
from repro.kernels.compress.audit import audit_fn

BUCKETS = [1, 3, 8, 0]          # 0 = auto-tuned (resolved deterministically)


class TestStateParity:
    """Post-step err_prev (the ONE J-sized fused state vector, written
    by the O(k) scatter-zero) == the reference pipeline's a * (1 - s),
    np.testing.assert_array_equal — bitwise, not allclose."""

    @pytest.mark.parametrize("kind", ["topk", "dgc", "regtopk",
                                      "thresholdk", "randk"])
    @pytest.mark.parametrize("nb", BUCKETS)
    def test_err_prev_bitwise_vs_reference(self, kind, nb):
        j = 6_000
        cfg_r = SparsifierConfig(kind=kind, sparsity=0.02, mu=0.5,
                                 selector="exact")
        cfg_f = dataclasses.replace(cfg_r, pipeline="fused", num_buckets=nb)
        sr = sparsify.init_state(cfg_r, j)
        sf = sparsify.init_state(cfg_f, j)
        key = jax.random.PRNGKey(3)
        for t in range(3):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            kt = jax.random.fold_in(key, 100 + t)
            orr = sparsify.compress(cfg_r, sr, g, omega=0.25, key=kt)
            off = sparsify.compress(cfg_f, sf, g, omega=0.25, key=kt)
            ctx = f"kind={kind} nb={nb} t={t}"
            np.testing.assert_array_equal(
                np.asarray(orr.state["err"]),
                np.asarray(off.state["err_prev"]), err_msg=ctx)
            if kind == "dgc":
                np.testing.assert_array_equal(
                    np.asarray(orr.state["mom"]),
                    np.asarray(off.state["mom"]), err_msg=ctx)
            agg = 0.25 * sparsify.dense_ghat(orr, j)
            sr = sparsify.observe_aggregate(cfg_r, orr.state, agg)
            sf = sparsify.observe_aggregate(cfg_f, off.state, agg)

    @pytest.mark.parametrize("kind", ["topk", "dgc", "regtopk"])
    @pytest.mark.parametrize("nb", [1, 3, 8])
    def test_histogram_err_prev_keeps_ef_invariant(self, kind, nb):
        """The histogram selector has no reference bit-parity contract,
        but its err_prev must still satisfy the EF invariant against its
        OWN selection: err = a * (1 - mask) with a = err_prev + (dgc
        momentum | g), pad slots inert."""
        j = 6_000
        cfg = SparsifierConfig(kind=kind, sparsity=0.02, mu=0.5,
                               selector="histogram", pipeline="fused",
                               num_buckets=nb)
        st = sparsify.init_state(cfg, j)
        key = jax.random.PRNGKey(5)
        for t in range(3):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            err0 = np.asarray(st["err_prev"], np.float32)
            if kind == "dgc":
                a = err0 + (cfg.momentum * np.asarray(st["mom"], np.float32)
                            + np.asarray(g))
            else:
                a = err0 + np.asarray(g)
            out = sparsify.compress(cfg, st, g, omega=0.25)
            mask = np.asarray(sparsify.dense_mask(out, j))
            np.testing.assert_array_equal(
                np.asarray(out.state["err_prev"]),
                (a * (1.0 - mask)).astype(np.float32), err_msg=f"t={t}")
            st = sparsify.observe_aggregate(
                cfg, out.state, 0.25 * sparsify.dense_ghat(out, j))

    def test_fused_state_has_no_dense_mask(self):
        for kind in ("topk", "dgc", "regtopk", "thresholdk", "randk"):
            cfg = SparsifierConfig(kind=kind, sparsity=0.02, mu=0.5,
                                   pipeline="fused")
            st = sparsify.init_state(cfg, 1_000)
            assert "s_prev" not in st and "a_prev" not in st, kind
            assert "err_prev" in st, kind


class TestWriteBilling:
    """Unit contracts of audit.write_units (kernels/compress/audit.py)."""

    J = 1 << 16

    def test_elementwise_group_bills_escaping_outputs(self):
        x = jnp.zeros((self.J,))

        def f(x):
            y = 2.0 * x + 1.0          # one fused group
            return jnp.sort(y)          # barrier consumes y -> y escapes

        res = audit_fn(f, x, j=self.J)
        # group writes y (1), sort barrier writes its output (1)
        assert res["write_units"] == 2.0, res

    def test_fusion_internal_temps_are_free(self):
        x = jnp.zeros((self.J,))

        def f(x):
            y = 2.0 * x
            z = y + 1.0                 # same group: y never hits HBM
            return z

        res = audit_fn(f, x, j=self.J)
        assert res["write_units"] == 1.0, res       # only z (the outvar)

    def test_ok_scatter_into_intermediate_is_free(self):
        x = jnp.zeros((self.J,))
        idx = jnp.arange(64)

        def f(x):
            a = 2.0 * x                             # produced in-stream
            return a.at[idx].set(0.0)               # O(k) in-place zeroing

        res = audit_fn(f, x, j=self.J)
        # a escapes via the scatter (1 write); the scatter itself is O(k)
        assert res["traversals"] == 1.0, res
        assert res["write_units"] == 1.0, res

    def test_undonated_input_scatter_pays_copy_donated_is_free(self):
        s = jnp.zeros((self.J,))
        idx = jnp.arange(64)

        def f(s):
            return s.at[idx].set(1.0)

        plain = audit_fn(f, s, j=self.J)
        donated = audit_fn(f, s, j=self.J, donate_argnums=(0,))
        # XLA cannot mutate an undonated argument: defensive O(J) copy
        assert plain["write_units"] == 1.0, plain
        # donated alias updates in place: O(k) writes only
        assert donated["write_units"] == 0.0, donated
        # either way no streaming traversal
        assert plain["traversals"] == donated["traversals"] == 0.0

    def test_bucketed_partial_writes_sum_to_one(self):
        x = jnp.zeros((self.J,))
        bounds = [(0, self.J // 4)] * 0 or [
            (i * (self.J // 4), self.J // 4) for i in range(4)]

        def f(x):
            return tuple(2.0 * x[o:o + s] for o, s in bounds)

        res = audit_fn(f, x, j=self.J)
        # 4 quarter-size groups: traversals, reads, and writes each sum
        # to ~1 J-equivalent instead of 4 or 0
        assert res["traversals"] == 1.0, res
        assert res["read_units"] == 1.0, res
        assert res["write_units"] == 1.0, res

    def test_compress_write_budget_and_donation(self):
        """The fused sparse compress step writes exactly its two sweep-1
        streams (a + |score| keys) — the (a_prev, s_prev) layout's mask
        write no longer exists — and donation of the state arg leaves
        the O(k) err scatter free."""
        j = 1 << 18
        cfg = SparsifierConfig(kind="topk", k=j // 1000, selector="exact",
                               comm_mode="sparse", pipeline="fused")
        state = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(state, g):
            o = sparsify.compress(cfg, state, g, omega=0.25)
            return tuple(jax.tree_util.tree_leaves(
                [o.state, o.values, o.indices]))

        res = audit_fn(f, state, g, j=j, donate_argnums=(0,))
        assert res["traversals"] <= 2.0, res
        assert res["write_units"] <= 2.0, res


class TestMemoryModelPeak:
    """roofline/memory_model.py surfaces peak-HBM per step: compress
    transients + (un)donated state double-buffering."""

    def _run(self, pipeline, kind="regtopk"):
        from repro.configs.base import (OptimizerConfig, RunConfig, SHAPES,
                                        get_config)
        return RunConfig(
            model=get_config("stablelm-3b"), shape=SHAPES["train_4k"],
            sparsifier=SparsifierConfig(kind=kind, sparsity=0.001,
                                        pipeline=pipeline),
            optimizer=OptimizerConfig(kind="adam"))

    def test_peak_exceeds_total_and_donation_helps(self):
        from repro.roofline.memory_model import per_device_memory
        mb = per_device_memory(self._run("fused"), tp=4, dp=4)
        nd = per_device_memory(self._run("fused"), tp=4, dp=4,
                               donate_ef=False)
        assert mb.peak > mb.total                   # transients counted
        assert nd.state_double_buffer == nd.ef > 0  # undonated copy
        assert nd.peak == mb.peak + nd.ef

    def test_fused_state_and_transients_smaller_than_reference(self):
        from repro.roofline.memory_model import per_device_memory
        fused = per_device_memory(self._run("fused"), tp=4, dp=4)
        ref = per_device_memory(self._run("reference"), tp=4, dp=4)
        assert fused.ef < ref.ef                    # err_prev vs 4 J-vectors
        assert fused.compress_transient < ref.compress_transient

    def test_fits_hbm_gates_on_peak(self):
        from repro.roofline.memory_model import fits_hbm, per_device_memory
        run = self._run("fused")
        mb = per_device_memory(run, tp=4, dp=4)
        ok_at_peak, _ = fits_hbm(run, hbm_bytes=mb.peak + 1, tp=4, dp=4)
        ok_below, _ = fits_hbm(run, hbm_bytes=mb.total + 1, tp=4, dp=4)
        assert ok_at_peak and not ok_below
