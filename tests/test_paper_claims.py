"""Validation of the paper's own claims (fast versions of the Figure
experiments; full curves live in benchmarks/)."""
import sys
import os

import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_fig1_top1_stalls_regtop1_tracks():
    """§1.2: at w0=[0,1], eta=0.9, TOP-1 cannot reduce the risk for ~50
    iterations; REGTOP-1 tracks the non-sparsified loss closely."""
    from benchmarks.paper_experiments import fig1_toy_logistic
    out = fig1_toy_logistic(iters=60)
    l0 = out["topk"][0]
    stall = sum(1 for v in out["topk"] if abs(v - l0) < 1e-6)
    assert stall >= 45, stall                      # paper: ~50 iterations
    # REGTOP-1 tracks dense (skip t<3: the first iteration is plain TOP-k
    # per Algorithm 1, so tracking starts once posterior evidence exists)
    # REGTOP-1 alternates (damped entry re-probed every other round) but
    # stays within a small band of dense; by t=8 the band is < 0.01.
    gap = max(abs(a - b)
              for a, b in zip(out["regtopk"][4:40], out["none"][4:40]))
    assert gap < 0.05, gap
    assert abs(out["regtopk"][8] - out["none"][8]) < 0.01
    assert out["regtopk"][20] < 0.1 < out["topk"][20]


def test_fig2_topk_plateaus_dense_converges():
    """§4.1: TOP-k oscillates at a fixed optimality gap; dense converges."""
    from benchmarks.paper_experiments import fig2_linreg
    res = fig2_linreg(S_values=(0.6,), iters=1500)
    dense = res[(0.6, "none")]
    topk = res[(0.6, "topk")]
    reg = res[(0.6, "regtopk")]
    assert dense[-1] < 1e-3                        # converges
    assert topk[-1] > 5 * dense[-1]                # plateau (paper Fig 2)
    # plateau is FLAT for topk: late-stage improvement is marginal
    assert topk[-1] > 0.5 * topk[len(topk) // 2]
    # REGTOP-k is no worse than TOP-k at the plateau
    assert reg[-1] < 1.5 * topk[-1]


def test_globaltopk_genie_tracks_dense():
    """The Bayesian-optimal limit (genie/global TOP-k, §3.1) tracks dense —
    the ceiling REGTOP-k approximates."""
    import jax
    from repro.configs.base import SparsifierConfig
    from repro.core import sparsify
    from repro.data.synthetic import linreg_dataset
    xs, ys, w_star = linreg_dataset(20, 500, 100, seed=0)
    grad_all = jax.jit(lambda w: [(X.T @ (X @ w - y)) / X.shape[0]
                                  for X, y in zip(xs, ys)])
    cfg = SparsifierConfig(kind="globaltopk", sparsity=0.6, selector="exact")
    w = jnp.zeros((100,))
    states = [sparsify.init_state(cfg, 100) for _ in range(20)]
    for _ in range(1500):
        g, states = sparsify.sparsified_round(cfg, states, grad_all(w))
        w = w - 1e-2 * g
    assert float(jnp.linalg.norm(w - w_star)) < 1e-3


@pytest.mark.slow
def test_fig3_regtopk_beats_topk_at_extreme_sparsity():
    """§4.2 analogue: at S=0.001 REGTOP-k reaches at least TOP-k accuracy
    (paper: +8% on ResNet-18/CIFAR-10; synthetic stand-in here)."""
    from benchmarks.paper_experiments import fig3_nn
    out = fig3_nn(iters=150, eval_every=150)
    acc_t = out["topk"][-1][1]
    acc_r = out["regtopk"][-1][1]
    assert acc_r >= acc_t - 0.02, (acc_r, acc_t)
