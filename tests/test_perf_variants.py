"""Perf-variant correctness: absorbed MLA equivalence, bigvec ops, serve
launcher smoke."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.core import bigvec
from repro.models import attention as attn
from repro.models.parallel import Parallel

PAL = Parallel()


def _mla_cfg(absorb):
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    return dataclasses.replace(cfg, mla_absorb=absorb)


class TestAbsorbedMLA:
    def setup_method(self, _):
        self.p = attn.init_attention(jax.random.PRNGKey(0), _mla_cfg(False),
                                     PAL)
        self.x = jax.random.normal(jax.random.PRNGKey(1),
                                   (2, 40, _mla_cfg(False).d_model))

    def test_full_forward_equivalent(self):
        y1 = attn.attn_fwd_full(self.p, self.x, _mla_cfg(False), PAL,
                                causal=True)
        y2 = attn.attn_fwd_full(self.p, self.x, _mla_cfg(True), PAL,
                                causal=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-5)

    def test_prefill_and_decode_equivalent(self):
        y1, c1 = attn.attn_prefill(self.p, self.x, _mla_cfg(False), PAL,
                                   max_seq=48)
        y2, c2 = attn.attn_prefill(self.p, self.x, _mla_cfg(True), PAL,
                                   max_seq=48)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-5)
        nxt = jax.random.normal(jax.random.PRNGKey(2), (2, 1, self.x.shape[-1]))
        d1, _ = attn.attn_decode(self.p, nxt, dict(c1), jnp.int32(40),
                                 _mla_cfg(False), PAL)
        d2, _ = attn.attn_decode(self.p, nxt, dict(c2), jnp.int32(40),
                                 _mla_cfg(True), PAL)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=5e-5)


class TestBigvec:
    def test_roundtrip_small(self):
        a = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        idx = jnp.asarray([3, 999, 0, 512], jnp.uint32)
        np.testing.assert_array_equal(np.asarray(bigvec.gather(a, idx)),
                                      np.asarray(a)[np.asarray(idx)])
        b = bigvec.scatter_set(a, idx, 0.0)
        assert float(jnp.abs(b[np.asarray(idx)]).max()) == 0.0
        c = bigvec.scatter_add(jnp.zeros(1000), idx, 2.0)
        assert float(c.sum()) == 8.0
        m = bigvec.mask_from_indices(1000, idx, jnp.float32)
        assert int(m.sum()) == 4

    def test_blocked_path_matches(self):
        import repro.core.bigvec as bv
        a = jax.random.normal(jax.random.PRNGKey(1), (10_000,))
        idx = jax.random.randint(jax.random.PRNGKey(2), (64,), 0,
                                 10_000).astype(jnp.uint32)
        old_needs, old_cols = bv._needs_big, bv.COLS
        bv._needs_big = lambda j: True
        bv.COLS = 1 << 10
        try:
            g = bv.gather(a, idx)
            s = bv.scatter_set(a, idx, 0.0)
            m = bv.mask_from_indices(10_000, idx, jnp.float32)
        finally:
            bv._needs_big, bv.COLS = old_needs, old_cols
        np.testing.assert_array_equal(np.asarray(g),
                                      np.asarray(a)[np.asarray(idx)])
        assert float(jnp.abs(s[np.asarray(idx)]).max()) == 0.0
        assert int(m.sum()) == len(set(np.asarray(idx).tolist()))


def test_serve_launcher_smoke():
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "deepseek-v2-lite-16b", "--smoke", "--devices", "4", "--data", "2",
         "--model", "2", "--batch", "4", "--prompt-len", "24",
         "--new-tokens", "4", "--mla-absorb"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=root)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "decode 4 steps" in out.stdout
