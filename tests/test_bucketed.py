"""Bucketed compression (DESIGN.md §2.4) vs the flat num_buckets=1 path.

The contract under test: bucketing is an execution-schedule choice, not
a semantics choice — for every num_buckets, the packed (values,
indices), the mask, and the post-step EF/posterior state must be
BIT-identical to the flat path (which is itself bit-identical to the
reference exact selector, tests/test_compress_pipeline.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import sparsify
from repro.core.flatten import bucket_bounds
from repro.kernels.compress import kernel as ck
from repro.kernels.compress import ops as cops
from repro.kernels.compress import ref as cref

BUCKETS = [1, 3, 8]


def _cfg(kind, nb, **kw):
    kw.setdefault("selector", "exact")
    kw.setdefault("pipeline", "fused")
    return SparsifierConfig(kind=kind, num_buckets=nb, **kw)


def _assert_state_equal(s1, s2, ctx):
    assert set(s1) == set(s2), ctx
    for name in s1:
        np.testing.assert_array_equal(np.asarray(s1[name]),
                                      np.asarray(s2[name]),
                                      err_msg=f"{ctx}: state[{name}]")


def _roundtrip_vs_flat(kind, nb, j, steps=4, seed=0, omega=0.25, gfn=None):
    """Run flat and bucketed side by side; everything must be bitwise equal."""
    cfg1 = _cfg(kind, 1, sparsity=0.02, mu=0.5)
    cfgb = dataclasses.replace(cfg1, num_buckets=nb)
    s1 = sparsify.init_state(cfg1, j)
    sb = sparsify.init_state(cfgb, j)
    key = jax.random.PRNGKey(seed)
    for t in range(steps):
        if gfn is None:
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
        else:
            g = gfn(j, t)
        o1 = sparsify.compress(cfg1, s1, g, omega=omega)
        ob = sparsify.compress(cfgb, sb, g, omega=omega)
        ctx = f"kind={kind} nb={nb} t={t}"
        np.testing.assert_array_equal(np.asarray(o1.indices),
                                      np.asarray(ob.indices), err_msg=ctx)
        np.testing.assert_array_equal(np.asarray(o1.values),
                                      np.asarray(ob.values), err_msg=ctx)
        np.testing.assert_array_equal(np.asarray(sparsify.dense_mask(o1, j)),
                                      np.asarray(sparsify.dense_mask(ob, j)),
                                      err_msg=ctx)
        agg = omega * sparsify.dense_ghat(o1, j)
        s1 = sparsify.observe_aggregate(cfg1, o1.state, agg)
        sb = sparsify.observe_aggregate(cfgb, ob.state, agg)
        _assert_state_equal(s1, sb, ctx)
    return s1


class TestBucketBounds:
    def test_partition_is_contiguous_and_exhaustive(self):
        for j, nb in ((12345, 3), (8, 8), (100, 7), (1, 1), (5, 9)):
            bounds = bucket_bounds(j, nb)
            assert bounds[0][0] == 0
            assert sum(s for _, s in bounds) == j
            for (o1, s1), (o2, _s2) in zip(bounds, bounds[1:]):
                assert o1 + s1 == o2
            sizes = [s for _, s in bounds]
            assert max(sizes) - min(sizes) <= 1
            assert min(sizes) >= 1          # clamped: no empty buckets
        assert len(bucket_bounds(5, 9)) == 5
        assert bucket_bounds(10, 1) == [(0, 10)]


class TestParityMatrix:
    """num_buckets in {1, 3, 8} x all three fused kinds: packed pairs and
    post-step EF/posterior state bit-identical to the flat path."""

    @pytest.mark.parametrize("kind", ["topk", "dgc", "regtopk"])
    @pytest.mark.parametrize("nb", BUCKETS)
    def test_bitwise_parity_vs_flat(self, kind, nb):
        final = _roundtrip_vs_flat(kind, nb, j=12_345)
        assert int(final["step"]) == 4

    @pytest.mark.parametrize("nb", [3, 8])
    def test_sparse_comm_packed_parity(self, nb):
        cfg1 = _cfg("regtopk", 1, sparsity=0.01, mu=0.5, comm_mode="sparse")
        cfgb = dataclasses.replace(cfg1, num_buckets=nb)
        j = 8_192
        g = jax.random.normal(jax.random.PRNGKey(3), (j,))
        o1 = sparsify.compress(cfg1, sparsify.init_state(cfg1, j), g)
        ob = sparsify.compress(cfgb, sparsify.init_state(cfgb, j), g)
        assert o1.ghat is None and ob.ghat is None
        np.testing.assert_array_equal(np.asarray(o1.indices),
                                      np.asarray(ob.indices))
        np.testing.assert_array_equal(np.asarray(o1.values),
                                      np.asarray(ob.values))


class TestCrossBucketTies:
    """Adversarial tie cases whose resolution spans bucket boundaries:
    selection must stay the reference tie-break (value desc, index asc),
    independent of where the bucket cuts fall."""

    @pytest.mark.parametrize("kind", ["topk", "regtopk"])
    @pytest.mark.parametrize("nb", [3, 8])
    def test_all_equal_selects_lowest_indices_across_buckets(self, kind, nb):
        # every entry ties; top-150 of 300 spans bucket 0 and half of
        # bucket 1 (nb=3) — the union must be indices [0, 150)
        j, k = 300, 150
        cfg1 = _cfg(kind, 1, k=k, mu=0.5)
        cfgb = dataclasses.replace(cfg1, num_buckets=nb)
        g = jnp.ones((j,))
        o1 = sparsify.compress(cfg1, sparsify.init_state(cfg1, j), g)
        ob = sparsify.compress(cfgb, sparsify.init_state(cfgb, j), g)
        np.testing.assert_array_equal(np.asarray(o1.indices),
                                      np.asarray(ob.indices))
        assert set(np.asarray(ob.indices).tolist()) == set(range(k))

    @pytest.mark.parametrize("nb", [3, 8])
    def test_boundary_tie_straddling_buckets(self, nb):
        # k-th magnitude duplicated on BOTH sides of every bucket cut;
        # multi-step so REGTOP-k support corrections hit the tie too
        def gfn(j, t):
            g = jnp.where(jnp.arange(j) % 7 == 0, 2.0, 1.0)
            bounds = bucket_bounds(j, nb)
            for off, _ in bounds[1:]:
                g = g.at[off - 1].set(2.0).at[off].set(2.0)
            return g * (1.0 + 0.1 * t)
        _roundtrip_vs_flat("regtopk", nb, j=6_000, steps=3, gfn=gfn)

    @pytest.mark.parametrize("nb", [3, 8])
    def test_degenerate_all_zero(self, nb):
        _roundtrip_vs_flat("topk", nb, j=2_000, steps=2,
                           gfn=lambda j, t: jnp.zeros((j,)))


class TestPallasBucketed:
    """Histogram-merge path (strategy="pallas_interpret")."""

    @pytest.mark.parametrize("kind", ["topk", "regtopk"])
    @pytest.mark.parametrize("nb", [3, 8])
    def test_bitwise_parity_vs_flat(self, kind, nb):
        j, k = 2 * ck.BLOCK, 37
        key = jax.random.PRNGKey(5)
        kw = {}
        if kind == "regtopk":
            kw = dict(idx_prev=jnp.zeros((k,), jnp.uint32),
                      a_prev_sel=jnp.zeros((k,)), g_prev_sel=jnp.zeros((k,)))
        err_prev = {1: jnp.zeros((j,)), nb: jnp.zeros((j,))}
        step = jnp.zeros((), jnp.int32)
        kws = {1: dict(kw), nb: dict(kw)}
        for t in range(3):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            outs = {}
            for b in (1, nb):
                outs[b] = cops.fused_compress_arrays(
                    kind, g, err_prev[b], step, k=k, omega=0.25,
                    mu=0.5, Q=0.0, want_ghat=True,
                    strategy="pallas_interpret", num_buckets=b, **kws[b])
            for f in ("err", "values", "indices", "ghat"):
                np.testing.assert_array_equal(
                    np.asarray(outs[1][f]), np.asarray(outs[nb][f]),
                    err_msg=f"kind={kind} nb={nb} t={t} field={f}")
            for b in (1, nb):
                err_prev[b] = outs[b]["err"]
                if kind == "regtopk":
                    agg = 0.25 * outs[b]["ghat"]
                    kws[b] = dict(
                        idx_prev=outs[b]["indices"],
                        a_prev_sel=outs[b]["values"],
                        g_prev_sel=agg[outs[b]["indices"].astype(jnp.int32)])
            step = step + 1

    def test_histogram_merge_equals_flat_histogram(self):
        """Per-bucket bit-pattern histograms sum to the flat histogram
        (the invariant the global-k merge rests on), and the merged
        threshold equals the flat threshold."""
        j = 4 * ck.BLOCK
        score = jax.random.normal(jax.random.PRNGKey(7), (j,))
        keys = jnp.abs(score)
        flat_hist = jnp.zeros((ck.BINS,), jnp.int32).at[ck.bit_bin(keys)].add(1)
        for nb in (2, 3, 8):
            bounds = bucket_bounds(j, nb)
            hists = cref.bucket_hists_ref(score, bounds, ck.BINS)
            np.testing.assert_array_equal(
                np.asarray(ck.merge_bucket_hists(hists)),
                np.asarray(flat_hist))
            for target in (1, 64, j // 2):
                assert float(ck.threshold_from_bucket_hists(hists, target)) \
                    == float(ck.threshold_from_hist(flat_hist, target))

    def test_sweep1_per_bucket_hists_merge(self):
        """Kernel-emitted per-bucket histograms (pad-corrected) merge to
        the dense-oracle flat histogram."""
        j = 3 * ck.BLOCK + 123          # forces per-bucket padding
        g = jax.random.normal(jax.random.PRNGKey(9), (j,))
        bounds = bucket_bounds(j, 3)
        hists = []
        for off, size in bounds:
            j_pad = -(-size // ck.BLOCK) * ck.BLOCK
            pad = lambda x: jnp.pad(x[off:off + size], (0, j_pad - size))
            _a, _s, _m, _amax, hist = ck.sweep1_pallas(
                pad(g), pad(jnp.zeros((j,))), 1.0,
                mode="plain", interpret=True)
            hists.append(hist.at[0].add(-(j_pad - size)))
        merged = np.asarray(ck.merge_bucket_hists(hists))
        bins = np.asarray(ck.bit_bin(jnp.abs(g)))
        np.testing.assert_array_equal(
            merged, np.bincount(bins, minlength=ck.BINS))
        assert int(merged.sum()) == j


class TestBucketedSweepCount:
    """The bucketed path must stay within the fused pipeline's O(J)
    traversal budget: num_buckets partial sweeps are ONE J-equivalent,
    not num_buckets traversals (audit weights by size, DESIGN.md §2.3)
    — and their partial WRITES must sum the same way."""

    @staticmethod
    def _audit(nb, comm_mode="sparse", j=1 << 21):
        from repro.kernels.compress.audit import audit_fn
        cfg = SparsifierConfig(kind="regtopk", k=j // 1000, mu=0.5,
                               selector="exact", comm_mode=comm_mode,
                               pipeline="fused", num_buckets=nb)
        state = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(state, g):
            o = sparsify.compress(cfg, state, g, omega=0.25)
            outs = [o.mask, o.state, o.values, o.indices]
            if o.ghat is not None:
                outs.append(o.ghat)
            return tuple(jax.tree_util.tree_leaves(outs))

        return audit_fn(f, state, g, j=j, donate_argnums=(0,))

    @pytest.mark.parametrize("nb", [1, 3, 8])
    def test_bucketed_sparse_within_budget(self, nb):
        # <= 2 traversals + the per-bucket BLOCK-padding slack (a bucket
        # of J/nb elements pads to a row multiple; < 1% at this J)
        res = self._audit(nb)
        assert res["traversals"] <= 2.02, (nb, res)
        assert res["read_units"] <= 3.55, (nb, res)
        assert res["write_units"] <= 2.02, (nb, res)

    def test_bucketing_does_not_inflate_traversals(self):
        flat, b8 = self._audit(1), self._audit(8)
        assert abs(b8["traversals"] - flat["traversals"]) <= 0.01, (flat, b8)
        assert abs(b8["write_units"] - flat["write_units"]) <= 0.01, (flat, b8)


class TestBucketedSyncGradient:
    """Chunked per-bucket sparse collectives == monolithic all-gather."""

    @pytest.mark.parametrize("nb", [1, 4])
    def test_sync_parity_across_buckets(self, nb):
        from jax.sharding import PartitionSpec as P
        from repro.core import aggregate as agg
        j = 4_096
        cfg = _cfg("regtopk", nb, sparsity=0.01, mu=0.5, comm_mode="sparse")
        mesh = jax.make_mesh((1,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def run(cfg):
            st = sparsify.init_state(cfg, j)

            def f(g, st):
                return agg.GradientSync(cfg, ("data",))(st, g)[0]

            with mesh:
                fn = jax.jit(jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"), jax.tree_util.tree_map(
                        lambda _: P(), st)),
                    out_specs=P("data"), check_vma=False))
                return np.asarray(fn(g, st))

        flat = run(dataclasses.replace(cfg, num_buckets=1))
        np.testing.assert_allclose(run(cfg), flat, rtol=1e-6, atol=1e-7)

    def test_chunked_combine_handles_k_not_divisible(self):
        """k=10 pairs over 4 chunks (padded tail must be inert)."""
        from jax.sharding import PartitionSpec as P
        from repro.core import aggregate as agg
        j, k = 1_000, 10
        vals = jnp.arange(1, k + 1, dtype=jnp.float32)
        idx = (jnp.arange(k, dtype=jnp.uint32) * 97) % j
        mesh = jax.make_mesh((1,), ("data",))
        with mesh:
            def f(v, i):
                return agg.sparse_allgather_combine(v, i, j, ("data",),
                                                    num_buckets=4)
            out = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                check_vma=False))(vals, idx)
        expect = np.zeros((j,), np.float32)
        expect[np.asarray(idx)] = np.asarray(vals)
        np.testing.assert_array_equal(np.asarray(out), expect)


class TestEdgeCases:
    def test_more_buckets_than_elements(self):
        cfg1 = _cfg("topk", 1, k=3)
        cfgb = dataclasses.replace(cfg1, num_buckets=64)
        j = 7
        g = jax.random.normal(jax.random.PRNGKey(1), (j,))
        o1 = sparsify.compress(cfg1, sparsify.init_state(cfg1, j), g)
        ob = sparsify.compress(cfgb, sparsify.init_state(cfgb, j), g)
        np.testing.assert_array_equal(np.asarray(o1.indices),
                                      np.asarray(ob.indices))

    def test_k_equals_j(self):
        _roundtrip_vs_flat("regtopk", 3, j=99, steps=2)
        cfg1 = _cfg("topk", 1, k=64)
        cfgb = dataclasses.replace(cfg1, num_buckets=3)
        j = 64
        g = jax.random.normal(jax.random.PRNGKey(2), (j,))
        o1 = sparsify.compress(cfg1, sparsify.init_state(cfg1, j), g)
        ob = sparsify.compress(cfgb, sparsify.init_state(cfgb, j), g)
        np.testing.assert_array_equal(np.asarray(sparsify.dense_mask(o1, j)),
                                      np.asarray(sparsify.dense_mask(ob, j)))
