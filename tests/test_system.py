"""End-to-end behaviour tests: launcher CLI, example drivers, dry-run on a
tiny mesh — all via subprocess (device-count isolation)."""
import json
import os
import re
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def run_cmd(args, env_extra=None, timeout=1500):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + ROOT + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.update(env_extra or {})
    out = subprocess.run([sys.executable] + args, capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=ROOT)
    assert out.returncode == 0, (
        f"STDOUT:\n{out.stdout[-4000:]}\nSTDERR:\n{out.stderr[-4000:]}")
    return out.stdout


def test_train_launcher_smoke(tmp_path):
    # --fixed-batch: the synthetic stream is uniform-random tokens, so loss
    # only decreases measurably when overfitting one batch
    out = run_cmd(["-m", "repro.launch.train", "--arch", "granite-8b",
                   "--smoke", "--steps", "8", "--data", "2", "--model", "2",
                   "--devices", "4", "--sparsifier", "regtopk",
                   "--comm", "sparse", "--log-every", "4", "--fixed-batch",
                   "--checkpoint-dir", str(tmp_path / "ck")])
    losses = [float(m) for m in re.findall(r"loss (\d+\.\d+)", out)]
    assert losses and losses[-1] < losses[0]
    assert any(f.endswith(".params.npz") for f in os.listdir(tmp_path / "ck"))


def test_train_launcher_allocation_smoke(tmp_path):
    """Convergence smoke for density allocation (DESIGN.md §2.6): the
    fused pipeline with per-layer adaptive budgets must still overfit
    the fixed batch, and the launcher must thread --allocation through."""
    out = run_cmd(["-m", "repro.launch.train", "--arch", "stablelm-3b",
                   "--smoke", "--steps", "8", "--data", "2", "--model", "1",
                   "--devices", "2", "--sparsifier", "regtopk",
                   "--comm", "sparse", "--pipeline", "fused",
                   "--allocation", "adaptive", "--num-segments", "6",
                   "--log-every", "4", "--fixed-batch"])
    losses = [float(m) for m in re.findall(r"loss (\d+\.\d+)", out)]
    assert losses and losses[-1] < losses[0]


def test_dryrun_tiny_mesh(tmp_path):
    out_json = str(tmp_path / "dr.json")
    out = run_cmd(["-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
                   "--shape", "decode_32k,long_500k", "--mesh", "2x2",
                   "--out", out_json])
    assert "0 failed" in out
    data = json.load(open(out_json))
    assert len(data["results"]) == 2
    for r in data["results"]:
        assert r["hlo_flops"] > 0
        assert r["memory"]["argument_size_in_bytes"] > 0


def test_dryrun_multipod_tiny():
    out = run_cmd(["-m", "repro.launch.dryrun", "--arch",
                   "granite-moe-3b-a800m", "--shape", "train_4k",
                   "--mesh", "2x2x2"])
    assert "0 failed" in out


def test_example_quickstart():
    out = run_cmd(["examples/quickstart.py"])
    assert "greedy decode" in out


def test_example_train_100m_tiny():
    out = run_cmd(["examples/train_100m.py", "--steps", "6", "--tiny",
                   "--batch", "4", "--seq", "64"])
    assert "loss" in out


def test_example_serve_batched():
    out = run_cmd(["examples/serve_batched.py"])
    assert "sliding" in out
