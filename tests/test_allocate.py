"""Density allocation (DESIGN.md §2.6, core/allocate.py).

Contracts under test:

- budget conservation: sum(k_l) == k EXACTLY in every mode — including
  largest-remainder distribution, per-segment caps (k_l <= J_l) with
  overflow redistribution, the >=1 floor, and degenerate tiny segments
  where J_l is below the segment's natural quota;
- allocation="global" is bit-identical to the pre-allocation pipeline
  (fused global == reference global across kinds x num_buckets);
- fused allocated selection == the dense reference allocated selector
  (packed values/indices/err state, multi-step, both strategies);
- adaptive mode is deterministic under jit and stays within its caps;
- the allocated fused step keeps the 2.0-traversal / 2-write-unit audit
  budget (no extra O(J) sweep for statistics or trims);
- the sparse-comm wire format is allocation-invariant (still exactly k
  packed pairs).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import allocate, sparsify
from repro.kernels.compress import kernel as ck
from repro.kernels.compress import ops as cops


def _cfg(kind, **kw):
    kw.setdefault("selector", "exact")
    kw.setdefault("mu", 0.5)
    return SparsifierConfig(kind=kind, **kw)


# ---------------------------------------------------------------------------
# Apportionment
# ---------------------------------------------------------------------------

class TestApportionment:
    def test_proportional_conserves_and_bounds(self):
        for k, sizes in ((10, [3, 100, 2, 895]), (1, [5, 5]),
                         (7, [1, 1, 1, 1, 1, 1, 1]), (100, [1000]),
                         (13, [2, 3, 5, 7, 11]), (999, [10, 10, 10, 10000])):
            c = allocate.proportional_counts(k, sizes)
            assert sum(c) == min(k, sum(sizes)), (k, sizes, c)
            assert all(0 <= ci <= sz for ci, sz in zip(c, sizes))
            if k >= len(sizes):
                assert min(c) >= 1          # floor

    def test_proportional_remainder_distribution(self):
        # k=10 over equal thirds: remainders break ties by index
        assert allocate.proportional_counts(10, [30, 30, 30]) == [4, 3, 3]

    def test_degenerate_tiny_segments(self):
        # segments smaller than their natural quota: caps bind at J_l and
        # the overflow redistributes — sum stays exact
        sizes = [2, 1, 3, 1000]
        for k in (5, 500, 900, 1006):
            c = allocate.proportional_counts(k, sizes)
            assert sum(c) == min(k, sum(sizes))
            assert all(ci <= sz for ci, sz in zip(c, sizes))
        # adaptive with all mass in the tiny segments: caps must bind
        m = jnp.asarray([1e6, 1e6, 1e6, 1.0])
        ca = allocate.adaptive_counts(500, sizes, m)
        assert int(ca.sum()) == 500
        assert all(int(ca[i]) <= sizes[i] for i in range(4))

    def test_adaptive_conserves_exactly(self):
        sizes = [3, 100, 2, 895]
        caps = allocate.segment_caps(10, sizes)
        for mom in ([0.0, 0.0, 0.0, 0.0], [1.0, 100.0, 0.0, 10.0],
                    [1e30, 1e-30, 1.0, 1.0]):
            c = allocate.adaptive_counts(10, sizes, jnp.asarray(mom))
            assert int(c.sum()) == 10, (mom, c)
            assert all(int(c[i]) <= caps[i] for i in range(4))
            assert int(c.min()) >= 1        # k >= S floor

    def test_adaptive_zero_moments_is_proportional(self):
        sizes = [100, 200, 300, 400]
        c = allocate.adaptive_counts(40, sizes, jnp.zeros((4,)))
        np.testing.assert_array_equal(
            np.asarray(c), allocate.proportional_counts(40, sizes))

    def test_adaptive_shifts_budget_to_heavy_segment(self):
        sizes = [1000, 1000, 1000, 1000]
        m = jnp.asarray([1000.0, 1.0, 1.0, 1.0])
        c = allocate.adaptive_counts(100, sizes, m)
        assert int(c[0]) > 25                # above the proportional share
        caps = allocate.segment_caps(100, sizes)
        assert int(c[0]) <= caps[0]          # bounded deviation

    def test_segment_caps_cover_k(self):
        for k, sizes in ((10, [1, 1, 1]), (100, [5, 5, 1000]),
                         (1000, [10] * 100)):
            caps = allocate.segment_caps(k, sizes)
            assert sum(caps) >= min(k, sum(sizes))
            assert all(c <= sz for c, sz in zip(caps, sizes))


class TestSegments:
    def test_segment_bounds_matches_bucket_rule(self):
        from repro.core.flatten import bucket_bounds
        assert allocate.segment_bounds(12345, 7) == bucket_bounds(12345, 7)

    def test_layer_segments_leaf_aligned(self):
        leaves = [100, 5, 300, 1, 250, 80, 7, 400]
        edges = set(np.cumsum([0] + leaves).tolist())
        for s in (1, 2, 3, 8, 20):
            bounds = allocate.layer_segments(leaves, s)
            assert sum(sz for _, sz in bounds) == sum(leaves)
            assert len(bounds) <= max(1, min(s, len(leaves)))
            off = 0
            for o, sz in bounds:
                assert o == off and sz > 0
                assert o in edges            # never cuts inside a leaf
                off += sz

    def test_layer_segments_zero_size_leaves(self):
        bounds = allocate.layer_segments([0, 10, 0, 0, 20, 0], 4)
        assert sum(sz for _, sz in bounds) == 30
        assert all(sz > 0 for _, sz in bounds)

    def test_resolve_num_segments_follows_buckets(self):
        cfg = _cfg("topk", k=10, allocation="proportional", num_buckets=4)
        assert allocate.resolve_num_segments(cfg, 1000) == 4
        cfg1 = dataclasses.replace(cfg, num_buckets=1)
        assert allocate.resolve_num_segments(cfg1, 1000) == \
            allocate.DEFAULT_SEGMENTS
        cfg2 = dataclasses.replace(cfg, num_segments=3)
        assert allocate.resolve_num_segments(cfg2, 1000) == 3
        assert allocate.resolve_num_segments(cfg2, 2) == 2   # clamp to j


class TestValidation:
    def test_histogram_selector_rejected(self):
        cfg = _cfg("topk", k=5, selector="histogram",
                   allocation="proportional")
        with pytest.raises(ValueError, match="exact"):
            allocate.check_allocation(cfg)

    def test_aggregate_level_kinds_rejected(self):
        for kind in ("none", "globaltopk", "sketchtopk"):
            with pytest.raises(ValueError, match="per-worker"):
                allocate.check_allocation(
                    _cfg(kind, k=5, allocation="adaptive"))

    def test_compress_raises_not_silently_degrades(self):
        cfg = _cfg("sketchtopk", k=5, allocation="proportional")
        with pytest.raises(ValueError):
            sparsify.compress(cfg, {"err": jnp.zeros((100,)),
                                    "step": jnp.zeros((), jnp.int32)},
                              jnp.ones((100,)))

    def test_global_always_valid(self):
        allocate.check_allocation(_cfg("sketchtopk", allocation="global"))


# ---------------------------------------------------------------------------
# allocation="global" bit-parity (the must-not-change contract)
# ---------------------------------------------------------------------------

class TestGlobalParity:
    @pytest.mark.parametrize("kind", ["topk", "dgc", "regtopk"])
    @pytest.mark.parametrize("nb", [1, 8])
    def test_fused_global_equals_reference(self, kind, nb):
        j = 12_345
        cfg_r = _cfg(kind, sparsity=0.02, allocation="global")
        cfg_f = dataclasses.replace(cfg_r, pipeline="fused", num_buckets=nb)
        sr, sf = sparsify.init_state(cfg_r, j), sparsify.init_state(cfg_f, j)
        key = jax.random.PRNGKey(0)
        for t in range(3):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            orr = sparsify.compress(cfg_r, sr, g, omega=0.25)
            off = sparsify.compress(cfg_f, sf, g, omega=0.25)
            ctx = f"kind={kind} nb={nb} t={t}"
            np.testing.assert_array_equal(np.asarray(orr.indices),
                                          np.asarray(off.indices), err_msg=ctx)
            np.testing.assert_array_equal(np.asarray(orr.values),
                                          np.asarray(off.values), err_msg=ctx)
            np.testing.assert_array_equal(
                np.asarray(orr.state["err"]),
                np.asarray(off.state["err_prev"]), err_msg=ctx)
            agg = 0.25 * sparsify.dense_ghat(orr, j)
            sr = sparsify.observe_aggregate(cfg_r, orr.state, agg)
            sf = sparsify.observe_aggregate(cfg_f, off.state, agg)


# ---------------------------------------------------------------------------
# Allocated selection: fused == dense reference oracle
# ---------------------------------------------------------------------------

def _roundtrip_fused_vs_reference(kind, allocation, j=12_345, steps=3,
                                  num_segments=0, key_seed=1, gfn=None,
                                  **cfg_kw):
    cfg_kw.setdefault("sparsity", 0.01)
    cfg_r = _cfg(kind, allocation=allocation, num_segments=num_segments,
                 **cfg_kw)
    cfg_f = dataclasses.replace(cfg_r, pipeline="fused")
    sr, sf = sparsify.init_state(cfg_r, j), sparsify.init_state(cfg_f, j)
    key = jax.random.PRNGKey(key_seed)
    for t in range(steps):
        g = (jax.random.normal(jax.random.fold_in(key, t), (j,))
             if gfn is None else gfn(j, t))
        kt = jax.random.fold_in(key, 1000 + t)
        orr = sparsify.compress(cfg_r, sr, g, key=kt, omega=0.25)
        off = sparsify.compress(cfg_f, sf, g, key=kt, omega=0.25)
        ctx = f"kind={kind} alloc={allocation} t={t}"
        np.testing.assert_array_equal(np.asarray(orr.indices),
                                      np.asarray(off.indices), err_msg=ctx)
        np.testing.assert_array_equal(np.asarray(orr.values),
                                      np.asarray(off.values), err_msg=ctx)
        np.testing.assert_array_equal(np.asarray(orr.state["err"]),
                                      np.asarray(off.state["err_prev"]),
                                      err_msg=ctx)
        agg = 0.25 * sparsify.dense_ghat(orr, j)
        sr = sparsify.observe_aggregate(cfg_r, orr.state, agg)
        sf = sparsify.observe_aggregate(cfg_f, off.state, agg)
    return sr, sf


class TestAllocatedParity:
    @pytest.mark.parametrize("kind", ["topk", "dgc", "regtopk",
                                      "thresholdk"])
    @pytest.mark.parametrize("allocation", ["proportional", "adaptive"])
    def test_fused_equals_reference(self, kind, allocation):
        _roundtrip_fused_vs_reference(kind, allocation)

    def test_randk_streams_identical(self):
        _roundtrip_fused_vs_reference("randk", "proportional")

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
    def test_adaptive_regtopk_stress(self, seed):
        """Heavy support corrections (mu=1, Q=1, S=0.1) + skewed
        per-segment magnitudes push the adaptive moments across
        integerization boundaries: fused must STILL match the reference
        bit-for-bit — the moments are computed from the CORRECTED ranked
        pool and the stat-cover witness routes truncated covers to the
        dense fallback (regression for the stats-before-corrections
        bug)."""
        j = 4_000

        def gfn(jj, t):
            kk = jax.random.fold_in(jax.random.PRNGKey(100 + seed), t)
            scale = jnp.exp(jnp.sin(jnp.arange(jj) * (0.003 + 0.001 * seed))
                            * 2.0)
            return jax.random.normal(kk, (jj,)) * scale

        _roundtrip_fused_vs_reference(
            "regtopk", "adaptive", j=j, steps=3, num_segments=6,
            key_seed=seed, gfn=gfn, sparsity=0.1, mu=1.0, Q=1.0)

    def test_explicit_seg_bounds(self):
        # layer-aligned (unequal) bounds through the seg_bounds kwarg
        j = 10_000
        bounds = allocate.layer_segments([4000, 100, 2900, 3000], 3)
        cfg_r = _cfg("topk", k=200, allocation="proportional")
        cfg_f = dataclasses.replace(cfg_r, pipeline="fused")
        g = jax.random.normal(jax.random.PRNGKey(2), (j,))
        orr = sparsify.compress(cfg_r, sparsify.init_state(cfg_r, j), g,
                                seg_bounds=bounds)
        off = sparsify.compress(cfg_f, sparsify.init_state(cfg_f, j), g,
                                seg_bounds=bounds)
        np.testing.assert_array_equal(np.asarray(orr.indices),
                                      np.asarray(off.indices))
        np.testing.assert_array_equal(np.asarray(orr.values),
                                      np.asarray(off.values))


class TestBudgetConservation:
    @pytest.mark.parametrize("allocation", ["proportional", "adaptive"])
    def test_selected_counts_match_allocation(self, allocation):
        j, k, ns = 20_000, 400, 5
        cfg = _cfg("topk", k=k, pipeline="fused", allocation=allocation,
                   num_segments=ns)
        g = jax.random.normal(jax.random.PRNGKey(3), (j,)) * \
            (1.0 + jnp.arange(j) / j)       # skewed mass across segments
        out = sparsify.compress(cfg, sparsify.init_state(cfg, j), g)
        idx = np.asarray(out.indices)
        assert idx.shape == (k,)
        assert len(set(idx.tolist())) == k   # unique -> per-segment sums
        bounds = allocate.segment_bounds(j, ns)
        per = [int(((idx >= o) & (idx < o + s)).sum()) for o, s in bounds]
        assert sum(per) == k
        if allocation == "proportional":
            assert per == allocate.proportional_counts(
                k, [s for _, s in bounds])

    def test_tiny_segments_roundtrip(self):
        # k close to J with segments of a few elements: caps bind
        j, k = 40, 30
        bounds = [(0, 2), (2, 1), (3, 17), (20, 20)]
        cfg = _cfg("topk", k=k, pipeline="fused", allocation="proportional")
        g = jax.random.normal(jax.random.PRNGKey(4), (j,))
        out = sparsify.compress(cfg, sparsify.init_state(cfg, j), g,
                                seg_bounds=bounds)
        idx = np.asarray(out.indices)
        assert len(set(idx.tolist())) == k
        per = [int(((idx >= o) & (idx < o + s)).sum()) for o, s in bounds]
        assert sum(per) == k
        assert all(p <= s for p, (_, s) in zip(per, bounds))


class TestAdaptive:
    def test_deterministic_under_jit(self):
        j = 8_192
        cfg = _cfg("regtopk", k=100, pipeline="fused", allocation="adaptive",
                   num_segments=4)
        g = jax.random.normal(jax.random.PRNGKey(5), (j,))
        state = sparsify.init_state(cfg, j)

        def f(state, g):
            o = sparsify.compress(cfg, state, g, omega=0.5)
            return o.values, o.indices, o.state["err_prev"]

        jf = jax.jit(f)
        v1, i1, e1 = jf(state, g)
        v2, i2, e2 = jf(state, g)
        ve, ie, ee = f(state, g)
        for x, y in ((v1, v2), (i1, i2), (e1, e2),
                     (v1, ve), (i1, ie), (e1, ee)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_adaptive_follows_mass(self):
        j, k = 80_000, 800
        g = jnp.concatenate([10.0 * jnp.ones((10_000,)),
                             0.01 * jax.random.normal(jax.random.PRNGKey(6),
                                                      (70_000,))])
        cfg = _cfg("topk", k=k, pipeline="fused", allocation="adaptive",
                   num_segments=8)
        out = sparsify.compress(cfg, sparsify.init_state(cfg, j), g)
        idx = np.asarray(out.indices)
        first = int((idx < 10_000).sum())
        prop = k // 8
        caps = allocate.segment_caps(k, [10_000] * 8)
        assert first > prop                  # shifted toward the mass
        assert first <= caps[0]              # bounded deviation


class TestPallasAllocated:
    """Allocated trim on the Pallas strategy (per-segment sweep-1
    histograms -> per-segment taus) must match the XLA strategy
    bit-for-bit. Small sizes: interpret mode is slow."""

    @pytest.mark.parametrize("kind", ["topk", "regtopk"])
    def test_strategies_agree(self, kind):
        j, k = 2 * ck.BLOCK, 37
        bounds = allocate.segment_bounds(j, 2)
        kw = (dict(idx_prev=jnp.zeros((k,), jnp.uint32),
                   a_prev_sel=jnp.zeros((k,)), g_prev_sel=jnp.zeros((k,)))
              if kind == "regtopk" else {})
        err = {s: jnp.zeros((j,)) for s in ("xla", "pallas_interpret")}
        kws = {s: dict(kw) for s in err}
        step = jnp.zeros((), jnp.int32)
        key = jax.random.PRNGKey(7)
        for t in range(2):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            outs = {}
            for s in err:
                outs[s] = cops.fused_compress_arrays(
                    kind, g, err[s], step, k=k, omega=0.25, mu=0.5, Q=0.0,
                    want_ghat=True, strategy=s, allocation="adaptive",
                    seg_bounds=bounds, **kws[s])
            for f in ("err", "values", "indices", "ghat"):
                np.testing.assert_array_equal(
                    np.asarray(outs["xla"][f]),
                    np.asarray(outs["pallas_interpret"][f]),
                    err_msg=f"kind={kind} t={t} field={f}")
            for s in err:
                err[s] = outs[s]["err"]
                if kind == "regtopk":
                    agg = 0.25 * outs[s]["ghat"]
                    kws[s] = dict(
                        idx_prev=outs[s]["indices"],
                        a_prev_sel=outs[s]["values"],
                        g_prev_sel=agg[outs[s]["indices"].astype(jnp.int32)])
            step = step + 1


class TestAllocatedSweepCount:
    """Per-segment allocation must not cost a traversal: the adaptive
    statistics, trims, and pack are all O(sum(caps)) — the audited step
    stays at the 2.0-traversal / <=2-write-unit fused sparse budget
    (the absolute gate benchmarks/check_compress.py enforces in CI)."""

    @staticmethod
    def _audit(allocation, j=1 << 21):
        from repro.kernels.compress.audit import audit_fn
        cfg = _cfg("regtopk", k=j // 1000, selector="exact",
                   comm_mode="sparse", pipeline="fused",
                   allocation=allocation)
        state = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(state, g):
            o = sparsify.compress(cfg, state, g, omega=0.25)
            return tuple(jax.tree_util.tree_leaves(
                [o.state, o.values, o.indices]))

        return audit_fn(f, state, g, j=j, donate_argnums=(0,))

    @pytest.mark.parametrize("allocation", ["proportional", "adaptive"])
    def test_allocated_within_budget(self, allocation):
        res = self._audit(allocation)
        assert res["traversals"] <= 2.02, (allocation, res)
        assert res["read_units"] <= 3.55, (allocation, res)
        assert res["write_units"] <= 2.02, (allocation, res)

    def test_allocation_does_not_inflate_vs_global(self):
        glob, adapt = self._audit("global"), self._audit("adaptive")
        assert abs(adapt["traversals"] - glob["traversals"]) <= 0.01
        assert abs(adapt["write_units"] - glob["write_units"]) <= 0.01


class TestSyncGradient:
    """Wire format is allocation-invariant: compress still packs exactly
    k pairs and the chunked sparse collective is untouched."""

    @pytest.mark.parametrize("allocation", ["proportional", "adaptive"])
    def test_sync_runs_and_packs_k(self, allocation):
        from jax.sharding import PartitionSpec as P
        from repro.core import aggregate as agg
        j = 4_096
        cfg = _cfg("regtopk", sparsity=0.01, comm_mode="sparse",
                   pipeline="fused", allocation=allocation, num_segments=4)
        mesh = jax.make_mesh((1,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))
        st = sparsify.init_state(cfg, j)

        def f(g, st):
            return agg.GradientSync(cfg, ("data",))(st, g)[0]

        with mesh:
            fn = jax.jit(jax.shard_map(
                f, mesh=mesh,
                in_specs=(P("data"), jax.tree_util.tree_map(lambda _: P(),
                                                            st)),
                out_specs=P("data"), check_vma=False))
            g_agg = np.asarray(fn(g, st))
        k = sparsify.resolve_k(cfg, j)
        assert int((g_agg != 0).sum()) <= k
        # dense-combine parity vs an explicit compress + scatter
        out = sparsify.compress(cfg, sparsify.init_state(cfg, j), g,
                                omega=1.0)
        expect = np.asarray(sparsify.dense_ghat(out, j))
        np.testing.assert_allclose(g_agg, expect, rtol=1e-6, atol=1e-7)

    def test_comm_bytes_allocation_invariant(self):
        from repro.core.aggregate import comm_bytes_per_step
        base = _cfg("regtopk", sparsity=0.001, comm_mode="sparse",
                    pipeline="fused")
        ref = comm_bytes_per_step(base, 1 << 20, 16)
        for allocation in ("proportional", "adaptive"):
            got = comm_bytes_per_step(
                dataclasses.replace(base, allocation=allocation),
                1 << 20, 16)
            assert got["bytes"] == ref["bytes"]
            assert got["packed_len"] == ref["packed_len"]
            assert got["allocation"] == allocation
