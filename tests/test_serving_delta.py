"""Versioned sparse delta broadcast (DESIGN.md §2.10): checksum +
non-finite guards, staleness contract, publisher error feedback, resync
protocol, fault-injected channels, and the in-flight pinned-decode
consistency invariant.

The contract every fault case pins: a replica either holds version v
with params BIT-EQUAL to the publisher's params-at-v, or is mid-resync
and refuses to advance. No injected fault may crash the replica or let
unhealthy values reach live params.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults
from repro.serve.delta import (DeltaApplier, DeltaPayload, DeltaPublisher,
                               DeltaVersionError, FaultyChannel,
                               MemoryChannel, SpoolChannel, delta_wire_bytes,
                               drain, payload_checksum, payload_health,
                               read_snapshot, resync_bytes,
                               resync_equiv_deltas, scatter_set_tree,
                               write_snapshot)


def _tree(key, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (16, 8), dtype),
        "nested": {"b": jax.random.normal(ks[1], (11,), dtype)},
        "head": jax.random.normal(ks[2], (5, 5), dtype),
    }


def _walk(params, t, scale=0.05):
    """Deterministic trainer step: params + seeded noise."""
    k = jax.random.PRNGKey(1000 + t)
    leaves, td = jax.tree_util.tree_flatten(params)
    new = [l + (scale * jax.random.normal(
        jax.random.fold_in(k, i), l.shape)).astype(l.dtype)
        for i, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(td, new)


def _assert_trees_equal(a, b, msg=""):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# Payload integrity: checksum + non-finite guards
# ---------------------------------------------------------------------------

def _payload(version=1, k=6, j=100, seed=0):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=k).astype(np.float32)
    idx = np.sort(rng.choice(j, size=k, replace=False)).astype(np.int32)
    return DeltaPayload.stamp(version, vals, idx, k, j)


def test_checksum_detects_any_single_flip():
    p = _payload()
    assert p.verify() == "ok"
    # value bit flip
    v = np.array(p.values, copy=True)
    v.view(np.uint32)[2] ^= 1 << 13
    assert dataclasses.replace(p, values=v).verify() == "corrupt"
    # index bit flip
    i = np.array(p.indices, copy=True)
    i[3] ^= 1 << 2
    assert dataclasses.replace(p, indices=i).verify() == "corrupt"
    # header tampering: version, count, j all feed the sum
    assert dataclasses.replace(p, version=p.version + 1).verify() == "corrupt"
    assert dataclasses.replace(p, count=p.count - 1).verify() == "corrupt"
    assert dataclasses.replace(p, j=p.j + 1).verify() == "corrupt"
    # swapped entries: position weights catch value permutations that
    # a plain sum would miss
    v2 = np.array(p.values, copy=True)
    v2[[0, 1]] = v2[[1, 0]]
    assert dataclasses.replace(p, values=v2).verify() == "corrupt"


def test_checksum_position_weighted_and_index_range():
    p = _payload(j=50)
    # out-of-range index with a RE-STAMPED checksum is still corrupt
    i = np.array(p.indices, copy=True)
    i[0] = 50
    bad = DeltaPayload.stamp(p.version, p.values, i, p.count, p.j)
    assert bad.verify() == "corrupt"
    # shape mismatch
    assert dataclasses.replace(p, values=p.values[:3]).verify() == "corrupt"


def test_nonfinite_is_distinct_from_corrupt():
    """A checksum-VALID payload carrying NaN is publisher poison, not
    transport damage — distinct verdict, distinct counter."""
    p = _payload()
    v = np.array(p.values, copy=True)
    v[1] = np.nan
    poisoned = DeltaPayload.stamp(p.version, v, p.indices, p.count, p.j)
    assert poisoned.verify() == "nonfinite"
    v[1] = np.inf
    assert DeltaPayload.stamp(p.version, v, p.indices, p.count,
                              p.j).verify() == "nonfinite"


def test_payload_health_traced_safe():
    """payload_health is the jit/psum-able form of verify()."""
    p = _payload()
    f = jax.jit(payload_health)
    csum = np.uint32(p.checksum)
    ok, corrupt, nonfinite = f(p.values, p.indices, csum,
                               p.version, p.count, p.j)
    assert bool(ok) and not bool(corrupt) and not bool(nonfinite)
    v = np.array(p.values, copy=True)
    v.view(np.uint32)[0] ^= 1 << 7
    ok, corrupt, _ = f(v, p.indices, csum, p.version, p.count, p.j)
    assert not bool(ok) and bool(corrupt)
    v = np.array(p.values, copy=True)
    v[0] = np.nan
    csum = np.uint32(payload_checksum(v, p.indices, p.version, p.count, p.j))
    ok, corrupt, nonfinite = f(v, p.indices, csum, p.version, p.count, p.j)
    assert not bool(ok) and not bool(corrupt) and bool(nonfinite)


# ---------------------------------------------------------------------------
# Publisher -> applier exact tracking (the §2.10 invariant, clean channel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_publish_apply_bitwise_tracking(dtype):
    """Replica at accepted version v is bit-identical to the publisher's
    params-at-v — in fp32 AND bf16 leaves (values round-trip through the
    fp32 wire and cast at the leaf on both sides)."""
    params = _tree(jax.random.PRNGKey(0), dtype)
    pub = DeltaPublisher(params, k=20, record_history=True)
    app = DeltaApplier(params)
    cur = params
    for t in range(12):
        cur = _walk(cur, t)
        payload = pub.publish(cur)
        assert app.offer(payload) == "applied"
        assert app.version == pub.version
        _assert_trees_equal(app.params, pub.params_at(app.version),
                            msg=f"v{app.version} dtype={dtype}")


def test_error_feedback_drains_residual():
    """Coordinates the k-budget skipped stay in the publisher's residual:
    after the trainer STOPS moving, ceil(j/k) more publishes bring the
    replica exactly to the true params."""
    params = _tree(jax.random.PRNGKey(1))
    j = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    k = 16
    pub = DeltaPublisher(params, k=k)
    app = DeltaApplier(params)
    final = _walk(_walk(params, 0, scale=0.5), 1, scale=0.5)
    for _ in range(math.ceil(j / k)):
        app.offer(pub.publish(final))
    _assert_trees_equal(app.params, final)


def test_scatter_set_is_idempotent():
    """Wire values are ABSOLUTE (scatter-SET): applying the same payload
    twice is a no-op, which is what makes redelivery harmless."""
    params = _tree(jax.random.PRNGKey(2))
    from repro.core.flatten import TreeFlattener
    flat = TreeFlattener(params)
    vals = jnp.linspace(1.0, 2.0, 7)
    idx = jnp.asarray([0, 5, 40, 127, 128, 140, 152], jnp.int32)
    once = scatter_set_tree(flat, params, vals, idx)
    twice = scatter_set_tree(flat, once, vals, idx)
    _assert_trees_equal(once, twice)


# ---------------------------------------------------------------------------
# Staleness contract: stale drop, gap -> refuse -> resync
# ---------------------------------------------------------------------------

def test_stale_dropped_gap_refuses_until_resync(tmp_path):
    params = _tree(jax.random.PRNGKey(3))
    pub = DeltaPublisher(params, k=20, record_history=True)
    app = DeltaApplier(params)
    snap = str(tmp_path)
    cur = params
    payloads = []
    for t in range(6):
        cur = _walk(cur, t)
        payloads.append(pub.publish(cur))
    assert app.offer(payloads[0]) == "applied"
    # redelivery of an applied version is stale, not an error
    assert app.offer(payloads[0]) == "stale"
    assert app.counters["dropped_stale"] == 1
    # v3 on top of v1 is a gap: flips needs_resync, params untouched
    before = app.params
    assert app.offer(payloads[2]) == "gap"
    assert app.needs_resync and app.counters["gaps_detected"] == 1
    _assert_trees_equal(app.params, before)
    # EVERYTHING is refused mid-resync, even the in-order v2
    assert app.offer(payloads[1]) == "resync_pending"
    assert app.offer(payloads[3]) == "resync_pending"
    # no snapshot yet -> cannot resync; equal-version snapshot neither
    assert not app.can_resync(snap)
    write_snapshot(snap, pub.params_at(1), 1)
    assert not app.can_resync(snap)
    # a NEWER snapshot re-arms intake and raises the floor
    pub.write_snapshot(snap)     # v6
    assert app.can_resync(snap)
    assert app.resync_from(snap) == 6
    assert app.version == 6 and app.floor == 6 and not app.needs_resync
    _assert_trees_equal(app.params, pub.params_at(6))
    # post-resync: old versions are stale, the next contiguous applies
    assert app.offer(payloads[3]) == "stale"
    cur = _walk(cur, 99)
    assert app.offer(pub.publish(cur)) == "applied"
    _assert_trees_equal(app.params, pub.params_at(7))


def test_resync_never_moves_backwards(tmp_path):
    params = _tree(jax.random.PRNGKey(4))
    pub = DeltaPublisher(params, k=20)
    app = DeltaApplier(params)
    old = str(tmp_path / "old")
    write_snapshot(old, params, 0)
    cur = params
    for t in range(3):
        cur = _walk(cur, t)
        app.offer(pub.publish(cur))
    assert app.version == 3
    with pytest.raises(DeltaVersionError, match="backwards"):
        app.resync_from(old, step=0)


def test_strict_apply_raises_on_violations(tmp_path):
    params = _tree(jax.random.PRNGKey(5))
    pub = DeltaPublisher(params, k=20)
    app = DeltaApplier(params)
    cur = _walk(params, 0)
    p1 = pub.publish(cur)
    cur = _walk(cur, 1)
    p2 = pub.publish(cur)
    # out of order
    with pytest.raises(DeltaVersionError, match="contiguous"):
        app.apply(p2)
    # corrupt
    v = np.array(p1.values, copy=True)
    v.view(np.uint32)[0] ^= 1
    with pytest.raises(DeltaVersionError, match="corrupt"):
        app.apply(dataclasses.replace(p1, values=v))
    # j mismatch (payload from another model)
    with pytest.raises(DeltaVersionError):
        app.apply(DeltaPayload.stamp(1, p1.values, p1.indices, p1.count,
                                     p1.j + 64))
    app.apply(p1)
    app.apply(p2)
    assert app.version == 2


def test_nonfinite_never_reaches_live_params():
    params = _tree(jax.random.PRNGKey(6))
    pub = DeltaPublisher(params, k=20)
    app = DeltaApplier(params)
    p1 = pub.publish(_walk(params, 0))
    v = np.array(p1.values, copy=True)
    v[0] = np.nan
    poisoned = DeltaPayload.stamp(p1.version, v, p1.indices, p1.count, p1.j)
    before = app.params
    assert app.offer(poisoned) == "nonfinite"
    assert app.counters["dropped_nonfinite"] == 1
    _assert_trees_equal(app.params, before)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in jax.tree_util.tree_leaves(app.params))
    # the dropped version then shows up as a gap when v2 arrives
    assert app.offer(pub.publish(_walk(params, 1))) == "gap"
    with pytest.raises(DeltaVersionError, match="nonfinite"):
        app.apply(poisoned)


# ---------------------------------------------------------------------------
# Checkpoint floor: deltas predating a restore are a hard error
# ---------------------------------------------------------------------------

def test_version_floor_from_restored_snapshot(tmp_path):
    params = _tree(jax.random.PRNGKey(7))
    pub = DeltaPublisher(params, k=20)
    snap = str(tmp_path)
    cur = params
    old_payloads = []
    for t in range(5):
        cur = _walk(cur, t)
        old_payloads.append(pub.publish(cur))
    pub.write_snapshot(snap)     # v5
    restored, version = read_snapshot(snap, params)
    assert version == 5
    app = DeltaApplier(restored, version=version)
    assert app.floor == 5
    for p in old_payloads:
        with pytest.raises(DeltaVersionError, match="floor"):
            app.apply(p)
    # at-floor is just as illegal as below-floor
    with pytest.raises(DeltaVersionError, match="floor"):
        app.apply(old_payloads[-1])
    cur = _walk(cur, 5)
    app.apply(pub.publish(cur))
    assert app.version == 6


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------

def test_spool_channel_roundtrip(tmp_path):
    root = str(tmp_path)
    tx, rx = SpoolChannel(root), SpoolChannel(root)
    ps = [_payload(version=v, seed=v) for v in (1, 2, 3)]
    for p in ps:
        tx.send(p)
    got = rx.recv()
    assert [g.version for g in got] == [1, 2, 3]
    for a, b in zip(ps, got):
        assert b.verify() == "ok"
        np.testing.assert_array_equal(a.values, b.values)
        np.testing.assert_array_equal(a.indices, b.indices)
        assert (a.version, a.count, a.j, a.checksum) == \
            (b.version, b.count, b.j, b.checksum)
    # receiver remembers its position; sender sequence survives restart
    assert rx.recv() == []
    SpoolChannel(root).send(_payload(version=4, seed=4))
    assert [g.version for g in rx.recv()] == [4]


def test_memory_channel_fifo():
    ch = MemoryChannel()
    for v in (1, 2):
        ch.send(_payload(version=v))
    assert [p.version for p in ch.recv()] == [1, 2]
    assert ch.recv() == []


def test_faulty_channel_one_sided_injection():
    """A wrapper used on the SEND side must not re-inject on recv —
    an even number of identical bit flips cancels out."""
    sched = faults.parse_channel_schedule("corrupt:0.999,seed=1")
    ch = FaultyChannel(MemoryChannel(), sched)
    p = _payload(version=1)
    ch.send(p)
    (got,) = ch.recv()
    assert got.verify() == "corrupt"   # flipped exactly once


# ---------------------------------------------------------------------------
# Channel fault schedules (core/faults.py)
# ---------------------------------------------------------------------------

def test_channel_schedule_parse_format_roundtrip():
    for spec in ("loss:0.3,seed=5", "corrupt:0.01,seed=0",
                 "reorder:4,seed=2", "stall:10,every=50,at=20"):
        s = faults.parse_channel_schedule(spec)
        assert faults.parse_channel_schedule(
            faults.format_channel_schedule(s)) == s
    assert faults.parse_channel_schedule("") is None
    assert faults.parse_channel_schedule("none") is None
    assert faults.format_channel_schedule(None) == ""
    # keyword form == bare form
    assert faults.parse_channel_schedule("loss:p=0.3") == \
        faults.parse_channel_schedule("loss:0.3")


def test_channel_schedule_rejects_bad_specs():
    for bad in ("jitter:0.5", "loss:1.0", "loss:-0.1", "reorder:0",
                "stall:0", "stall:10,every=5", "loss:0.1,huh"):
        with pytest.raises(ValueError):
            faults.parse_channel_schedule(bad)


def test_channel_decisions_deterministic_and_seeded():
    s1 = faults.parse_channel_schedule("loss:0.5,seed=3")
    s2 = faults.parse_channel_schedule("loss:0.5,seed=4")
    d1 = [bool(faults.channel_drops(s1, v)) for v in range(64)]
    assert d1 == [bool(faults.channel_drops(s1, v)) for v in range(64)]
    assert d1 != [bool(faults.channel_drops(s2, v)) for v in range(64)]
    assert 0.25 < np.mean(d1) < 0.75
    r = faults.parse_channel_schedule("reorder:3,seed=1")
    delays = [int(faults.channel_delay(r, v)) for v in range(64)]
    assert min(delays) >= 0 and max(delays) <= 3 and max(delays) > 0
    st = faults.parse_channel_schedule("stall:5,at=3")
    stalled = [bool(faults.channel_stalled(st, v)) for v in range(12)]
    assert stalled == [False] * 3 + [True] * 5 + [False] * 4
    per = faults.parse_channel_schedule("stall:2,every=4,at=1")
    assert [bool(faults.channel_stalled(per, v)) for v in range(9)] == \
        [False, True, True, False, False, True, True, False, False]


def test_expected_delivery_rate_and_describe():
    assert faults.expected_delivery_rate(None) == 1.0
    assert faults.expected_delivery_rate(
        faults.parse_channel_schedule("loss:0.2")) == pytest.approx(0.8)
    assert faults.expected_delivery_rate(
        faults.parse_channel_schedule("reorder:4")) == 1.0
    d = faults.describe_channel(faults.parse_channel_schedule("corrupt:0.1"))
    assert d["kind"] == "corrupt"
    assert d["delivery_rate_expected"] == pytest.approx(0.9)
    assert faults.parse_channel_schedule(d["schedule"]) is not None
    import json
    json.dumps(d)


# ---------------------------------------------------------------------------
# The fault-trace invariant: ANY injected fault, replica holds v
# bit-equal to publisher-at-v or is mid-resync
# ---------------------------------------------------------------------------

def _run_faulty(spec, tmp_path, steps=25, snap_every=8, k=24):
    params = _tree(jax.random.PRNGKey(8))
    pub = DeltaPublisher(params, k=k, record_history=True)
    app = DeltaApplier(params)
    chan = FaultyChannel(MemoryChannel(),
                         faults.parse_channel_schedule(spec))
    snap = str(tmp_path / "snaps")
    write_snapshot(snap, params, 0)
    cur = params
    for t in range(steps):
        cur = _walk(cur, t)
        chan.send(pub.publish(cur))
        if pub.version % snap_every == 0:
            pub.write_snapshot(snap)
        drain(chan, app)
        if app.needs_resync and app.can_resync(snap):
            app.resync_from(snap)
        # THE invariant: held version bit-equal to publisher-at-version
        _assert_trees_equal(app.params, pub.params_at(app.version),
                            msg=f"{spec} @ t={t} v{app.version}")
        assert np.all([np.all(np.isfinite(np.asarray(l, np.float32)))
                       for l in jax.tree_util.tree_leaves(app.params)])
    # end of stream: flush the channel, final snapshot, converge
    for p in chan.flush():
        app.offer(p)
    pub.write_snapshot(snap)
    if app.needs_resync and app.can_resync(snap):
        app.resync_from(snap)
    drain(chan, app)
    _assert_trees_equal(app.params, pub.params_at(app.version), msg=spec)
    assert app.version == pub.version, (spec, app.metrics())
    return app, chan


def test_invariant_under_loss(tmp_path):
    app, chan = _run_faulty("loss:0.4,seed=2", tmp_path)
    assert chan.counters["dropped"] > 0
    assert app.counters["gaps_detected"] > 0 and app.counters["resyncs"] > 0


def test_invariant_under_corruption(tmp_path):
    app, chan = _run_faulty("corrupt:0.4,seed=3", tmp_path)
    assert chan.counters["corrupted"] > 0
    assert app.counters["dropped_corrupt"] == chan.counters["corrupted"]
    assert app.counters["resyncs"] > 0


def test_invariant_under_reorder(tmp_path):
    app, chan = _run_faulty("reorder:3,seed=4", tmp_path)
    assert chan.counters["delayed"] > 0
    # reorder delivers everything eventually; anything early is stale
    # or gapped, never applied out of order
    assert app.counters["applied"] + app.counters["dropped_stale"] > 0


def test_invariant_under_stall_no_resync(tmp_path):
    """A paused link flushes IN ORDER: the replica absorbs the backlog
    with zero gaps and zero resyncs."""
    app, chan = _run_faulty("stall:5,at=3", tmp_path)
    assert chan.counters["stalled"] > 0
    assert app.counters["gaps_detected"] == 0
    assert app.counters["resyncs"] == 0
    assert app.counters["applied"] == 25


@pytest.mark.slow
def test_invariant_long_horizon_all_faults(tmp_path):
    """Long-horizon sweep over every fault kind (the CI fault-injection
    lane's delta-channel analogue of the elastic soak test)."""
    for i, spec in enumerate(("loss:0.25,seed=11", "corrupt:0.25,seed=12",
                              "reorder:5,seed=13",
                              "stall:7,every=20,at=5")):
        _run_faulty(spec, tmp_path / f"case{i}", steps=120, snap_every=16)


# ---------------------------------------------------------------------------
# In-flight consistency: pinned decode streams are bit-identical to a
# version-pinned oracle while deltas land between steps
# ---------------------------------------------------------------------------

def test_pinned_decode_unaffected_by_live_applies():
    from repro.configs.base import get_config, reduced_config
    from repro.models import Parallel, decode_step, init_params, prefill
    cfg = reduced_config(get_config("stablelm-3b"))
    pal = Parallel()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, pal, key)
    B, S, new = 2, 12, 6
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pre = jax.jit(lambda p, b: prefill(p, b, cfg, pal, max_seq=S + new))
    dec = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, pal))

    def run(p, interleave):
        """Greedy decode; interleave() fires between steps."""
        logits, cache = pre(p, {"tokens": prompt})
        toks = []
        for _ in range(new):
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(np.asarray(nxt))
            logits, cache = dec(p, cache, nxt)
            interleave()
        return toks, np.asarray(logits)

    # oracle: decode at version 0, nothing else happening
    oracle_toks, oracle_logits = run(params, lambda: None)

    pub = DeltaPublisher(params, k=256)
    app = DeltaApplier(params)
    chan = MemoryChannel()
    state = {"cur": params, "t": 0}

    def trainer_step():
        state["cur"] = _walk(state["cur"], state["t"], scale=0.5)
        state["t"] += 1
        chan.send(pub.publish(state["cur"]))
        drain(chan, app)

    pinned, pinned_v = app.acquire()
    assert pinned_v == 0
    live_toks, live_logits = run(pinned, trainer_step)
    # live tree moved...
    assert app.version == new and app.counters["applied"] == new
    # ...but the pinned stream is BIT-identical to the oracle
    np.testing.assert_array_equal(oracle_logits, live_logits)
    for a, b in zip(oracle_toks, live_toks):
        np.testing.assert_array_equal(a, b)
    # and a stream acquired NOW starts from the advanced version
    _, v2 = app.acquire()
    assert v2 == new


# ---------------------------------------------------------------------------
# Analytic costs (dryrun record + roofline terms)
# ---------------------------------------------------------------------------

def test_wire_cost_helpers():
    assert delta_wire_bytes(1024) == 1024 * 8 + 24
    assert resync_bytes(10_000) == 40_024
    r = resync_equiv_deltas(1_000_000, 1024)
    assert r == pytest.approx(4_000_024 / (1024 * 8 + 24))


def test_roofline_delta_terms():
    from repro.roofline.analysis import HW_V5E, roofline_terms
    rec = {
        "mesh": {"data": 4, "model": 2},
        "kind": "decode", "shape": "decode_32k",
        "active_params": 3_000_000_000,
        "flops": 1e12, "bytes_accessed": 1e11,
        "collective_bytes": {"total": 1e9},
        "delta": {"k": 4096,
                  "wire_bytes": delta_wire_bytes(4096),
                  "resync_bytes": resync_bytes(3_000_000_000),
                  "resync_equiv_deltas":
                      resync_equiv_deltas(3_000_000_000, 4096),
                  "fault": faults.describe_channel(
                      faults.parse_channel_schedule("loss:0.05"))},
    }
    t = roofline_terms(rec, HW_V5E)
    assert t["delta_wire_bytes"] == delta_wire_bytes(4096)
    assert t["delta_bcast_s"] == pytest.approx(
        delta_wire_bytes(4096) / HW_V5E.ici_bw)
    assert t["delta_apply_s"] == pytest.approx(16.0 * 4096 / HW_V5E.hbm_bw)
    assert t["resync_s"] == pytest.approx(
        resync_bytes(3_000_000_000) / HW_V5E.ici_bw)
    assert t["delta_delivery_rate"] == pytest.approx(0.95)
    # losing 5% of versions costs 5% of a resync-per-delta, amortized
    assert t["delta_wire_bytes_effective"] > t["delta_wire_bytes"]
    # clean channel: no effective-rate terms
    clean = dict(rec, delta=dict(rec["delta"], fault=None))
    tc = roofline_terms(clean, HW_V5E)
    assert "delta_delivery_rate" not in tc


def test_dryrun_record_carries_delta_costs(tmp_path):
    """CLI-level: --delta-k/--delta-fault-schedule land in the dryrun
    record with the analytic wire/resync costs (subprocess for device-
    count isolation, like test_system)."""
    import json
    import os
    import subprocess
    import sys
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    out_json = str(tmp_path / "dr.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "xlstm-125m", "--shape", "decode_32k", "--mesh", "2x2",
         "--delta-k", "4096", "--delta-fault-schedule", "loss:0.05",
         "--out", out_json],
        capture_output=True, text=True, timeout=1500, env=env, cwd=root)
    assert out.returncode == 0, (
        f"STDOUT:\n{out.stdout[-4000:]}\nSTDERR:\n{out.stderr[-4000:]}")
    (rec,) = json.load(open(out_json))["results"]
    d = rec["delta"]
    assert d["k"] == 4096
    assert d["wire_bytes"] == delta_wire_bytes(4096)
    assert d["resync_equiv_deltas"] > 1
    assert d["fault"]["kind"] == "loss"
    assert d["fault"]["delivery_rate_expected"] == pytest.approx(0.95)
