"""Optimizer unit tests (flat-vector, ZeRO slice semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim import apply_updates, init_opt_state, lr_at_step, opt_shard_len


def _run_steps(cfg, g_fn, steps=10, j=50):
    w = jnp.zeros((j,))
    st = init_opt_state(cfg, w)
    for _ in range(steps):
        g = g_fn(st["master"])
        w, st = apply_updates(cfg, st, g)
    return w, st


def test_sgd_quadratic_converges():
    cfg = OptimizerConfig(kind="sgd", lr=0.1)
    target = jnp.linspace(-1, 1, 50)
    w, _ = _run_steps(cfg, lambda w: w - target, steps=100)
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-3)


@pytest.mark.parametrize("kind", ["momentum", "adam", "adamw"])
def test_momentum_adam_converge(kind):
    cfg = OptimizerConfig(kind=kind, lr=0.05, momentum=0.9)
    target = jnp.linspace(-1, 1, 50)
    w, st = _run_steps(cfg, lambda w: w - target, steps=300)
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=2e-2)
    assert int(st["step"]) == 300


def test_adam_matches_reference_formula():
    cfg = OptimizerConfig(kind="adam", lr=1e-2, b1=0.9, b2=0.999, eps=1e-8)
    w0 = jnp.ones((4,))
    st = init_opt_state(cfg, w0)
    g = jnp.asarray([1.0, -2.0, 0.5, 0.0])
    w1, st = apply_updates(cfg, st, g)
    m = 0.1 * np.asarray(g)
    v = 0.001 * np.asarray(g) ** 2
    upd = (m / 0.1) / (np.sqrt(v / 0.001) + 1e-8)
    np.testing.assert_allclose(np.asarray(w1), 1.0 - 1e-2 * upd, rtol=1e-6)


def test_lr_schedule_warmup_cosine():
    cfg = OptimizerConfig(kind="sgd", lr=1.0, warmup_steps=10,
                          schedule="cosine", total_steps=110)
    assert float(lr_at_step(cfg, jnp.int32(0))) == pytest.approx(0.1)
    assert float(lr_at_step(cfg, jnp.int32(9))) == pytest.approx(1.0)
    assert float(lr_at_step(cfg, jnp.int32(110))) == pytest.approx(0.0, abs=1e-6)


def test_opt_shard_len_covers():
    for j in (100, 101, 16 * 7 + 3):
        for dp in (1, 2, 16):
            s = opt_shard_len(j, dp)
            assert s * dp >= j


def test_grad_clip():
    cfg = OptimizerConfig(kind="sgd", lr=1.0, grad_clip=1.0)
    w0 = jnp.zeros((3,))
    st = init_opt_state(cfg, w0)
    g = jnp.asarray([3.0, 4.0, 0.0])        # norm 5 -> scaled by 1/5
    st = dict(st, gnorm=jnp.linalg.norm(g))
    w1, _ = apply_updates(cfg, st, g)
    np.testing.assert_allclose(np.asarray(w1), [-0.6, -0.8, 0.0], rtol=1e-6)
