"""Two-sweep fused compression pipeline (kernels/compress) vs the dense
reference path: parity matrix, kernel-body checks (interpret mode),
adversarial tie/overflow fallbacks, and the O(J) sweep-count regression
(DESIGN.md §2.2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparsifierConfig
from repro.core import sparsify
from repro.kernels.compress import kernel as ck
from repro.kernels.compress import ops as cops
from repro.kernels.compress import ref as cref
from repro.kernels.compress.audit import audit_fn
from repro.kernels.compress.ops import sweep_plan


def _pair(kind, **kw):
    kw.setdefault("selector", "exact")
    ref = SparsifierConfig(kind=kind, **kw)
    return ref, dataclasses.replace(ref, pipeline="fused")


def _roundtrip(cfg_r, cfg_f, j, steps=4, seed=0, omega=0.25):
    """Run both pipelines side by side; assert support + value parity at
    every step (including the t=0 plain-top-k branch)."""
    key = jax.random.PRNGKey(seed)
    sr = sparsify.init_state(cfg_r, j)
    sf = sparsify.init_state(cfg_f, j)
    for t in range(steps):
        g = jax.random.normal(jax.random.fold_in(key, t), (j,))
        orr = sparsify.compress(cfg_r, sr, g, omega=omega)
        off = sparsify.compress(cfg_f, sf, g, omega=omega)
        # fused carries no dense mask; both reconstruct via the one
        # shared O(k) helper (no dtype branching)
        assert (sparsify.dense_mask(orr, j) ==
                sparsify.dense_mask(off, j)).all(), f"mask diverged at t={t}"
        gr = np.asarray(orr.ghat)
        gf = np.asarray(sparsify.dense_ghat(off, j))
        np.testing.assert_allclose(gr, gf, rtol=1e-5, atol=1e-6)
        # error feedback parity: fused err_prev is the ONE state vector,
        # maintained by the O(k) scatter-zero — bit-identical, not close
        np.testing.assert_array_equal(np.asarray(orr.state["err"]),
                                      np.asarray(off.state["err_prev"]))
        if orr.values is not None:
            assert set(np.asarray(orr.indices).tolist()) == \
                set(np.asarray(off.indices).tolist())
        agg = omega * gr
        sr = sparsify.observe_aggregate(cfg_r, orr.state, jnp.asarray(agg))
        sf = sparsify.observe_aggregate(cfg_f, off.state, jnp.asarray(agg))


class TestParityMatrix:
    @pytest.mark.parametrize("kind", ["topk", "dgc", "regtopk"])
    @pytest.mark.parametrize("comm_mode", ["simulate", "sparse"])
    def test_fused_matches_reference(self, kind, comm_mode):
        cfg_r, cfg_f = _pair(kind, sparsity=0.02, mu=0.5,
                             comm_mode=comm_mode)
        _roundtrip(cfg_r, cfg_f, j=12_345)

    def test_histogram_selector_is_fused_with_contract(self):
        """selector="histogram" is served by the fused pipeline since the
        capability-dispatch PR: threshold selection at the sweep-1
        bit-pattern bin edge, count in [k, hist_capacity]. The full
        contract suite lives in tests/test_fused_configs.py."""
        from repro.kernels.compress.dispatch import dispatch, hist_capacity
        cfg_r, cfg_f = _pair("topk", sparsity=0.02, selector="histogram")
        assert dispatch(cfg_f).path == "fused"
        assert dispatch(cfg_r).path == "reference"
        j = 20_000
        k = sparsify.resolve_k(cfg_f, j)
        st_f = sparsify.init_state(cfg_f, j)
        assert "err_prev" in st_f and "err" not in st_f   # fused layout
        assert "s_prev" not in st_f                       # no dense mask state
        g = jax.random.normal(jax.random.PRNGKey(11), (j,))
        off = sparsify.compress(cfg_f, st_f, g)
        n = int(sparsify.dense_mask(off, j).sum())
        assert k <= n <= hist_capacity(k, j)
        assert n == int(off.count)
        # the reference histogram selector keeps its own (linear-bin)
        # over-selection; both are supersets of the exact top-k
        orr = sparsify.compress(cfg_r, sparsify.init_state(cfg_r, j), g)
        assert int(orr.mask.sum()) >= k

    def test_bf16_ef_dtype_is_fused(self):
        """ef_dtype="bfloat16" takes the fused path: bf16 J-sized state,
        fp32 in-register sweep math (tolerance contract vs the fp32
        reference in tests/test_fused_configs.py)."""
        _, cfg_f = _pair("regtopk", sparsity=0.02, mu=0.5,
                         ef_dtype="bfloat16")
        j = 2_000
        st_f = sparsify.init_state(cfg_f, j)
        assert "err_prev" in st_f and "err" not in st_f   # fused layout
        assert st_f["err_prev"].dtype == jnp.bfloat16
        out = sparsify.compress(cfg_f, st_f, jax.random.normal(
            jax.random.PRNGKey(1), (j,)))
        assert int(sparsify.dense_mask(out, j).sum()) == \
            sparsify.resolve_k(cfg_f, j)

    @pytest.mark.parametrize("kind", ["randk", "thresholdk"])
    def test_randk_thresholdk_fused_parity(self, kind):
        """randk/thresholdk are fused since the capability-dispatch PR and
        must match the reference path (identical sampler / identical
        exact selection) — and both now pack (values, indices)."""
        cfg_r, cfg_f = _pair(kind, sparsity=0.05)
        j = 2_000
        key = jax.random.PRNGKey(1)
        sr = sparsify.init_state(cfg_r, j)
        sf = sparsify.init_state(cfg_f, j)
        assert "err_prev" in sf and "err" not in sf     # fused layout
        g = jax.random.normal(key, (j,))
        orr = sparsify.compress(cfg_r, sr, g, key=key)
        off = sparsify.compress(cfg_f, sf, g, key=key)
        assert (sparsify.dense_mask(orr, j) ==
                sparsify.dense_mask(off, j)).all()
        assert orr.values is not None and off.values is not None
        if kind == "randk":
            # shared sampler => identical index STREAM, not just support
            np.testing.assert_array_equal(np.asarray(orr.indices),
                                          np.asarray(off.indices))
        else:
            assert set(np.asarray(orr.indices).tolist()) == \
                set(np.asarray(off.indices).tolist())

    def test_sparse_comm_skips_dense_ghat(self):
        _, cfg_f = _pair("regtopk", sparsity=0.01, mu=0.5,
                         comm_mode="sparse")
        j = 8_192
        st = sparsify.init_state(cfg_f, j)
        out = sparsify.compress(cfg_f, st, jnp.ones((j,)))
        assert out.ghat is None
        assert out.values.shape[0] == sparsify.resolve_k(cfg_f, j)
        dense = sparsify.dense_ghat(out, j)
        assert int((dense != 0).sum()) == out.values.shape[0]

    def test_mu_small_reduces_to_topk(self):
        """mu -> 0 regularizer => fused REGTOP-k == fused TOP-k masks."""
        _, cfg_t = _pair("topk", k=15)
        _, cfg_r = _pair("regtopk", k=15, mu=1e-6, Q=0.0)
        j = 3_000
        st_t = sparsify.init_state(cfg_t, j)
        st_r = sparsify.init_state(cfg_r, j)
        key = jax.random.PRNGKey(7)
        for t in range(4):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            ot = sparsify.compress(cfg_t, st_t, g)
            orr = sparsify.compress(cfg_r, st_r, g)
            assert (sparsify.dense_mask(ot, j) ==
                    sparsify.dense_mask(orr, j)).all(), f"t={t}"
            agg = 0.5 * (sparsify.dense_ghat(ot, j) +
                         sparsify.dense_ghat(orr, j))
            st_t = sparsify.observe_aggregate(cfg_t, ot.state, agg)
            st_r = sparsify.observe_aggregate(cfg_r, orr.state, agg)


class TestAdversarial:
    """Tie and fixed-k compaction overflow cases route through the exact
    fallback and must still match the reference selector bit-for-bit."""

    @pytest.mark.parametrize("kind", ["topk", "regtopk"])
    @pytest.mark.parametrize("gname,gfn", [
        ("all-equal", lambda j: jnp.ones((j,))),          # compaction overflow
        ("all-zero", lambda j: jnp.zeros((j,))),
        ("boundary-ties", lambda j: jnp.where(
            jnp.arange(j) % 11 == 0, 2.0, 1.0)),          # ties at tau
        ("few-distinct", lambda j: (jnp.arange(j) % 3).astype(jnp.float32)),
    ])
    def test_degenerate_inputs(self, kind, gname, gfn):
        cfg_r, cfg_f = _pair(kind, k=64, mu=0.5)
        j = 6_000
        g = gfn(j)
        _roundtrip_static(cfg_r, cfg_f, g, steps=3)

    def test_tiny_and_edge_k(self):
        for j, k in ((64, 1), (100, 100), (257, 256)):
            cfg_r, cfg_f = _pair("regtopk", k=k, mu=0.5)
            _roundtrip(cfg_r, cfg_f, j=j, steps=3, seed=j)


def _roundtrip_static(cfg_r, cfg_f, g, steps=3, omega=0.5):
    j = g.shape[0]
    sr = sparsify.init_state(cfg_r, j)
    sf = sparsify.init_state(cfg_f, j)
    for t in range(steps):
        orr = sparsify.compress(cfg_r, sr, g, omega=omega)
        off = sparsify.compress(cfg_f, sf, g, omega=omega)
        assert (sparsify.dense_mask(orr, j) ==
                sparsify.dense_mask(off, j)).all(), f"t={t}"
        np.testing.assert_allclose(
            np.asarray(orr.ghat), np.asarray(sparsify.dense_ghat(off, j)),
            rtol=1e-5, atol=1e-6)
        agg = omega * orr.ghat
        sr = sparsify.observe_aggregate(cfg_r, orr.state, agg)
        sf = sparsify.observe_aggregate(cfg_f, off.state, agg)


class TestPallasKernels:
    """Kernel bodies under interpret=True vs the pure-jnp oracle."""

    def test_sweep1_plain(self):
        j = 3 * ck.BLOCK
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        g = jax.random.normal(ks[0], (j,))
        # err_prev: the ONE state vector (zero at the previous support)
        err_prev = jax.random.normal(ks[1], (j,)) * (
            jax.random.uniform(ks[2], (j,)) >= 0.1)
        a, score, _mom, amax, hist = ck.sweep1_pallas(
            g, err_prev, 1.0, mode="plain", interpret=True)
        a_ref, score_ref, _ = cref.dense_scores_ref(g, err_prev,
                                                    1, kind="topk")
        np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(score), np.asarray(score_ref),
                                   rtol=1e-6, atol=1e-6)
        # per-block amax + accumulated bit-pattern histogram
        keys = np.abs(np.asarray(score_ref)).reshape(-1, ck.BLOCK)
        np.testing.assert_allclose(np.asarray(amax), keys.max(axis=1),
                                   rtol=1e-6)
        assert int(hist.sum()) == j
        bins = np.asarray(ck.bit_bin(jnp.abs(score_ref)))
        np.testing.assert_array_equal(np.asarray(hist),
                                      np.bincount(bins, minlength=ck.BINS))

    def test_sweep1_dgc_momentum(self):
        j = ck.BLOCK
        key = jax.random.PRNGKey(1)
        g = jax.random.normal(key, (j,))
        mom = jax.random.normal(jax.random.fold_in(key, 1), (j,))
        a, _score, mom_out, _amax, _hist = ck.sweep1_pallas(
            g, jnp.zeros((j,)), 1.0, mode="dgc",
            momentum=0.9, mom=mom, interpret=True)
        np.testing.assert_allclose(np.asarray(mom_out),
                                   np.asarray(0.9 * mom + g),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(a),
                                   np.asarray(0.9 * mom + g),
                                   rtol=1e-6, atol=1e-6)

    def test_threshold_brackets_kth(self):
        j = 2 * ck.BLOCK
        x = jax.random.normal(jax.random.PRNGKey(2), (j,))
        keys = jnp.abs(x)
        hist = jnp.asarray(np.bincount(np.asarray(ck.bit_bin(keys)),
                                       minlength=ck.BINS), jnp.int32)
        for k in (1, 17, 500):
            tau = float(ck.threshold_from_hist(hist, k))
            kth = float(jnp.sort(keys)[-k])
            assert tau <= kth + 1e-7
            assert int((keys >= tau).sum()) >= k

    def test_sweep2_compaction(self):
        j = 4 * ck.BLOCK
        x = jax.random.normal(jax.random.PRNGKey(3), (j,))
        score = x
        tau = float(jnp.sort(jnp.abs(x))[-100])
        maxpb = 64
        mask, vals, idx, cnts = ck.sweep2_pallas(score, tau, maxpb=maxpb,
                                                 interpret=True)
        keys = np.abs(np.asarray(score))
        expect = keys >= tau
        np.testing.assert_array_equal(np.asarray(mask), expect.astype(np.uint8))
        assert np.asarray(cnts).sum() == expect.sum()
        valid = np.asarray(idx) != ck.INVALID_IDX
        got = set(np.asarray(idx)[valid].tolist())
        assert got == set(np.nonzero(expect)[0].tolist())
        np.testing.assert_allclose(np.sort(np.asarray(vals)[valid]),
                                   np.sort(keys[expect]), rtol=1e-6)

    def test_pallas_strategy_full_parity(self):
        """fused_compress_arrays(strategy="pallas_interpret") == reference."""
        j, k = 2 * ck.BLOCK, 37
        cfg_r = SparsifierConfig(kind="regtopk", k=k, mu=0.5,
                                 selector="exact")
        sr = sparsify.init_state(cfg_r, j)
        err_prev = jnp.zeros((j,))
        idx_prev = jnp.zeros((k,), jnp.uint32)
        aps = jnp.zeros((k,))
        gps = jnp.zeros((k,))
        step = jnp.zeros((), jnp.int32)
        key = jax.random.PRNGKey(5)
        for t in range(3):
            g = jax.random.normal(jax.random.fold_in(key, t), (j,))
            orr = sparsify.compress(cfg_r, sr, g, omega=0.25)
            out = cops.fused_compress_arrays(
                "regtopk", g, err_prev, step, k=k, omega=0.25, mu=0.5,
                Q=0.0, idx_prev=idx_prev, a_prev_sel=aps, g_prev_sel=gps,
                want_ghat=True, strategy="pallas_interpret")
            assert set(np.asarray(orr.indices).tolist()) == \
                set(np.asarray(out["indices"]).tolist()), f"t={t}"
            np.testing.assert_allclose(np.asarray(orr.ghat),
                                       np.asarray(out["ghat"]),
                                       rtol=1e-6, atol=1e-7)
            # post-step state parity: err_prev == reference a * (1 - s)
            np.testing.assert_array_equal(np.asarray(orr.state["err"]),
                                          np.asarray(out["err"]))
            agg = 0.25 * orr.ghat
            sr = sparsify.observe_aggregate(cfg_r, orr.state, agg)
            err_prev = out["err"]
            idx_prev, aps = out["indices"], out["values"]
            gps = agg[idx_prev.astype(jnp.int32)]
            step = step + 1


class TestSweepCount:
    """Traced-shape audit: the fused pipeline must stay <= 2 O(J) HBM
    traversals per compress step on the production (sparse-comm) path —
    the err_prev layout leaves NO third sweep (state writes are O(k)
    scatters) — vs ~8 logical passes (audit: >= 6) for the reference
    path. Writes are gated too (write_units, DESIGN.md §2.3)."""

    @staticmethod
    def _audit(pipeline, comm_mode, j=1 << 18):
        cfg = SparsifierConfig(kind="regtopk", k=j // 1000, mu=0.5,
                               selector="exact", comm_mode=comm_mode,
                               pipeline=pipeline)
        state = sparsify.init_state(cfg, j)
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def f(state, g):
            o = sparsify.compress(cfg, state, g, omega=0.25)
            outs = [o.mask, o.state, o.values, o.indices]
            if o.ghat is not None:
                outs.append(o.ghat)
            return tuple(jax.tree_util.tree_leaves(outs))

        return audit_fn(f, state, g, j=j, donate_argnums=(0,))

    def test_fused_sparse_within_budget(self):
        res = self._audit("fused", "sparse")
        assert res["traversals"] <= 2, res
        assert res["read_units"] <= 3.5, res
        # writes: sweep-1's (a, keys) streams only — the mask-write
        # sweep of the (a_prev, s_prev) layout is gone
        assert res["write_units"] <= 2.0, res

    def test_fused_simulate_within_budget(self):
        res = self._audit("fused", "simulate")
        assert res["traversals"] <= sweep_plan("fused", "simulate")["o_j_passes"], res

    def test_reference_is_heavier(self):
        ref = self._audit("reference", "sparse")
        fus = self._audit("fused", "sparse")
        assert ref["traversals"] >= 6, ref
        assert ref["traversals"] > fus["traversals"]
        assert ref["read_units"] > 2 * fus["read_units"], (ref, fus)
        assert ref["write_units"] > fus["write_units"], (ref, fus)

    def test_plan_matches_audit(self):
        assert sweep_plan("fused", "sparse")["o_j_passes"] == 2
        assert sweep_plan("fused", "simulate")["o_j_passes"] == 3
        assert sweep_plan("reference")["full_sorts"] == 2


class TestShardMapSync:
    """sync_gradient under shard_map: fused sparse == fused simulate ==
    reference, on a 1-device mesh."""

    @pytest.mark.parametrize("comm_mode", ["simulate", "sparse"])
    def test_sync_parity(self, comm_mode):
        from jax.sharding import PartitionSpec as P
        from repro.core import aggregate as agg
        j = 4_096
        cfg_r, cfg_f = _pair("regtopk", sparsity=0.01, mu=0.5,
                             comm_mode=comm_mode)
        mesh = jax.make_mesh((1,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (j,))

        def run(cfg):
            st = sparsify.init_state(cfg, j)

            def f(g, st):
                return agg.GradientSync(cfg, ("data",))(st, g)[0]

            with mesh:
                fn = jax.jit(jax.shard_map(
                    f, mesh=mesh,
                    in_specs=(P("data"), jax.tree_util.tree_map(
                        lambda _: P(), st)),
                    out_specs=P("data"), check_vma=False))
                return fn(g, st)

        np.testing.assert_allclose(np.asarray(run(cfg_r)),
                                   np.asarray(run(cfg_f)),
                                   rtol=1e-5, atol=1e-6)


class TestRandkBigIndex:
    def test_randk_uses_uint32_and_bigvec(self):
        cfg = SparsifierConfig(kind="randk", k=16, selector="exact")
        j = 1_000
        st = sparsify.init_state(cfg, j)
        out = sparsify.compress(cfg, st, jnp.arange(j, dtype=jnp.float32),
                                key=jax.random.PRNGKey(0))
        assert out.indices.dtype == jnp.uint32
        assert int(sparsify.dense_mask(out, j).sum()) == 16
        np.testing.assert_allclose(
            np.asarray(out.values),
            np.asarray(out.indices).astype(np.float32))
