"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.roofline_report \
      --single results/dryrun_single.json --multi results/dryrun_multipod.json
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import SHAPES, list_archs
from repro.roofline.analysis import HW_V5E, roofline_terms

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path):
    try:
        d = json.load(open(path))
    except FileNotFoundError:
        return {}, []
    recs = {(r["arch"], r["shape"]): r for r in d.get("results", [])}
    return recs, d.get("failures", [])


def fmt_bytes(b):
    for u, s in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(b) >= s:
            return f"{b / s:.1f}{u}"
    return f"{b:.0f}B"


def dryrun_table(recs):
    """XLA arg/temp sizes are reported raw (CPU-backend aggregation is
    backend-dependent — the fits-check uses roofline/memory_model.py)."""
    rows = ["| arch | shape | XLA args (raw) | XLA temp (raw) | HLO GFLOP/dev | "
            "wire bytes/dev | ag / rs / ar / a2a / cp |",
            "|---|---|---|---|---|---|---|"]
    for a in list_archs():
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if not r:
                rows.append(f"| {a} | {s} | MISSING | | | | |")
                continue
            mem = r["memory"]
            c = r["hlo_collectives"]
            cl = " / ".join(fmt_bytes(c.get(k, 0)) for k in
                            ("all-gather", "reduce-scatter", "all-reduce",
                             "all-to-all", "collective-permute"))
            rows.append(
                f"| {a} | {s} | {fmt_bytes(mem['argument_size_in_bytes'])} "
                f"| {fmt_bytes(mem['temp_size_in_bytes'])} "
                f"| {r['hlo_flops']/1e9:.1f} "
                f"| {fmt_bytes(r['hlo_collective_wire_bytes'])} | {cl} |")
    return "\n".join(rows)


def fits_table():
    from repro.configs.base import RunConfig, SparsifierConfig
    from repro.roofline.memory_model import per_device_memory
    rows = ["| arch | EF layout | params | opt | EF | act | total/dev | "
            "peak/dev | fits 16GB? |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in list_archs():
        from repro.configs.base import get_config
        cfg = get_config(a)
        for sf, ed, tag in (("dense", "float32", "paper-dense fp32"),
                            ("sparse", "bfloat16", "sparse+bf16")):
            run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                            sparsifier=SparsifierConfig(
                                kind="regtopk", sparsity=0.001,
                                state_format=sf, ef_dtype=ed))
            mb = per_device_memory(run, kind="train")
            rows.append(
                f"| {a} | {tag} | {mb.params/1e9:.2f} | {mb.opt/1e9:.2f} | "
                f"{mb.ef/1e9:.2f} | {mb.activations/1e9:.2f} | "
                f"{mb.total/1e9:.2f} GB | {mb.peak/1e9:.2f} GB | "
                f"{'YES' if mb.peak <= 16e9 else 'NO'} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
            "dominant | 6ND/HLO | MFU-ub | what would move the bottleneck |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in list_archs():
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if not r:
                continue
            t = roofline_terms(r, HW_V5E)
            hint = {
                "compute": "higher-arithmetic-intensity kernels / more chips",
                "memory": "flash-attention Pallas kernel; fuse EF pass; "
                          "bf16 sparsifier state",
                "collective": "sparser sync (lower S) / overlap collectives "
                              "with compute / ring schedule",
            }[t["dominant"]]
            rows.append(
                f"| {a} | {s} | {t['compute_s']*1e3:.1f} | "
                f"{t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} | "
                f"**{t['dominant']}** | {t['useful_ratio']:.2f} | "
                f"{t['mfu_upper_bound']*100:.0f}% | {hint} |")
    return "\n".join(rows)


def streaming_table(path):
    """§Streaming overlap (DESIGN.md §2.8): exposed-communication view of
    overlap="backward" records — the serialized collective term next to
    the comm-behind-backward exposed term (strictly smaller whenever the
    record streams >= 2 segments and the gather share is positive)."""
    try:
        results = json.load(open(path)).get("results", [])
    except FileNotFoundError:
        return ""
    recs = [r for r in results if r.get("overlap") == "backward"]
    if not recs:
        return ""
    rows = ["| arch | shape | segments | collective (ms) | "
            "exposed serial (ms) | exposed streamed (ms) | hidden (ms) |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        t = roofline_terms(r, HW_V5E)
        gather = r.get("sparse_gather_wire_bytes",
                       r.get("hlo_collective_wire_bytes", 0)) / HW_V5E.ici_bw
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t.get('num_stream_segments', 1)}"
            f" | {t['collective_s']*1e3:.2f} | {gather*1e3:.2f} | "
            f"{(t['collective_exposed_backward_s'] - (t['collective_s'] - gather))*1e3:.2f}"
            f" | {t['backward_overlap_s']*1e3:.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="results/dryrun_single.json")
    ap.add_argument("--multi", default="results/dryrun_multipod.json")
    args = ap.parse_args()
    recs_s, fail_s = load(args.single)
    recs_m, fail_m = load(args.multi)
    print("## Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table(recs_s))
    print(f"\nfailures: {[(f['arch'], f['shape']) for f in fail_s]}")
    print("\n## Memory fits-check (analytic, train_4k)\n")
    print(fits_table())
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs_s))
    st = streaming_table(args.single)
    if st:
        print("\n## Streaming overlap (overlap=backward, DESIGN.md §2.8)\n")
        print(st)
    if recs_m:
        print("\n## Multi-pod (2x16x16 = 512 chips) — lowering proof\n")
        print(dryrun_table(recs_m))
        print(f"\nfailures: {[(f['arch'], f['shape']) for f in fail_m]}")


if __name__ == "__main__":
    main()
