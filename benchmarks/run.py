"""Benchmark harness — one entry per paper table/figure plus system
benchmarks. Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--json] \
      [--only fig1,kernels,compress,...]

``--json`` additionally persists machine-readable results for benches
that support it (currently ``compress`` -> BENCH_compress.json), so the
perf trajectory of the hot path is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

WRITE_JSON = False


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_fig1_toy(quick):
    from benchmarks.paper_experiments import fig1_toy_logistic
    t0 = time.time()
    out = fig1_toy_logistic(iters=100)
    us = (time.time() - t0) * 1e6 / 100
    stall = sum(1 for v in out["topk"] if abs(v - out["topk"][0]) < 1e-6)
    track = max(abs(a - b) for a, b in zip(out["regtopk"], out["none"]))
    _row("fig1_toy_top1_stall_iters", us, stall)
    _row("fig1_toy_regtop1_max_gap_vs_dense", us, f"{track:.4f}")


def bench_fig2_linreg(quick):
    from benchmarks.paper_experiments import fig2_linreg
    iters = 800 if quick else 3000
    t0 = time.time()
    res = fig2_linreg(iters=iters)
    us = (time.time() - t0) * 1e6 / (iters * 9)
    for S in (0.4, 0.5, 0.6):
        g_t = res[(S, "topk")][-1]
        g_r = res[(S, "regtopk")][-1]
        g_d = res[(S, "none")][-1]
        _row(f"fig2_linreg_S{S}_final_gap_topk", us, f"{g_t:.4e}")
        _row(f"fig2_linreg_S{S}_final_gap_regtopk", us, f"{g_r:.4e}")
        _row(f"fig2_linreg_S{S}_final_gap_dense", us, f"{g_d:.4e}")
        g_s = res[(S, "sketchtopk")][-1]
        _row(f"fig2_linreg_S{S}_final_gap_sketchtopk", us, f"{g_s:.4e}")
        _row(f"fig2_linreg_S{S}_regtopk_improvement", us,
             f"{g_t / max(g_r, 1e-12):.1f}x")
        _row(f"fig2_linreg_S{S}_sketchtopk_improvement", us,
             f"{g_t / max(g_s, 1e-12):.1f}x")


def bench_fig3_nn(quick):
    from benchmarks.paper_experiments import fig3_nn
    iters = 120 if quick else 400
    t0 = time.time()
    out = fig3_nn(iters=iters, eval_every=max(iters // 4, 1))
    us = (time.time() - t0) * 1e6 / iters
    acc_t = out["topk"][-1][1]
    acc_r = out["regtopk"][-1][1]
    _row("fig3_nn_S0.001_acc_topk", us, f"{acc_t:.4f}")
    _row("fig3_nn_S0.001_acc_regtopk", us, f"{acc_r:.4f}")
    _row("fig3_nn_S0.001_acc_gain", us, f"{(acc_r - acc_t) * 100:.1f}pp")


def bench_comm_volume(quick):
    from repro.configs.base import SparsifierConfig, get_config, list_archs
    from repro.core.aggregate import comm_bytes_per_step
    n_workers = 16
    for arch in list_archs():
        cfg = get_config(arch)
        j = cfg.param_count()
        dense = comm_bytes_per_step(
            SparsifierConfig(kind="none"), j, n_workers)["bytes"]
        for S in (0.01, 0.001):
            sp = comm_bytes_per_step(
                SparsifierConfig(kind="regtopk", sparsity=S,
                                 comm_mode="sparse"), j, n_workers)
            _row(f"comm_{arch}_S{S}_reduction", 0.0,
                 f"{dense / sp['bytes']:.0f}x")


def bench_kernels(quick):
    from repro.core import select
    j = 200_000 if quick else 1_000_000
    x = jax.random.normal(jax.random.PRNGKey(0), (j,))
    k = j // 1000
    for name, fn in (
        ("exact_topk_mask", jax.jit(lambda v: select.topk_mask_exact(v, k))),
        ("histogram_topk_mask_jnp",
         jax.jit(lambda v: select.topk_mask_histogram(v, k))),
    ):
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            fn(x).block_until_ready()
        _row(f"kernel_{name}_J{j}", (time.time() - t0) * 1e6 / 5, k)
    # fused EF pass (Pallas; interpret mode on CPU -> correctness timing only)
    from repro.kernels.fused_ef.ops import fused_regtopk_scores
    je = 131_072
    args = [jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(1), i),
                              (je,)) for i in range(5)]
    fn = jax.jit(lambda g, e, a, ga, s: fused_regtopk_scores(
        g, e, a, ga, s, omega=1 / 16, mu=0.5, Q=0.0))
    fn(*args)[0].block_until_ready()
    t0 = time.time()
    for _ in range(3):
        fn(*args)[0].block_until_ready()
    _row(f"kernel_fused_ef_scores_J{je}", (time.time() - t0) * 1e6 / 3,
         "interpret" if jax.default_backend() != "tpu" else "native")


def bench_compress(quick):
    """Reference vs fused two-sweep compress on the production
    (comm_mode="sparse") paths (DESIGN.md §2.2/§2.5):

    - group "regtopk_exact": the REGTOP-k exact-selector path, plus the
      bucketed (num_buckets=8) and auto-bucketed (num_buckets=0) fused
      variants (§2.4), and the density-allocation variants (§2.6:
      fused_prop / fused_adapt — per-segment budget split; every row
      carries an ``allocation`` column and the allocated rows must hold
      the same absolute 2-sweep / 2-write-unit fused budget), and the
      streaming variant (§2.8: fused_stream — overlap="backward" per-
      segment sweeps; same 2-sweep budget, plus the analytic
      exposed-comm pair the check_compress streaming gate compares);
    - group "topk_hist": the histogram-selector path — fused since the
      capability-dispatch PR (reference-pipeline histogram packs no
      pairs and degrades sparse comm, so its row times the simulate
      path);
    - group "fused_sketch": the per-worker unit of the sketch-
      coordinated path (§2.9) — accumulate a = err + g and CountSketch-
      encode it. reference = legacy vmap encode (materializes (rows, J)
      hash/sign intermediates); fused = ops.fused_sketch_encode (encode
      kernel reads a once), which must hold the same absolute 2-sweep
      sparse-path budget as every other fused row.
      benchmarks.check_compress REQUIRES this group in fresh results.

    us/call = min over repeats (microbenchmark convention); sweeps/step
    from the traced-shape audit. --json -> BENCH_compress.json (the
    committed copy is the baseline benchmarks.check_compress gates CI
    against: audit metrics per row + fused-beats-reference per group at
    the largest J)."""
    import dataclasses
    from repro.configs.base import SparsifierConfig

    sizes = [1 << 20] if quick else [1 << 20, 1 << 24]
    # min-over-repeats strips scheduler/steal noise; the 2-vCPU CI-class
    # boxes this runs on need a few more samples for a clean window
    repeats = 3 if quick else 8
    rows = []
    for j in sizes:
        cfg_ref = SparsifierConfig(kind="regtopk", sparsity=0.001, mu=0.5,
                                   selector="exact", comm_mode="sparse")
        cfg_fus = dataclasses.replace(cfg_ref, pipeline="fused")
        cfg_hr = SparsifierConfig(kind="topk", sparsity=0.001,
                                  selector="histogram", comm_mode="sparse")
        groups = (
            ("regtopk_exact", "regtopk", (
                ("reference", cfg_ref),
                ("fused", cfg_fus),
                ("fused_b8", dataclasses.replace(cfg_fus, num_buckets=8)),
                ("fused_auto", dataclasses.replace(cfg_fus, num_buckets=0)),
                ("fused_prop", dataclasses.replace(
                    cfg_fus, allocation="proportional")),
                ("fused_adapt", dataclasses.replace(
                    cfg_fus, allocation="adaptive")),
                ("fused_stream", dataclasses.replace(
                    cfg_fus, overlap="backward")),
            )),
            ("topk_hist", "topk_hist", (
                ("reference", cfg_hr),
                ("fused", dataclasses.replace(cfg_hr, pipeline="fused")),
            )),
        )
        cfg_sk = SparsifierConfig(kind="sketchtopk", sparsity=0.001,
                                  selector="exact", comm_mode="sparse")
        groups += (
            ("fused_sketch", "sketch", (
                ("reference", cfg_sk),
                ("fused", dataclasses.replace(cfg_sk, pipeline="fused")),
            )),
        )
        g = jax.random.normal(jax.random.PRNGKey(0), (j,), jnp.float32)
        for group, stem, variants in groups:
            us = {}
            for label, cfg in variants:
                bench_one = (_bench_sketch_one if group == "fused_sketch"
                             else _bench_compress_one)
                row = bench_one(cfg, g, j, repeats)
                us[label] = row["us_per_call"]
                row.update({"name": f"compress_{stem}_{label}_J{j}",
                            "group": group, "pipeline": label,
                            "selector": cfg.selector,
                            "comm_mode": cfg.comm_mode})
                rows.append(row)
                _row(row["name"], row["us_per_call"],
                     f"sweeps={row['sweeps_per_step']}")
            speedup = us["reference"] / us["fused"]
            tag = "" if group == "regtopk_exact" else f"_{group}"
            rows.append({"name": f"compress_speedup{tag}_J{j}", "j": j,
                         "group": group, "speedup": round(speedup, 2)})
            _row(f"compress_speedup{tag}_J{j}", 0.0, f"{speedup:.2f}x")
    if WRITE_JSON:
        payload = {"bench": "compress", "backend": jax.default_backend(),
                   "sparsity": 0.001, "comm_mode": "sparse",
                   "rows": rows}
        with open("BENCH_compress.json", "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")


# worker count the compress benchmark models (omega = 1/N_WORKERS and the
# num_buckets=0 auto-resolution must agree on it)
N_WORKERS = 16


def _bench_compress_one(cfg, g, j, repeats) -> dict:
    from repro.core import sparsify
    from repro.kernels.compress.audit import audit_fn
    state = sparsify.init_state(cfg, j)

    def f(state, g):
        o = sparsify.compress(cfg, state, g, omega=1 / N_WORKERS)
        outs = [o.state, o.values, o.indices]
        if o.ghat is not None:
            outs.append(o.ghat)
        return tuple(jax.tree_util.tree_leaves(outs))

    # timing methodology unchanged across PRs (fixed inputs, undonated,
    # min over repeats) so us_per_call rows stay comparable; the audit
    # below models the PRODUCTION calling convention — launch/train.py
    # donates the state, so err_prev/mom O(k) scatters update in place
    # (audit_fn's donate_argnums mirrors jit's).
    fn = jax.jit(f)
    jax.block_until_ready(fn(state, g))       # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(state, g))
        best = min(best, time.perf_counter() - t0)
    aud = audit_fn(f, state, g, j=j, donate_argnums=(0,))
    row = {"j": j, "num_buckets": cfg.num_buckets,
           "allocation": cfg.allocation, "overlap": cfg.overlap,
           "us_per_call": round(best * 1e6, 1),
           "sweeps_per_step": aud["traversals"],
           "read_units": round(aud["read_units"], 2),
           "write_units": round(aud["write_units"], 2)}
    if cfg.num_buckets == 0:
        row["num_buckets_resolved"] = sparsify.resolve_num_buckets(
            cfg, j, N_WORKERS)
    if cfg.overlap == "backward":
        # analytic exposed-comm model (roofline.comm_behind_backward_s,
        # DESIGN.md §2.8): the sparse gather either serializes after the
        # backward pass (serial) or streams behind it per segment
        # (stream). t_backward is LOWER-bounded by one fp32 re-read of
        # the gradient, so the streamed term is a conservative claim;
        # check_compress gates stream <= serial.
        from repro.core import allocate
        from repro.core.aggregate import sparse_gather_wire_bytes
        from repro.roofline.analysis import HW_V5E, comm_behind_backward_s
        gw = sparse_gather_wire_bytes(cfg, j, N_WORKERS)
        t_gather = (gw or 0) / HW_V5E.ici_bw
        t_bwd = j * 4 / HW_V5E.hbm_bw
        nseg = allocate.resolve_num_segments(cfg, j)
        row["num_stream_segments"] = nseg
        row["exposed_comm_serial_s"] = t_gather
        row["exposed_comm_stream_s"] = comm_behind_backward_s(
            t_gather, t_bwd, nseg)
    return row


def _bench_sketch_one(cfg, g, j, repeats) -> dict:
    """Per-worker unit of the sketch-coordinated path (DESIGN.md §2.9):
    accumulate a = err + g and CountSketch-encode it. Selection and the
    shared-mask decode run at the AGGREGATE level (after the sketch
    all-reduce), so they are not part of the per-worker compress unit
    this row times and audits."""
    from repro.core import sketch, sparsify
    from repro.kernels.compress import ops as cops
    from repro.kernels.compress.audit import audit_fn
    state = sparsify.init_state(cfg, j)
    n_rows = cfg.sketch_rows
    width = sketch.resolve_width(sparsify.resolve_k(cfg, j),
                                 cfg.sketch_width)
    if cfg.pipeline == "fused":
        def f(state, g):
            out = cops.fused_sketch_encode(g, state["err_prev"],
                                           rows=n_rows, width=width)
            return out["a"], out["sketch"]
    else:
        def f(state, g):
            a = state["err"].astype(jnp.float32) + g
            return a, sketch.encode(a, n_rows, width)

    fn = jax.jit(f)
    jax.block_until_ready(fn(state, g))       # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(state, g))
        best = min(best, time.perf_counter() - t0)
    aud = audit_fn(f, state, g, j=j, donate_argnums=(0,))
    return {"j": j, "num_buckets": cfg.num_buckets,
            "allocation": cfg.allocation, "overlap": cfg.overlap,
            "sketch_rows": n_rows, "sketch_width": width,
            "us_per_call": round(best * 1e6, 1),
            "sweeps_per_step": aud["traversals"],
            "read_units": round(aud["read_units"], 2),
            "write_units": round(aud["write_units"], 2)}


def bench_train_step(quick):
    """Smoke-scale distributed train step wall time per sparsifier."""
    from repro.configs.base import (OptimizerConfig, RunConfig, SHAPES,
                                    SparsifierConfig, get_config,
                                    reduced_config)
    from repro.data import lm_batch
    from repro.train.step import (build_parallel, build_train_step,
                                  init_train_state)
    cfg = reduced_config(get_config("stablelm-3b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for kind, pipeline in (("none", "reference"), ("topk", "reference"),
                           ("regtopk", "reference"), ("regtopk", "fused")):
        run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        sparsifier=SparsifierConfig(kind=kind, sparsity=0.01,
                                                    pipeline=pipeline),
                        optimizer=OptimizerConfig(kind="adam", lr=1e-3))
        pal = build_parallel(mesh)
        with mesh:
            params, opt_state, ef_state = init_train_state(
                run, mesh, pal, jax.random.PRNGKey(0))
            step, _, _ = build_train_step(run, mesh, pal)
            jstep = jax.jit(step)
            batch = lm_batch(cfg, 4, 64, 0, 0)
            out = jstep(params, opt_state, ef_state, batch,
                        jax.random.PRNGKey(0))
            jax.block_until_ready(out)
            t0 = time.time()
            n = 3
            m = None
            for t in range(n):
                params, opt_state, ef_state, m = jstep(
                    params, opt_state, ef_state, batch, jax.random.PRNGKey(t))
            jax.block_until_ready(params)
            tag = kind if pipeline == "reference" else f"{kind}_{pipeline}"
            _row(f"train_step_smoke_{tag}", (time.time() - t0) * 1e6 / n,
                 f"loss={float(m['loss']):.3f}")


BENCHES = {
    "fig1": bench_fig1_toy,
    "fig2": bench_fig2_linreg,
    "fig3": bench_fig3_nn,
    "comm": bench_comm_volume,
    "kernels": bench_kernels,
    "compress": bench_compress,
    "train_step": bench_train_step,
}


def main() -> None:
    global WRITE_JSON
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="persist machine-readable results (BENCH_*.json)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    WRITE_JSON = args.json
    names = args.only.split(",") if args.only else list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; known: {sorted(BENCHES)}")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n](args.quick)


if __name__ == "__main__":
    main()
