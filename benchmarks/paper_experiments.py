"""Paper-experiment drivers shared by benchmarks and examples.

One function per paper figure:
- fig1_toy_logistic   (§1.2, Fig 1)  — TOP-1 stall vs REGTOP-1 tracking
- fig2_linreg         (§4.1, Fig 2)  — optimality gap at S in {0.4,0.5,0.6}
- fig3_nn             (§4.2, Fig 3)  — DNN accuracy at S=0.001, N=8 workers
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SparsifierConfig
from repro.core import sparsify
from repro.data.synthetic import image_dataset, linreg_dataset


# ---------------------------------------------------------------------------
# Fig 1: toy logistic regression (§1.2)
# ---------------------------------------------------------------------------

def fig1_toy_logistic(iters=100, eta=0.9, mu=0.5, Q=0.0):
    xs = [jnp.array([100.0, 1.0]), jnp.array([-100.0, 1.0])]

    def grad_n(w, xn):
        e = jnp.exp(-jnp.dot(w, xn))
        return -e * xn / (1 + e)

    def loss(w):
        return 0.5 * sum(jnp.log(1 + jnp.exp(-jnp.dot(w, xn))) for xn in xs)

    out = {}
    for kind in ("none", "topk", "regtopk"):
        cfg = SparsifierConfig(kind=kind, k=1, mu=mu, Q=Q, selector="exact")
        w = jnp.array([0.0, 1.0])
        states = [sparsify.init_state(cfg, 2) for _ in range(2)]
        hist = []
        for _ in range(iters):
            grads = [grad_n(w, xn) for xn in xs]
            if kind == "none":
                g = 0.5 * (grads[0] + grads[1])
            else:
                g, states = sparsify.sparsified_round(cfg, states, grads)
            w = w - eta * g
            hist.append(float(loss(w)))
        out[kind] = hist
    return out


# ---------------------------------------------------------------------------
# Fig 2: distributed linear regression (§4.1)
# ---------------------------------------------------------------------------

def fig2_linreg(S_values=(0.4, 0.5, 0.6), iters=3000, eta=1e-2, mu=0.5,
                n_workers=20, n_points=500, dim=100, seed=0,
                kinds=("none", "topk", "regtopk", "sketchtopk")):
    xs, ys, w_star = linreg_dataset(n_workers, n_points, dim, seed=seed)

    def grad_n(w, X, y):
        r = X @ w - y
        return X.T @ r / X.shape[0]

    grad_all = jax.jit(lambda w: jnp.stack([grad_n(w, X, y)
                                            for X, y in zip(xs, ys)]))

    results = {}
    for S in S_values:
        for kind in kinds:
            cfg = SparsifierConfig(kind=kind, sparsity=S, mu=mu,
                                   selector="exact")
            w = jnp.zeros((dim,))
            states = sparsify.stack_states(
                [sparsify.init_state(cfg, dim) for _ in range(n_workers)])
            round_fn = sparsify.make_round_fn(cfg, n_workers)
            gaps = []
            for _ in range(iters):
                grads = grad_all(w)
                if kind == "none":
                    g = jnp.mean(grads, 0)
                else:
                    g, states = round_fn(states, grads)
                w = w - eta * g
                gaps.append(float(jnp.linalg.norm(w - w_star)))
            results[(S, kind)] = gaps
    return results


# ---------------------------------------------------------------------------
# Fig 3: DNN on synthetic images (§4.2 analogue)
# ---------------------------------------------------------------------------

def fig3_nn(iters=400, n_workers=8, batch=20, S=0.001, eta=0.01, mu=0.5,
            seed=0, eval_every=50, kinds=("topk", "regtopk"), width=16,
            sketch_rows=3, sketch_width=0):
    from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
    xtr, ytr, xte, yte = image_dataset(n_train=n_workers * 500, seed=seed)
    # split evenly among workers (paper: data distributed evenly)
    xw = np.array_split(np.asarray(xtr), n_workers)
    yw = np.array_split(np.asarray(ytr), n_workers)

    p0 = init_cnn(jax.random.PRNGKey(seed), width=width)
    flat0, unravel = jax.flatten_util.ravel_pytree(p0)
    j = flat0.size

    def worker_grad(vec, xb, yb):
        p = unravel(vec)
        return jax.flatten_util.ravel_pytree(
            jax.grad(cnn_loss)(p, xb, yb))[0]

    wg = jax.jit(worker_grad)

    out = {}
    for kind in kinds:
        cfg = SparsifierConfig(kind=kind, sparsity=S, mu=mu, selector="exact",
                               sketch_rows=sketch_rows,
                               sketch_width=sketch_width)
        vec = jnp.array(flat0)
        states = sparsify.stack_states(
            [sparsify.init_state(cfg, j) for _ in range(n_workers)])
        round_fn = (sparsify.make_round_fn(cfg, n_workers)
                    if kind != "none" else None)
        rng = np.random.default_rng(seed)   # identical batch order per kind
        accs = []
        for t in range(iters):
            grads = []
            for n in range(n_workers):
                idx = rng.integers(0, xw[n].shape[0], size=batch)
                grads.append(wg(vec, jnp.asarray(xw[n][idx]),
                                jnp.asarray(yw[n][idx])))
            grads = jnp.stack(grads)
            if kind == "none":
                g = jnp.mean(grads, 0)
            else:
                g, states = round_fn(states, grads)
            vec = vec - eta * g
            if (t + 1) % eval_every == 0:
                accs.append((t + 1, cnn_accuracy(unravel(vec), xte, yte)))
        out[kind] = accs
    return out
