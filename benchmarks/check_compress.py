"""CI bench-regression gate for the compression hot path.

  PYTHONPATH=src python -m benchmarks.check_compress BASELINE.json FRESH.json

Compares a freshly produced BENCH_compress.json (``benchmarks.run --json
--only compress``) against the committed baseline and FAILS (exit 1) if:

- any fused-pipeline row regressed its deterministic audit metrics —
  ``sweeps_per_step`` (O(J)-traversal J-equivalents), ``read_units``,
  or ``write_units`` (streamed J-fp32-equivalents, DESIGN.md §2.3)
  above the baseline row of the same name. Rows carry an ``allocation``
  column (DESIGN.md §2.6); the allocated fused variants (fused_prop /
  fused_adapt) are gated exactly like the rest — per-segment budget
  allocation must not cost a traversal;
- any SPARSE-COMM fused row (``comm_mode`` on the row, falling back to
  the payload-level field) exceeds the ABSOLUTE two-traversal budget
  (``sweeps_per_step`` > FUSED_MAX_TRAVERSALS): the err_prev state
  layout makes the whole sparse-path step 2 sweeps, and a third one
  creeping back in is a regression even if a stale baseline row also
  had it. Dense/simulate fused rows are exempt — their extra ghat
  write is by design (ops.sweep_plan);
- any streaming row (``overlap == "backward"``, the fused_stream
  variant, DESIGN.md §2.8) is missing its analytic exposed-comm pair or
  reports ``exposed_comm_stream_s`` above ``exposed_comm_serial_s`` —
  streaming must hide collective time behind the backward pass, never
  add any (its sweep budget is gated by the absolute rule above);
- in any benchmark group (``group`` field: the exact-selector REGTOP-k
  path, the histogram-selector path, ...) at the largest J where the
  group has BOTH a reference and a fused row, no fused variant's
  us/call is faster than the reference row (wall-clock is noisy on
  shared CI runners, so only these robust orderings are gated, not
  absolute timings; NEW groups missing either side are reported, never
  failed — but a group the baseline gated must keep a comparable pair,
  so a dropped/renamed reference row cannot silently disarm the gate).

- the fresh results lack any REQUIRED_GROUPS group with a comparable
  reference/fused pair ("fused_sketch": the sketch-coordinated encode
  unit, DESIGN.md §2.9) — required independent of the baseline so a
  stale baseline cannot disarm the gate.

Rows present in only one file are reported but never fail the gate
(adding a new benchmark row must not need a two-step merge dance).
"""
from __future__ import annotations

import argparse
import json
import sys

# deterministic integer-ish metrics get an epsilon for float formatting
# noise only; a real regression moves them by >= 1/num_buckets
EPS = 1e-6
# absolute O(J)-traversal budget of the fused SPARSE-COMM compress step
# (sweep 1 + sweep 2; all state updates are O(k) since the err_prev
# layout — DESIGN.md §2.2). Dense/simulate fused rows are 3 by design.
FUSED_MAX_TRAVERSALS = 2.0
# groups the FRESH results must always carry with a comparable
# reference/fused pair — independent of the baseline (a baseline that
# predates the group must not disarm its gate). "fused_sketch" is the
# sketch-coordinated encode unit (DESIGN.md §2.9): its fused row holds
# the same absolute sparse-path budget and must beat the legacy encode.
REQUIRED_GROUPS = ("fused_sketch",)


def _rows_by_name(payload: dict) -> dict:
    return {r["name"]: r for r in payload.get("rows", []) if "name" in r}


def check(baseline: dict, fresh: dict) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    failures = []
    base = _rows_by_name(baseline)
    new = _rows_by_name(fresh)
    payload_comm = fresh.get("comm_mode", "sparse")

    for name, row in sorted(new.items()):
        if row.get("pipeline", "").startswith("fused"):
            sw = row.get("sweeps_per_step")
            if (sw is not None and sw > FUSED_MAX_TRAVERSALS + EPS
                    and row.get("comm_mode", payload_comm) == "sparse"):
                failures.append(
                    f"{name}: sweeps_per_step {sw} exceeds the absolute "
                    f"sparse-path fused budget {FUSED_MAX_TRAVERSALS}")
            if row.get("overlap") == "backward":
                # streaming gate (DESIGN.md §2.8): the comm-behind-
                # backward exposed term must never exceed the serialized
                # one, and streaming must not cost a sweep (the absolute
                # budget above already covers the latter; this pins the
                # claim the fused_stream rows exist to make)
                ser = row.get("exposed_comm_serial_s")
                stm = row.get("exposed_comm_stream_s")
                if ser is None or stm is None:
                    failures.append(
                        f"{name}: overlap='backward' row lacks the "
                        "exposed_comm_serial_s/exposed_comm_stream_s pair")
                elif stm > ser + EPS:
                    failures.append(
                        f"{name}: streaming exposed comm {stm} exceeds "
                        f"the serialized term {ser}")
            ref_row = base.get(name)
            if ref_row is None:
                print(f"[check_compress] new row (not gated): {name}")
                continue
            for metric in ("sweeps_per_step", "read_units", "write_units"):
                got, want = row.get(metric), ref_row.get(metric)
                if got is None or want is None:
                    continue
                if got > want + EPS:
                    failures.append(
                        f"{name}: {metric} regressed {want} -> {got}")

    # per group: some fused variant must beat the reference at the
    # largest J where BOTH exist (the production regime the two-sweep
    # pipeline exists for). Rows without a group field (pre-§2.5
    # baselines) gate as one implicit group. A NEW group missing either
    # side is reported but never fails — same no-merge-dance rule as
    # new rows above (a reference-only baseline row must not break CI)
    # — but a group the BASELINE gated must not silently lose its
    # comparison (e.g. a pipeline-label typo dropping the reference
    # row would otherwise disarm the gate).
    def _by_group(payload):
        out = {}
        for r in _rows_by_name(payload).values():
            if "us_per_call" not in r or "j" not in r:
                continue
            out.setdefault(r.get("group", "default"), []).append(r)
        return out

    def _comparable_js(rows):
        fused_js = {r["j"] for r in rows
                    if str(r.get("pipeline", "")).startswith("fused")}
        ref_js = {r["j"] for r in rows if r.get("pipeline") == "reference"}
        return fused_js, fused_js & ref_js

    base_gated = {g for g, rows in _by_group(baseline).items()
                  if _comparable_js(rows)[1]}
    groups = _by_group(fresh)
    for req in REQUIRED_GROUPS:
        if not _comparable_js(groups.get(req, []))[1]:
            failures.append(
                f"required group {req!r} is missing a comparable "
                "reference/fused pair in the fresh results")
    any_fused = False
    for gname, rows in sorted(groups.items()):
        fused_js, both = _comparable_js(rows)
        if not both:
            if gname in base_gated:
                failures.append(
                    f"group {gname}: baseline had a comparable "
                    "reference/fused pair but the fresh results do not "
                    "(row dropped or pipeline label changed?)")
            else:
                print(f"[check_compress] group {gname}: no comparable "
                      "reference/fused pair (not gated)")
            any_fused = any_fused or bool(fused_js)
            continue
        any_fused = True
        j_max = max(both)
        at_max = [r for r in rows if r["j"] == j_max]
        ref = next(r for r in at_max if r.get("pipeline") == "reference")
        fused = [r for r in at_max
                 if str(r.get("pipeline", "")).startswith("fused")]
        best = min(fused, key=lambda r: r["us_per_call"])
        if not best["us_per_call"] < ref["us_per_call"]:
            failures.append(
                f"group {gname} J={j_max}: fused ({best['us_per_call']} us)"
                f" not faster than reference ({ref['us_per_call']} us)")
    if not any_fused:
        failures.append("no fused rows found in fresh results")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_compress.json")
    ap.add_argument("fresh", help="freshly benchmarked BENCH_compress.json")
    args = ap.parse_args(argv)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    failures = check(baseline, fresh)
    for f in failures:
        print(f"[check_compress] FAIL: {f}")
    if not failures:
        print("[check_compress] OK: no fused-path regressions "
              f"({len(_rows_by_name(fresh))} rows checked)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
