"""CI bench-regression gate for the compression hot path.

  PYTHONPATH=src python -m benchmarks.check_compress BASELINE.json FRESH.json

Compares a freshly produced BENCH_compress.json (``benchmarks.run --json
--only compress``) against the committed baseline and FAILS (exit 1) if:

- any fused-pipeline row regressed its deterministic audit metrics —
  ``sweeps_per_step`` (O(J)-traversal J-equivalents) or ``read_units``
  above the baseline row of the same name;
- at the largest benchmarked J, the fused path's us/call is not faster
  than the reference path (wall-clock is noisy on shared CI runners, so
  only this one robust ordering is gated, not absolute timings).

Rows present in only one file are reported but never fail the gate
(adding a new benchmark row must not need a two-step merge dance).
"""
from __future__ import annotations

import argparse
import json
import sys

# deterministic integer-ish metrics get an epsilon for float formatting
# noise only; a real regression moves them by >= 1/num_buckets
EPS = 1e-6


def _rows_by_name(payload: dict) -> dict:
    return {r["name"]: r for r in payload.get("rows", []) if "name" in r}


def check(baseline: dict, fresh: dict) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    failures = []
    base = _rows_by_name(baseline)
    new = _rows_by_name(fresh)

    for name, row in sorted(new.items()):
        if row.get("pipeline", "").startswith("fused"):
            ref_row = base.get(name)
            if ref_row is None:
                print(f"[check_compress] new row (not gated): {name}")
                continue
            for metric in ("sweeps_per_step", "read_units"):
                got, want = row.get(metric), ref_row.get(metric)
                if got is None or want is None:
                    continue
                if got > want + EPS:
                    failures.append(
                        f"{name}: {metric} regressed {want} -> {got}")

    # fused must beat reference at the largest J (the production regime
    # the two-sweep pipeline exists for)
    js = [r["j"] for r in new.values()
          if r.get("pipeline") == "fused" and "j" in r]
    if not js:
        failures.append("no fused rows found in fresh results")
        return failures
    j_max = max(js)
    by_pipe = {r.get("pipeline"): r for r in new.values()
               if r.get("j") == j_max and "us_per_call" in r}
    ref, fus = by_pipe.get("reference"), by_pipe.get("fused")
    if ref is None or fus is None:
        failures.append(f"J={j_max}: missing reference/fused timing rows")
    elif not fus["us_per_call"] < ref["us_per_call"]:
        failures.append(
            f"J={j_max}: fused ({fus['us_per_call']} us) not faster than "
            f"reference ({ref['us_per_call']} us)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_compress.json")
    ap.add_argument("fresh", help="freshly benchmarked BENCH_compress.json")
    args = ap.parse_args(argv)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    failures = check(baseline, fresh)
    for f in failures:
        print(f"[check_compress] FAIL: {f}")
    if not failures:
        print("[check_compress] OK: no fused-path regressions "
              f"({len(_rows_by_name(fresh))} rows checked)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
