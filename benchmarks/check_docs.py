"""Doc CI gate: README.md / DESIGN.md must not reference things that no
longer exist.

  PYTHONPATH=src python -m benchmarks.check_docs [README.md DESIGN.md ...]

Three checks, all against the CURRENT tree (exit 1 on any failure):

- every ``--flag`` token the docs mention is defined by some
  ``add_argument`` in src/, benchmarks/, or examples/ (``--help`` is
  argparse-implicit);
- every ``SparsifierConfig.<field>`` attribute the docs mention is a
  real dataclass field;
- every backtick-quoted or markdown-linked file/dir path resolves
  (tried as-is and under src/ and src/repro/, with a trailing
  ``.member`` or ``::TestClass`` suffix stripped and ``{a,b}`` braces
  expanded).

Deliberately regex-simple: the point is that renaming a flag, config
field, or module without updating the docs fails CI — not perfect
markdown parsing.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_DOCS = ("README.md", "DESIGN.md", "EXPERIMENTS.md")
FLAG_RE = re.compile(r"(?<![\w-])(--[a-z][a-z0-9_-]+)")
ADD_ARG_RE = re.compile(r"add_argument\(\s*['\"](--[a-z0-9_-]+)['\"]")
SPARSIFIER_FIELD_RE = re.compile(r"SparsifierConfig\.([a-z_]+)")
BACKTICK_RE = re.compile(r"`([^`\n]+)`")
MDLINK_RE = re.compile(r"\]\(([^)#\s]+)\)")
IMPLICIT_FLAGS = {"--help"}
PATH_ROOTS = ("", "src/", "src/repro/")


def _all_basenames() -> set:
    """Every file basename in the tracked trees — the resolution rule
    for bare ``foo.py`` doc mentions (their directory is usually given
    by the surrounding prose/table cell)."""
    names = set()
    for sub in ("src", "benchmarks", "examples", "tests", ".github"):
        for _dirpath, _dirs, files in os.walk(os.path.join(ROOT, sub)):
            names.update(files)
    names.update(f for f in os.listdir(ROOT)
                 if os.path.isfile(os.path.join(ROOT, f)))
    return names


def _source_flags() -> set:
    flags = set(IMPLICIT_FLAGS)
    for sub in ("src", "benchmarks", "examples"):
        for dirpath, _dirs, files in os.walk(os.path.join(ROOT, sub)):
            for f in files:
                if not f.endswith(".py"):
                    continue
                with open(os.path.join(dirpath, f)) as fh:
                    flags.update(ADD_ARG_RE.findall(fh.read()))
    return flags


def _expand_braces(token: str) -> list:
    m = re.search(r"\{([^{}]*)\}", token)
    if not m:
        return [token]
    out = []
    for part in m.group(1).split(","):
        out.extend(_expand_braces(token[:m.start()] + part + token[m.end():]))
    return out


def _path_candidates(token: str):
    token = token.split("::")[0].rstrip("/")
    for t in _expand_braces(token):
        # strip trailing ".member" accessor chains (core/aggregate.sync_
        # gradient -> core/aggregate), keeping real file extensions
        trims = [t]
        base = t
        for _ in range(3):
            stem, dot, ext = base.rpartition(".")
            if not dot or ext in ("py", "md", "json", "yml", "yaml", "txt"):
                break
            base = stem
            trims.append(base)
        for variant in trims:
            for root in PATH_ROOTS:
                yield os.path.join(ROOT, root, variant)
                if not variant.endswith((".py", ".md", ".json", ".yml")):
                    yield os.path.join(ROOT, root, variant + ".py")


def _looks_like_path(token: str) -> bool:
    if any(c in token for c in "()<>*=$ \t'\","):
        return False
    if token.startswith(("--", "http://", "https://")):
        return False
    return "/" in token or token.endswith((".py", ".md", ".json", ".yml"))


def check_doc(path: str, src_flags: set, fields: set,
              basenames: set) -> list:
    failures = []
    with open(path) as fh:
        text = fh.read()
    name = os.path.basename(path)
    for flag in sorted(set(FLAG_RE.findall(text))):
        if flag not in src_flags:
            failures.append(f"{name}: flag {flag} is not defined by any "
                            "add_argument in src/benchmarks/examples")
    for field in sorted(set(SPARSIFIER_FIELD_RE.findall(text))):
        if field not in fields:
            failures.append(f"{name}: SparsifierConfig.{field} is not a "
                            "config field")
    tokens = set(BACKTICK_RE.findall(text)) | set(MDLINK_RE.findall(text))
    for token in sorted(tokens):
        token = token.strip()
        if not _looks_like_path(token):
            continue
        if "/" not in token:
            if token not in basenames:
                failures.append(f"{name}: referenced file {token!r} does "
                                "not exist anywhere in the tree")
            continue
        if not any(os.path.exists(c) for c in
                   itertools.islice(_path_candidates(token), 64)):
            failures.append(f"{name}: referenced path {token!r} does not "
                            "resolve (tried as-is, under src/ and "
                            "src/repro/, and with trailing members "
                            "stripped)")
    return failures


def check(doc_paths) -> list:
    from repro.configs.base import SparsifierConfig
    fields = {f.name for f in dataclasses.fields(SparsifierConfig)}
    src_flags = _source_flags()
    basenames = _all_basenames()
    failures = []
    for p in doc_paths:
        full = p if os.path.isabs(p) else os.path.join(ROOT, p)
        if not os.path.exists(full):
            failures.append(f"doc file missing: {p}")
            continue
        failures.extend(check_doc(full, src_flags, fields, basenames))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("docs", nargs="*", default=list(DEFAULT_DOCS))
    args = ap.parse_args(argv)
    failures = check(args.docs or list(DEFAULT_DOCS))
    for f in failures:
        print(f"[check_docs] FAIL: {f}")
    if not failures:
        print(f"[check_docs] OK: {', '.join(args.docs or DEFAULT_DOCS)} "
              "reference only existing flags/fields/paths")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
